"""Histogram benchmarks — paper §6.2, Figures 9/10/11.

Fig 9  weak scaling, highly fragmented (many blocks per core).
Fig 10 weak scaling, perfectly balanced (1 block per core) — SplIter's
       worst case: measures pure overhead.
Fig 11 sensitivity to fragmentation at fixed locations.

Locations model cluster nodes; rows-per-location is held constant for the
weak scalings (paper: 880M points/node — scaled to this container).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api import Baseline, Rechunk, SplIter, engine
from repro.core.apps.histogram import histogram
from repro.core.blocked import BlockedArray, round_robin_placement

from benchmarks.harness import (
    Table,
    check_stream_bounds,
    report_row,
    smoke_executors,
    stream_disk_setup,
    timeit,
    winsorized,
)

POLICIES = (
    Baseline(),
    SplIter(),
    SplIter(materialize=True),
    SplIter(partitions_per_location="auto"),
    Rechunk(),
)
SMOKE_POLICIES = POLICIES + (SplIter(fusion="pallas"),)


def _dataset(locs: int, blocks_per_loc: int, rows_per_loc: int, d: int = 5, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.random((locs * rows_per_loc, d)).astype(np.float32)
    block_rows = max(1, rows_per_loc // blocks_per_loc)
    return BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )


def _run(x, policy, *, bins, repeats):
    # One persistent executor per measured row: repeated calls amortize
    # prepare/tracing (paper §6.3.1) and give the spliter_auto row's tuner
    # a schedule to advance through.  The traffic bill is paid by the FIRST
    # call only (the later ones hit the prepare cache), so it is captured
    # separately — the steady-state report would show bytes_moved == 0 for
    # Rechunk and hide the very cost these tables contrast.
    ex = engine("local")
    rep_box = {}

    def once():
        h, rep = histogram(x, bins=bins, policy=policy, executor=ex)
        rep_box.setdefault("prep_bytes", rep.bytes_moved)
        rep_box["rep"] = rep
        return h

    stats = winsorized(timeit(once, repeats=repeats))
    return stats, rep_box["rep"], rep_box["prep_bytes"]


def _stream_disk_row() -> dict:
    """The store=disk axis: 4×-budget dataset streamed out of core.

    32 fine blocks, one block per partition, so the double buffer's peak
    residency stays within the acceptance bound; results must be bit-exact
    vs the in-memory run (integer counts).
    """
    x = _dataset(2, 16, 2048, d=2)
    pol = SplIter(partitions_per_location=16)
    h_ref, _ = histogram(x, bins=8, policy=pol)
    (xd,), store, ex = stream_disk_setup(x)
    _, cold = histogram(xd, bins=8, policy=pol, executor=ex)
    h, rep = histogram(xd, bins=8, policy=pol, executor=ex)
    assert bool(jnp.all(h == h_ref)), "stream-disk histogram diverged"
    check_stream_bounds(
        store, prefetch_hits=rep.prefetch_hits, bytes_loaded=rep.bytes_loaded,
        context="histogram stream-disk",
    )
    row = report_row(pol, "stream-disk", rep, prep_bytes=cold.bytes_moved)
    ex.close()
    store.close()
    return row


def smoke() -> list[dict]:
    """Toy-size policy×executor grid for the CI smoke job (BENCH_histogram)."""
    x = _dataset(2, 4, 2048, d=2)
    rows = []
    for pol in SMOKE_POLICIES:
        for name, ex in smoke_executors():
            _, cold = histogram(x, bins=8, policy=pol, executor=ex)  # trace+prepare
            _, rep = histogram(x, bins=8, policy=pol, executor=ex)   # steady state
            rows.append(report_row(pol, name, rep, prep_bytes=cold.bytes_moved))
            if hasattr(ex, "close"):
                ex.close()
    rows.append(_stream_disk_row())
    return rows


def bench(quick: bool = True) -> list[Table]:
    rows_per_loc = 16_384 if quick else 131_072
    repeats = 3 if quick else 10
    bins = 8

    # -- Fig 9: weak scaling, fragmented (16 blocks/loc) ---------------------
    t9 = Table("histogram_weak_fragmented", "paper Fig. 9")
    for locs in (1, 2, 4, 8):
        x = _dataset(locs, 16, rows_per_loc)
        for pol in POLICIES:
            stats, rep, prep_bytes = _run(x, pol, bins=bins, repeats=repeats)
            t9.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                   dispatches=rep.dispatches, bytes_moved=prep_bytes,
                   **stats)

    # -- Fig 10: weak scaling, balanced (1 block/loc) -------------------------
    t10 = Table("histogram_weak_balanced", "paper Fig. 10")
    for locs in (1, 2, 4, 8):
        x = _dataset(locs, 1, rows_per_loc)
        for pol in POLICIES:
            stats, rep, prep_bytes = _run(x, pol, bins=bins, repeats=repeats)
            t10.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=rep.dispatches, bytes_moved=prep_bytes,
                    **stats)

    # -- Fig 11: fragmentation sweep at 8 locations ---------------------------
    t11 = Table("histogram_fragmentation", "paper Fig. 11")
    for bpl in (1, 4, 16, 48):
        x = _dataset(8, bpl, rows_per_loc)
        for pol in POLICIES:
            stats, rep, prep_bytes = _run(x, pol, bins=bins, repeats=repeats)
            t11.add(blocks_per_loc=bpl, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=rep.dispatches, bytes_moved=prep_bytes,
                    **stats)

    return [t9, t10, t11]
