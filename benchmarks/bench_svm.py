"""Cascade SVM benchmarks — paper §6.4, Figures 15/16/17.

Compute-bound: per-task cost is O(n²) in group rows, so materialized
execution (rechunk / spliter_mat) can win — the paper's key nuance.  The
SplIter's materialized partitions recover that advantage with zero
inter-location traffic (paper §7 future work, implemented here).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api import Baseline, Rechunk, SplIter, engine
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.blocked import BlockedArray, round_robin_placement

from benchmarks.harness import (
    Table,
    check_stream_bounds,
    report_row,
    smoke_executors,
    stream_disk_setup,
    timeit,
    winsorized,
)

POLICIES = (
    Baseline(),
    SplIter(),
    SplIter(materialize=True),
    SplIter(partitions_per_location="auto"),
    Rechunk(),
)


def _dataset(locs: int, blocks_per_loc: int, rows_per_loc: int, d: int = 8, seed=0):
    rng = np.random.default_rng(seed)
    n = locs * rows_per_loc
    pts = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32)
    labels = np.sign(pts @ w + 0.05 * rng.standard_normal(n)).astype(np.float32)
    block_rows = max(1, rows_per_loc // blocks_per_loc)
    mk = lambda a: BlockedArray.from_array(
        jnp.asarray(a), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )
    return mk(pts), mk(labels)


def _run(x, y, policy, *, steps, repeats):
    # One persistent executor per measured row: repeats amortize
    # prepare/tracing and advance the spliter_auto row's tuning schedule.
    # The rechunk traffic bill is paid by the FIRST call only (later calls
    # hit the prepare cache), so capture it separately for the tables.
    ex = engine("local")
    box = {}

    def once():
        res = cascade_svm(x, y, num_sv=32, steps=steps, iterations=1,
                          policy=policy, executor=ex)
        box.setdefault("prep_bytes", res.report.bytes_moved)
        box["res"] = res
        return res.sv_x

    stats = winsorized(timeit(once, repeats=repeats))
    return stats, box["res"], box["prep_bytes"]


def smoke() -> list[dict]:
    """Toy-size policy×executor grid for the CI smoke job (BENCH_svm)."""
    x, y = _dataset(2, 4, 256, d=4)
    rows = []
    for pol in POLICIES:
        for name, ex in smoke_executors():
            cold = None
            for _ in range(3):  # 3 calls: the auto row's probe schedule advances
                res = cascade_svm(
                    x, y, num_sv=16, steps=30, iterations=1, policy=pol,
                    executor=ex,
                )
                cold = cold if cold is not None else res.report
            rows.append(report_row(pol, name, res.report,
                                   prep_bytes=cold.bytes_moved))
            if hasattr(ex, "close"):
                ex.close()
    rows.append(_stream_disk_row())
    return rows


def _stream_disk_row() -> dict:
    """The store=disk axis: aligned points+labels chunked into ONE store.

    The multi-input case: x and y blocks share the chunk tier and stream
    together through each zipped partition view; support vectors must be
    bit-identical to the in-memory cascade.
    """
    x, y = _dataset(2, 16, 512, d=4)
    pol = SplIter(partitions_per_location=16)
    ref = cascade_svm(x, y, num_sv=16, steps=30, iterations=1, policy=pol)
    (xd, yd), store, ex = stream_disk_setup(x, y)
    cold = cascade_svm(xd, yd, num_sv=16, steps=30, iterations=1,
                       policy=pol, executor=ex)
    res = cascade_svm(xd, yd, num_sv=16, steps=30, iterations=1,
                      policy=pol, executor=ex)
    assert bool(jnp.all(res.sv_x == ref.sv_x)), "stream-disk svm SVs diverged"
    check_stream_bounds(
        store, prefetch_hits=res.report.prefetch_hits,
        bytes_loaded=res.report.bytes_loaded, context="svm stream-disk",
    )
    row = report_row(pol, "stream-disk", res.report,
                     prep_bytes=cold.report.bytes_moved)
    ex.close()
    store.close()
    return row


def bench(quick: bool = True) -> list[Table]:
    rows_per_loc = 1_024 if quick else 4_096
    steps = 100 if quick else 300
    repeats = 3 if quick else 10

    t15 = Table("svm_weak_fragmented", "paper Fig. 15")
    for locs in (1, 2, 4, 8):
        x, y = _dataset(locs, 8, rows_per_loc)
        for pol in POLICIES:
            stats, res, prep_bytes = _run(x, y, pol, steps=steps, repeats=repeats)
            t15.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.report.dispatches,
                    bytes_moved=prep_bytes, **stats)

    t16 = Table("svm_weak_balanced", "paper Fig. 16")
    for locs in (1, 2, 4, 8):
        x, y = _dataset(locs, 1, rows_per_loc)
        for pol in POLICIES:
            stats, res, prep_bytes = _run(x, y, pol, steps=steps, repeats=repeats)
            t16.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.report.dispatches,
                    bytes_moved=prep_bytes, **stats)

    t17 = Table("svm_fragmentation", "paper Fig. 17")
    for bpl in (1, 2, 4, 8):
        x, y = _dataset(8, bpl, rows_per_loc)
        for pol in POLICIES:
            stats, res, prep_bytes = _run(x, y, pol, steps=steps, repeats=repeats)
            t17.add(blocks_per_loc=bpl, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.report.dispatches,
                    bytes_moved=prep_bytes, **stats)

    return [t15, t16, t17]
