"""Benchmark entry point:  PYTHONPATH=src python -m benchmarks.run

One benchmark per paper table/figure (Figs 9–21, Table 1) plus the
framework-level benches (trainer accumulation modes, dispatch overhead).
Results print as tables and persist to results/bench/*.json.

``--full`` uses larger datasets / more repeats (paper-scale shapes);
default sizes finish in a few minutes on one CPU core.
"""

from __future__ import annotations

import argparse
import time

SUITES = ("histogram", "kmeans", "svm", "knn", "trainer")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", action="append", choices=SUITES, default=None)
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args()
    suites = args.suite or list(SUITES)
    quick = not args.full

    from benchmarks import (
        bench_histogram,
        bench_kmeans,
        bench_knn,
        bench_svm,
        bench_trainer,
    )

    mods = {
        "histogram": bench_histogram,
        "kmeans": bench_kmeans,
        "svm": bench_svm,
        "knn": bench_knn,
        "trainer": bench_trainer,
    }

    t_all = time.perf_counter()
    for name in suites:
        t0 = time.perf_counter()
        tables = mods[name].bench(quick=quick)
        for tbl in tables:
            tbl.show()
            tbl.save(args.out)
        print(f"[{name}] done in {time.perf_counter() - t0:.1f}s "
              f"→ {args.out}/*.json", flush=True)
    print(f"\nall suites done in {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()
