"""Benchmark smoke job:  PYTHONPATH=src python -m benchmarks.smoke

Runs every benchmark suite at toy size — the policy×executor grid per app —
and emits one ``BENCH_<app>.json`` artifact each (wall, dispatches, merges,
traces, bytes_moved per row).  CI runs this on every push so the perf
trajectory of the execution layer (dispatch counts, collective traffic,
jit-cache behaviour) is tracked from PR 2 on; the structural columns are
exact on any host, wall-clock is indicative only.

Exits non-zero if any suite fails, so a regression that breaks an app at
toy size fails the job rather than silently dropping its artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.environ.get("REPRO_BENCH_DIR", "results/bench"))
    ap.add_argument("--suite", action="append", default=None,
                    help="subset of suites (default: all)")
    args = ap.parse_args()

    from benchmarks import (
        bench_histogram,
        bench_kmeans,
        bench_knn,
        bench_svm,
        bench_trainer,
    )

    suites = {
        "histogram": bench_histogram,
        "kmeans": bench_kmeans,
        "svm": bench_svm,
        "knn": bench_knn,
        "trainer": bench_trainer,
    }
    selected = args.suite or list(suites)
    os.makedirs(args.out, exist_ok=True)

    t_all = time.perf_counter()
    for name in selected:
        t0 = time.perf_counter()
        rows = suites[name].smoke()
        elapsed = time.perf_counter() - t0
        path = os.path.join(args.out, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(
                {"app": name, "elapsed_s": round(elapsed, 2), "rows": rows},
                f,
                indent=1,
            )
        print(f"[{name}] {len(rows)} rows in {elapsed:.1f}s → {path}", flush=True)
        for r in rows:
            print(
                f"  {r['policy']:<16} {r['executor']:<9} "
                f"wall={r['wall_s']:<9} disp={r['dispatches']:<5} "
                f"traces={r['traces']:<3} bytes={r['bytes_moved']}"
            )
    print(f"smoke done in {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()
