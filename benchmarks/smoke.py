"""Benchmark smoke job:  PYTHONPATH=src python -m benchmarks.smoke

Runs every benchmark suite at toy size — the policy×executor grid per app —
and emits one ``BENCH_<app>.json`` artifact each (wall, dispatches, merges,
traces, bytes_moved, granularity, retunes per row).

The perf trajectory is *committed*: the canonical ``BENCH_<app>.json``
baselines live in the repo root and CI re-runs the grid on every push,
diffing the **structural** columns (dispatches / merges / traces /
bytes_moved — exact on any single-device host) against the committed
baseline via ``--baseline .``.  Wall-clock is indicative only and never
diffed.  Rows of autotuned policies (``*_auto``) are compared by presence
only: their steady-state granularity follows *measured* wall times, so
their structural columns are legitimately host-dependent.

Baseline files are written with ``--write-baseline DIR`` and contain ONLY
the row identity + structural columns — no wall times, no tuner outputs —
so the committed artifact is deterministic and regenerating it on any
host produces an empty git diff unless something structural actually
changed.  Full rows (wall, granularity, retunes) always go to ``--out``
for the CI artifact upload.

Exits non-zero if any suite fails or the baseline diff is non-empty, so a
regression that breaks an app at toy size — or silently changes the
execution layer's dispatch/traffic behaviour — fails the job rather than
slipping through.  After an *intentional* change, regenerate and commit:
``PYTHONPATH=src python -m benchmarks.smoke --write-baseline .``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

#: columns that must match the committed baseline exactly (deterministic on
#: any single-device host); wall_s and the autotuner outputs are excluded.
#: ``bytes_moved`` is the steady-state (cache-warm) traffic, ``prep_bytes``
#: the first call's one-time prepare traffic — both diffed, so regressions
#: in either the per-iteration or the preparation path are caught.
#: ``remote_dispatches`` and ``retries`` pin the cluster rows: how much of
#: each plan crosses the IPC boundary is structural, and a non-zero retry
#: count in a no-fault smoke run is a bug.  ``shm_bytes`` pins the cluster
#: data plane: exact block bytes copied into shared-memory segments (raw
#: array sizes, not serialized forms — deterministic), so a regression
#: that silently re-routes payloads back onto the pipes (shm_bytes → 0)
#: or re-copies cached exports (shm_bytes inflated) fails the diff.
#: ``ipc_bytes`` is excluded — serialized sizes may drift across
#: pickle/numpy versions.  ``jobs`` and ``resumes`` pin the JobServer
#: rows: how many submissions one app run multiplexes is structural, and
#: a non-zero resume count in a no-kill smoke run is a bug.
#: ``overlapped_launches`` pins the pipelined rows (DESIGN.md §14): the
#: overlap count is frozen at submit time — a pure function of the app's
#: call order, not of host speed — so a regression that silently stops
#: iterations from overlapping (count → 0) fails the diff.
#: ``p2p_bytes`` and ``driver_merge_bytes`` pin the peer-exchange path
#: (DESIGN.md §16): both are exact — every member partial is consumed
#: exactly once, and with p2p off (the default at smoke partial sizes)
#: ``p2p_bytes`` must be exactly 0 on every grid row, so an auto gate
#: that silently flips (or a fold that double-bills) fails the diff.
#: The ``cluster-p2p`` kmeans row pins the collapse itself: one merged
#: partial per location, asserted in-suite at ≥4×.
#: ``steals`` and ``scale_events`` pin the elastic rows (DESIGN.md §15):
#: both must be exactly 0 on every non-elastic row (stealing defaults
#: off, so any non-zero count here is an accounting leak).  The elastic
#: straggler rows themselves (executor ``cluster-elastic``) are
#: presence-only, like ``*_auto`` policies: which units get stolen
#: follows measured load, so their structural columns are legitimately
#: host-dependent.
STRUCTURAL = (
    "dispatches",
    "merges",
    "traces",
    "bytes_moved",
    "prep_bytes",
    "remote_dispatches",
    "shm_bytes",
    "p2p_bytes",
    "driver_merge_bytes",
    "retries",
    "jobs",
    "resumes",
    "overlapped_launches",
    "steals",
    "scale_events",
)


def _row_key(row: dict) -> tuple:
    return (row.get("policy"), row.get("executor"))


def diff_rows(app: str, rows: list[dict], baseline_rows: list[dict]) -> list[str]:
    """Human-readable structural mismatches of one suite vs its baseline."""
    got = {_row_key(r): r for r in rows}
    want = {_row_key(r): r for r in baseline_rows}
    problems = []
    for key in sorted(set(want) - set(got)):
        problems.append(f"{app}: row {key} missing (present in baseline)")
    for key in sorted(set(got) - set(want)):
        problems.append(f"{app}: row {key} new (absent from baseline — "
                        "regenerate with --write-baseline . and commit)")
    for key in sorted(set(got) & set(want)):
        policy = key[0] or ""
        executor = key[1] or ""
        if "_auto" in policy:
            continue  # measured-granularity rows: presence-only
        if "elastic" in executor:
            continue  # measured-load steal rows: presence-only
        for col in STRUCTURAL:
            g, w = got[key].get(col), want[key].get(col)
            if g != w:
                problems.append(f"{app}: row {key} {col} = {g}, baseline {w}")
    return problems


def _baseline_row(row: dict) -> dict:
    """Strip a row to its deterministic identity + structural columns."""
    keep = {"policy": row.get("policy"), "executor": row.get("executor")}
    if "_auto" not in (row.get("policy") or "") and "elastic" not in (
        row.get("executor") or ""
    ):
        keep.update({col: row.get(col) for col in STRUCTURAL})
    return keep


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default=os.environ.get("REPRO_BENCH_DIR", "results/bench"),
        help="directory for the full BENCH_<app>.json artifacts",
    )
    ap.add_argument(
        "--baseline",
        default=None,
        help="directory holding committed BENCH_<app>.json files; structural "
        "columns are diffed and mismatches fail the run",
    )
    ap.add_argument(
        "--write-baseline",
        default=None,
        metavar="DIR",
        help="also write structural-only baseline files (deterministic; "
        "commit these — canonically the repo root)",
    )
    ap.add_argument("--suite", action="append", default=None,
                    choices=["histogram", "kmeans", "svm", "knn", "trainer"],
                    help="subset of suites (default: all)")
    args = ap.parse_args()

    from benchmarks import (
        bench_histogram,
        bench_kmeans,
        bench_knn,
        bench_svm,
        bench_trainer,
    )

    suites = {
        "histogram": bench_histogram,
        "kmeans": bench_kmeans,
        "svm": bench_svm,
        "knn": bench_knn,
        "trainer": bench_trainer,
    }
    selected = args.suite or list(suites)
    os.makedirs(args.out, exist_ok=True)

    problems: list[str] = []
    t_all = time.perf_counter()
    for name in selected:
        t0 = time.perf_counter()
        rows = suites[name].smoke()
        elapsed = time.perf_counter() - t0
        path = os.path.join(args.out, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(
                {"app": name, "elapsed_s": round(elapsed, 2), "rows": rows},
                f,
                indent=1,
            )
        if args.write_baseline is not None:
            os.makedirs(args.write_baseline, exist_ok=True)
            base_out = os.path.join(args.write_baseline, f"BENCH_{name}.json")
            with open(base_out, "w") as f:
                json.dump(
                    {"app": name, "rows": [_baseline_row(r) for r in rows]},
                    f,
                    indent=1,
                )
                f.write("\n")
        print(f"[{name}] {len(rows)} rows in {elapsed:.1f}s → {path}", flush=True)
        for r in rows:
            print(
                f"  {r['policy']:<16} {r['executor']:<9} "
                f"wall={r['wall_s']:<9} disp={r['dispatches']:<5} "
                f"traces={r['traces']:<3} bytes={r['bytes_moved']}"
                + (f" ppl={r['granularity']} retunes={r['retunes']}"
                   if r.get("granularity") else "")
            )
        if args.baseline is not None:
            base_path = os.path.join(args.baseline, f"BENCH_{name}.json")
            if not os.path.exists(base_path):
                problems.append(f"{name}: no committed baseline {base_path}")
            else:
                with open(base_path) as f:
                    baseline_rows = json.load(f)["rows"]
                problems.extend(diff_rows(name, rows, baseline_rows))
    print(f"smoke done in {time.perf_counter() - t_all:.1f}s")

    if args.baseline is not None:
        if problems:
            print(f"\nbaseline diff: {len(problems)} structural mismatch(es):")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print("baseline diff: clean")


if __name__ == "__main__":
    main()
