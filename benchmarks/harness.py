"""Benchmark harness: timing, winsorized statistics, tabular reports.

The paper's evaluation methodology (§6): dozens of repeats, winsorizing to
clean outliers, inter-quartile error bars.  We reproduce it scaled to this
container — the *structural* metrics (dispatch counts, bytes moved, trace
counts) are exact regardless of host speed; wall-clock columns quantify the
dispatch-overhead effect on the CPU backend.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Iterable

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------


def timeit(fn: Callable[[], Any], *, repeats: int = 5, warmup: int = 1) -> list[float]:
    """Wall-times of ``fn()`` after ``warmup`` discarded calls (jit tracing)."""
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return out


def winsorized(times: Iterable[float], pct: float = 10.0) -> dict[str, float]:
    """Winsorize at ``pct`` percent per tail; report median + IQR (paper §6)."""
    t = np.asarray(sorted(times), np.float64)
    lo, hi = np.percentile(t, [pct, 100 - pct])
    t = np.clip(t, lo, hi)
    q1, med, q3 = np.percentile(t, [25, 50, 75])
    return {"median_s": float(med), "iqr_lo_s": float(q1), "iqr_hi_s": float(q3)}


# ---------------------------------------------------------------------------
# result rows + reporting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Table:
    """One paper table/figure analogue: named rows of measurement dicts."""

    name: str
    figure: str            # which paper figure/table this mirrors
    rows: list[dict] = dataclasses.field(default_factory=list)

    def add(self, **row) -> None:
        self.rows.append(row)

    # -- printing -----------------------------------------------------------

    def show(self) -> None:
        print(f"\n== {self.name}  ({self.figure}) ==")
        if not self.rows:
            print("  (empty)")
            return
        cols = list(self.rows[0].keys())
        widths = {
            c: max(len(c), *(len(_fmt(r.get(c))) for r in self.rows)) for c in cols
        }
        print("  " + "  ".join(c.ljust(widths[c]) for c in cols))
        for r in self.rows:
            print("  " + "  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))

    # -- persistence ---------------------------------------------------------

    def save(self, out_dir: str = RESULTS_DIR) -> str:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump(
                {"name": self.name, "figure": self.figure, "rows": self.rows},
                f,
                indent=1,
            )
        return path


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


# ---------------------------------------------------------------------------
# smoke-job support (CI perf trajectory: BENCH_<app>.json per suite)
# ---------------------------------------------------------------------------


def policy_label(policy) -> str:
    """Row label for a policy: mode name + the fusion knob when non-default."""
    label = getattr(policy, "mode_name", str(policy))
    if getattr(policy, "fusion", "auto") != "auto":
        label += f"+{policy.fusion}"
    return label


def report_row(
    policy,
    executor_name: str,
    report,
    *,
    wall_s: float | None = None,
    prep_bytes: int | None = None,
) -> dict:
    """One BENCH_<app>.json row: structural metrics + wall for a config.

    ``report`` is the steady-state execution (prepare cache warm, jit cache
    hit), so its ``bytes_moved`` shows the per-iteration traffic;
    ``prep_bytes`` carries the FIRST call's one-time prepare traffic (the
    rechunk bill) so baseline diffs can catch preparation regressions the
    steady-state columns are blind to.
    """
    return {
        "policy": policy_label(policy),
        "executor": executor_name,
        "wall_s": round(report.wall_s if wall_s is None else wall_s, 5),
        "dispatches": report.dispatches,
        "merges": report.merges,
        "traces": report.traces,
        "bytes_moved": report.bytes_moved,
        "prep_bytes": report.bytes_moved if prep_bytes is None else prep_bytes,
        "granularity": report.granularity,
        "retunes": report.retunes,
        "bytes_loaded": report.bytes_loaded,
        "bytes_spilled": report.bytes_spilled,
        "prefetch_hits": report.prefetch_hits,
        "remote_dispatches": report.remote_dispatches,
        "ipc_bytes": report.ipc_bytes,
        "shm_bytes": report.shm_bytes,
        "p2p_bytes": report.p2p_bytes,
        "driver_merge_bytes": report.driver_merge_bytes,
        "retries": report.retries,
        "overlapped_launches": report.overlapped_launches,
        "steals": report.steals,
        "scale_events": report.scale_events,
    }


def smoke_executors():
    """Fresh (name, executor) pairs for the policy×executor smoke grid.

    ``stream`` runs on in-memory inputs here (no chunk store): it must
    degrade to plain sequential execution with LocalExecutor's structural
    numbers.  The out-of-core axis is separate — see :func:`stream_disk_row`.
    ``cluster`` runs the same plans over real worker processes: results
    must stay bit-identical and dispatch counts match Local, while
    ``remote_dispatches`` bills how much of the work crossed the IPC
    boundary (``retries`` must be 0 — no faults are injected here).
    """
    from repro.api import engine

    return [(name, engine(name)) for name in
            ("local", "threaded", "mesh", "stream", "cluster")]


#: residency budget = dataset bytes / this factor on the store=disk axis —
#: the acceptance configuration: the dataset cannot fit, so it must stream.
DISK_BUDGET_FRACTION = 4

#: peak resident chunk bytes must stay under budget × this bound while the
#: 4×-budget dataset streams (current partition + prefetched partition +
#: one in-flight insert).
RESIDENCY_BOUND = 1.25


def stream_disk_setup(*arrays, budget_fraction: int = DISK_BUDGET_FRACTION):
    """Chunk ``arrays`` into one DiskStore sized 1/``budget_fraction`` of them.

    Returns ``(chunked_arrays, store, StreamExecutor)`` — the ``store=disk``
    bench axis: the dataset is ``budget_fraction``× the residency budget,
    so completing at all proves out-of-core streaming works.
    """
    from repro.api import DiskStore, engine

    total = sum(a.nbytes for a in arrays)
    store = DiskStore(residency_bytes=max(1, total // budget_fraction))
    chunked = tuple(a.to_store(store) for a in arrays)
    return chunked, store, engine("stream", close_stores=False)


def check_stream_bounds(store, *, prefetch_hits: int, bytes_loaded: int, context: str) -> None:
    """Assert the out-of-core row's acceptance bounds (fail the smoke job).

    Bounded RSS — peak resident chunk bytes within ``RESIDENCY_BOUND`` of
    the budget — and a warm streaming pipeline.  Result equality vs the
    in-memory run is asserted by the caller, which has both values.
    """
    budget = store.residency_bytes
    peak = store.stats.peak_resident_bytes
    assert peak <= RESIDENCY_BOUND * budget, (
        f"{context}: peak resident {peak}B exceeds {RESIDENCY_BOUND}x "
        f"budget ({budget}B)"
    )
    assert prefetch_hits > 0, f"{context}: prefetch pipeline never hit"
    assert bytes_loaded > 0, f"{context}: nothing streamed from spill"
