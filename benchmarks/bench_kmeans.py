"""k-means benchmarks — paper §6.3, Figures 12/13/14.

Iterative memory-bound application: the split/rechunk cost is paid once and
diluted across iterations; baseline per-block dispatch overhead is paid
every iteration (paper: 10 loops amplify it 10×).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Baseline, JobClient, Rechunk, SplIter, engine
from repro.core.apps.kmeans import kmeans
from repro.core.blocked import BlockedArray, round_robin_placement

from benchmarks.harness import (
    Table,
    check_stream_bounds,
    policy_label,
    smoke_executors,
    stream_disk_setup,
    timeit,
    winsorized,
)

POLICIES = (
    Baseline(),
    SplIter(),
    SplIter(materialize=True),
    SplIter(partitions_per_location="auto"),
    Rechunk(),
)
SMOKE_POLICIES = POLICIES + (SplIter(fusion="pallas"),)


def _dataset(locs: int, blocks_per_loc: int, rows_per_loc: int, d: int = 20, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.random((locs * rows_per_loc, d)).astype(np.float32)
    block_rows = max(1, rows_per_loc // blocks_per_loc)
    return BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )


def _run(x, policy, *, k, iters, repeats):
    box = {}

    def once():
        res = kmeans(x, k=k, iters=iters, seed=1, policy=policy)
        box["res"] = res
        return res.centers

    stats = winsorized(timeit(once, repeats=repeats))
    res = box["res"]
    return stats, res


def _aggregate_row(pol, executor_name: str, warm, res) -> dict:
    """One BENCH_kmeans row aggregating a whole multi-iteration run."""
    return {
        "policy": policy_label(pol),
        "executor": executor_name,
        "wall_s": round(res.total_wall_s, 5),
        "dispatches": res.total_dispatches,
        "merges": sum(r.merges for r in res.reports),
        "traces": sum(r.traces for r in res.reports),
        "bytes_moved": res.total_bytes_moved,
        "prep_bytes": warm.total_bytes_moved,
        "granularity": res.reports[-1].granularity,
        "retunes": res.total_retunes,
        "bytes_loaded": sum(r.bytes_loaded for r in res.reports),
        "bytes_spilled": sum(r.bytes_spilled for r in res.reports),
        "prefetch_hits": sum(r.prefetch_hits for r in res.reports),
        "remote_dispatches": sum(r.remote_dispatches for r in res.reports),
        "ipc_bytes": sum(r.ipc_bytes for r in res.reports),
        "shm_bytes": sum(r.shm_bytes for r in res.reports),
        "p2p_bytes": sum(r.p2p_bytes for r in res.reports),
        "driver_merge_bytes": sum(r.driver_merge_bytes for r in res.reports),
        "retries": sum(r.retries for r in res.reports),
        "jobs": 0,
        "resumes": 0,
        "overlapped_launches": sum(r.overlapped_launches for r in res.reports),
        "steals": sum(r.steals for r in res.reports),
        "scale_events": sum(r.scale_events for r in res.reports),
    }


def smoke() -> list[dict]:
    """Toy-size policy×executor grid for the CI smoke job (BENCH_kmeans).

    Iterative app: rows aggregate the whole 3-iteration run (dispatches and
    bytes summed, traces summed — 0 after warmup shows the jit-cache hit).
    """
    x = _dataset(2, 4, 1024, d=4)
    rows = []
    for pol in SMOKE_POLICIES:
        for name, ex in smoke_executors():
            warm = kmeans(x, k=4, iters=3, policy=pol, executor=ex)  # warm+prepare
            res = kmeans(x, k=4, iters=3, policy=pol, executor=ex)   # steady state
            rows.append(_aggregate_row(pol, name, warm, res))
            if hasattr(ex, "close"):
                ex.close()
    rows.append(_stream_disk_row())
    rows.append(_server_row())
    rows.extend(_pipelined_rows())
    rows.append(_elastic_row())
    rows.append(_p2p_row())
    return rows


def _p2p_row() -> dict:
    """The peer-exchange axis (DESIGN.md §16): worker-side merge folds.

    Same iterative plan on two cluster pools — ``p2p=False`` (every
    partial crosses the reply pipe for a driver-side fold) vs ``p2p=True``
    (each location's fold chain runs worker-side over published
    ``/dev/shm`` partials).  With 4 partitions per location the driver's
    merge traffic must collapse ≥4× (N partials → one merged partial per
    location), the member bytes must reappear as ``p2p_bytes``, and the
    centers must stay bit-identical — the fold tree is the same
    association in the same order on both routes.  All three are
    structural; the row is baseline-diffed exactly.
    """
    x = _dataset(2, 8, 8192, d=8)
    pol = SplIter(partitions_per_location=4)

    pinned_ex = engine("cluster", p2p=False)
    kmeans(x, k=8, iters=2, policy=pol, executor=pinned_ex)  # warm
    pinned = kmeans(x, k=8, iters=3, policy=pol, executor=pinned_ex)
    pinned_ex.close()

    ex = engine("cluster", p2p=True)
    warm = kmeans(x, k=8, iters=2, policy=pol, executor=ex)
    res = kmeans(x, k=8, iters=3, policy=pol, executor=ex)
    ex.close()

    assert bool(jnp.all(res.centers == pinned.centers)), (
        "p2p kmeans diverged from the pinned run"
    )
    p2p_bytes = sum(r.p2p_bytes for r in res.reports)
    merged = sum(r.driver_merge_bytes for r in res.reports)
    pinned_merged = sum(r.driver_merge_bytes for r in pinned.reports)
    assert p2p_bytes > 0, "p2p kmeans never folded worker-side"
    assert pinned_merged >= 4 * merged, (
        f"driver merge traffic did not collapse: pinned {pinned_merged}B "
        f"vs p2p {merged}B"
    )
    row = _aggregate_row(pol, "cluster-p2p", warm, res)
    row["pinned_driver_merge_bytes"] = pinned_merged
    return row


def _pipelined_rows() -> list[dict]:
    """The pipelined-iteration axis (DESIGN.md §14): Lloyd with no barrier.

    Same data, same policy, ``pipeline=True`` (depth-2 window of async
    executes, centers carried as a Deferred): centers must stay
    bit-identical to the barriered loop on the same executor, and every
    iteration past the first must report overlapped launches — both are
    structural, so a regression that quietly serializes (or reorders) the
    pipeline fails the smoke job.

    The dataset is deliberately larger than the toy grid (16K×8 rather
    than 2K×4): the pipeline hides the per-execute barrier — merge wait,
    host-side update, next iteration's lowering — so the comparison only
    means something when iterations carry real compute.  Both arms are
    warmed (the pipelined machinery traces on first use too) and timed
    as a median of 3; ``barriered_wall_s`` rides in the row so each
    pipelined row carries its own wall-clock comparison (wall columns
    are informational, never baseline-diffed — on a single-core runner
    the two arms tie within noise, the overlap needs idle cores or real
    transport latency to pay).
    """
    from statistics import median

    x = _dataset(2, 8, 8192, d=8)
    pol = SplIter(partitions_per_location=2)
    rows = []
    for name, ex in (("threaded", engine("threaded")), ("cluster", engine("cluster"))):
        kmeans(x, k=8, iters=2, policy=pol, executor=ex)  # warm barriered
        kmeans(x, k=8, iters=2, policy=pol, executor=ex, pipeline=True)
        bars, pipes = [], []
        for _ in range(3):
            t0 = time.perf_counter()
            warm = kmeans(x, k=8, iters=6, policy=pol, executor=ex)
            bars.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            res = kmeans(x, k=8, iters=6, policy=pol, executor=ex, pipeline=True)
            pipes.append(time.perf_counter() - t0)
        assert bool(jnp.all(res.centers == warm.centers)), (
            f"pipelined kmeans diverged on {name}"
        )
        overlapped = sum(r.overlapped_launches for r in res.reports)
        assert overlapped > 0, f"pipelined kmeans never overlapped on {name}"
        row = _aggregate_row(pol, f"{name}-pipelined", warm, res)
        row["wall_s"] = round(median(pipes), 5)
        row["barriered_wall_s"] = round(median(bars), 5)
        rows.append(row)
        ex.close()
    return rows


def _elastic_row() -> dict:
    """The elasticity axis (DESIGN.md §15): a straggler vs work stealing.

    One worker is slowed ~10× via the fault hook (a 50ms sleep before
    every unit execution, dwarfing the ~ms unit compute), making it a
    straggler owning half the partitions.  The *pinned* arm leaves the
    schedule locality-bound — the straggler's queue gates every
    iteration; the *elastic* arm enables work stealing, so idle siblings
    raid the straggler's queue whenever the fitted cost model predicts
    the move pays (descriptors over shm, not bytes).

    Three things are load-bearing and asserted here: stealing actually
    happened (``steals > 0``), centers stay bit-identical to the pinned
    run, and the elastic wall is at most half the pinned wall (the
    straggler's queue really was offloaded, not just shuffled).  The row
    itself is presence-only in the baseline diff — which units get stolen
    follows measured load — and carries ``pinned_wall_s`` so the
    comparison rides in the artifact.
    """
    from statistics import median

    from repro.api import FaultPlan

    x = _dataset(2, 8, 8192, d=8)
    pol = SplIter(partitions_per_location=4)
    slow = FaultPlan(slow=((0, 0.05),))

    pinned_ex = engine("cluster", fault_plan=slow)
    kmeans(x, k=8, iters=2, policy=pol, executor=pinned_ex)  # warm
    pinned_walls, pinned_res = [], None
    for _ in range(3):
        t0 = time.perf_counter()
        pinned_res = kmeans(x, k=8, iters=3, policy=pol, executor=pinned_ex)
        pinned_walls.append(time.perf_counter() - t0)
    pinned_ex.close()

    ex = engine("cluster", fault_plan=slow, steal=True)
    warm = kmeans(x, k=8, iters=2, policy=pol, executor=ex)  # warm
    walls, res = [], None
    for _ in range(3):
        t0 = time.perf_counter()
        res = kmeans(x, k=8, iters=3, policy=pol, executor=ex)
        walls.append(time.perf_counter() - t0)
    steals = sum(r.steals for r in res.reports)
    assert steals > 0, "elastic kmeans never stole from the straggler"
    assert bool(jnp.all(res.centers == pinned_res.centers)), (
        "elastic kmeans diverged from the pinned straggler run"
    )
    pinned_wall, elastic_wall = median(pinned_walls), median(walls)
    assert elastic_wall <= 0.5 * pinned_wall, (
        f"stealing did not offload the straggler: elastic {elastic_wall:.3f}s "
        f"vs pinned {pinned_wall:.3f}s"
    )
    row = _aggregate_row(pol, "cluster-elastic", warm, res)
    row["wall_s"] = round(elastic_wall, 5)
    row["pinned_wall_s"] = round(pinned_wall, 5)
    ex.close()
    return row


def _server_row() -> dict:
    """The engine-as-a-service axis: kmeans through JobServer + JobClient.

    Each Lloyd iteration becomes one server job (3 iterations → 3 jobs),
    multiplexed on the server's shared pool; centers must stay bit-identical
    to the direct-executor run.  ``jobs`` (submissions in the steady-state
    window) and ``resumes`` (0 — nobody killed the server) are structural.
    """
    x = _dataset(2, 4, 1024, d=4)
    pol = SplIter()
    ref = kmeans(x, k=4, iters=3, policy=pol)
    server = engine("server")
    client = JobClient(server, tenant="bench")
    warm = kmeans(x, k=4, iters=3, policy=pol, executor=client)  # warm+prepare
    jobs_before = len(server.jobs())
    res = kmeans(x, k=4, iters=3, policy=pol, executor=client)   # steady state
    assert bool(jnp.all(res.centers == ref.centers)), "server kmeans diverged"
    row = _aggregate_row(pol, "server", warm, res)
    row["jobs"] = len(server.jobs()) - jobs_before
    row["resumes"] = server.resumed_jobs
    server.close()
    return row


def _stream_disk_row() -> dict:
    """The store=disk axis: 3 Lloyd iterations over a 4×-budget dataset.

    The iterative stress case for the chunk tier: every iteration re-streams
    all spilled blocks (aggregate ``bytes_loaded`` ≈ iters × dataset) while
    centers stay bit-identical to the in-memory run.
    """
    x = _dataset(2, 16, 1024, d=4)
    pol = SplIter(partitions_per_location=16)
    ref = kmeans(x, k=4, iters=3, policy=pol)
    (xd,), store, ex = stream_disk_setup(x)
    warm = kmeans(xd, k=4, iters=3, policy=pol, executor=ex)
    res = kmeans(xd, k=4, iters=3, policy=pol, executor=ex)
    assert bool(jnp.all(res.centers == ref.centers)), "stream-disk kmeans diverged"
    check_stream_bounds(
        store,
        prefetch_hits=sum(r.prefetch_hits for r in res.reports),
        bytes_loaded=sum(r.bytes_loaded for r in res.reports),
        context="kmeans stream-disk",
    )
    row = _aggregate_row(pol, "stream-disk", warm, res)
    ex.close()
    store.close()
    return row


def bench(quick: bool = True) -> list[Table]:
    rows_per_loc = 8_192 if quick else 65_536
    iters = 5 if quick else 10
    repeats = 3 if quick else 10
    k = 8

    t12 = Table("kmeans_weak_fragmented", "paper Fig. 12")
    for locs in (1, 2, 4, 8):
        x = _dataset(locs, 16, rows_per_loc)
        for pol in POLICIES:
            stats, res = _run(x, pol, k=k, iters=iters, repeats=repeats)
            t12.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.total_dispatches,
                    bytes_moved=res.total_bytes_moved, **stats)

    t13 = Table("kmeans_weak_balanced", "paper Fig. 13")
    for locs in (1, 2, 4, 8):
        x = _dataset(locs, 1, rows_per_loc)
        for pol in POLICIES:
            stats, res = _run(x, pol, k=k, iters=iters, repeats=repeats)
            t13.add(locations=locs, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.total_dispatches,
                    bytes_moved=res.total_bytes_moved, **stats)

    t14 = Table("kmeans_fragmentation", "paper Fig. 14")
    for bpl in (1, 4, 16, 48):
        x = _dataset(8, bpl, rows_per_loc)
        for pol in POLICIES:
            stats, res = _run(x, pol, k=k, iters=iters, repeats=repeats)
            t14.add(blocks_per_loc=bpl, mode=pol.mode_name, blocks=x.num_blocks,
                    dispatches=res.total_dispatches,
                    bytes_moved=res.total_bytes_moved, **stats)

    return [t12, t13, t14]
