"""k-NN benchmarks — paper §6.5, Figures 18/19/20/21 and Table 1.

Fig 18/19 + Table 1: microkernel characterization — build time grows with
structure size, lookup time is sub-linear in it (the consolidation
argument).  Fig 20: full-stack scalability.  Fig 21: fit-dataset scaling —
blocks/second improves with consolidated structures (log-like lookups)
while per-block baselines stay flat (linear).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Baseline, Rechunk, SplIter, engine
from repro.core.apps.knn import _lookup, knn
from repro.core.blocked import BlockedArray, round_robin_placement

from benchmarks.harness import (
    Table,
    check_stream_bounds,
    report_row,
    smoke_executors,
    stream_disk_setup,
    timeit,
    winsorized,
)

POLICIES = (
    Baseline(),
    SplIter(),
    SplIter(partitions_per_location="auto"),
    Rechunk(),
)


def _blocked(arr, block_rows, locs):
    return BlockedArray.from_array(
        jnp.asarray(arr), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )


def smoke() -> list[dict]:
    """Toy-size policy×executor grid for the CI smoke job (BENCH_knn)."""
    rng = np.random.default_rng(0)
    d = 3
    fit = _blocked(rng.random((2 * 4 * 256, d)).astype(np.float32), 256, 2)
    qry = _blocked(rng.random((512, d)).astype(np.float32), 256, 2)
    rows = []
    for pol in POLICIES:
        for name, ex in smoke_executors():
            cold = None
            for _ in range(3):  # 3 calls: the auto row's probe schedule advances
                res = knn(fit, qry, k=4, policy=pol, executor=ex)
                cold = cold if cold is not None else res.report
            rows.append(report_row(pol, name, res.report,
                                   prep_bytes=cold.bytes_moved))
            if hasattr(ex, "close"):
                ex.close()
    rows.append(_stream_disk_row())
    return rows


def _stream_disk_row() -> dict:
    """The store=disk axis: 4×-budget fit dataset, consolidated structures.

    The map_partitions path: structures build from streamed chunk views
    (one block per partition), then the query loop runs against the
    (resident) structures; neighbor ids must match the in-memory run
    exactly — global row ordering survives the chunk tier.
    """
    rng = np.random.default_rng(0)
    d = 3
    fit_mem = _blocked(rng.random((2 * 16 * 128, d)).astype(np.float32), 128, 2)
    qry = _blocked(rng.random((512, d)).astype(np.float32), 256, 2)
    pol = SplIter(partitions_per_location=16)
    ref = knn(fit_mem, qry, k=4, policy=pol)
    (fit_disk,), store, ex = stream_disk_setup(fit_mem)
    cold = knn(fit_disk, qry, k=4, policy=pol, executor=ex)
    res = knn(fit_disk, qry, k=4, policy=pol, executor=ex)
    assert bool(jnp.all(res.indices == ref.indices)), "stream-disk knn ids diverged"
    assert bool(jnp.all(res.distances == ref.distances))
    check_stream_bounds(
        store, prefetch_hits=res.report.prefetch_hits,
        bytes_loaded=res.report.bytes_loaded, context="knn stream-disk",
    )
    row = report_row(pol, "stream-disk", res.report,
                     prep_bytes=cold.report.bytes_moved)
    ex.close()
    store.close()
    return row


def bench(quick: bool = True) -> list[Table]:
    rng = np.random.default_rng(0)
    d, k = 3, 8
    repeats = 3 if quick else 10
    base_rows = 4_096 if quick else 32_768

    # -- Fig 18/19 + Table 1: microkernels vs structure size ------------------
    t18 = Table("knn_kernels", "paper Figs. 18/19 + Table 1")
    q = jnp.asarray(rng.random((1_024, d)).astype(np.float32))
    jit_lookup = jax.jit(lambda f, ids, qq: _lookup(f, ids, qq, k))
    for size in (base_rows // 4, base_rows // 2, base_rows, base_rows * 2):
        pts = jnp.asarray(rng.random((size, d)).astype(np.float32))
        ids = jnp.arange(size, dtype=jnp.int32)
        # "fit" = structure build (consolidated candidate matrix)
        fit_stats = winsorized(
            timeit(lambda: jax.block_until_ready(jnp.concatenate([pts], 0)),
                   repeats=repeats)
        )
        lk_stats = winsorized(
            timeit(lambda: jax.block_until_ready(jit_lookup(pts, ids, q)),
                   repeats=repeats)
        )
        t18.add(structure_rows=size, fit_s=fit_stats["median_s"],
                lookup_s=lk_stats["median_s"],
                lookup_s_per_krow=lk_stats["median_s"] / (size / 1e3))

    # -- Fig 20: scalability ---------------------------------------------------
    t20 = Table("knn_scalability", "paper Fig. 20")
    for locs in (1, 2, 4, 8):
        fit = _blocked(rng.random((locs * 6 * 512, d)).astype(np.float32), 512, locs)
        qry = _blocked(rng.random((locs * 4 * 256, d)).astype(np.float32), 256, locs)
        for pol in POLICIES:
            ex = engine("local")   # persistent: amortized prepare + live tuner
            box = {}

            def once():
                box["res"] = knn(fit, qry, k=k, policy=pol, executor=ex)
                box.setdefault("prep_bytes", box["res"].report.bytes_moved)
                return box["res"].indices

            stats = winsorized(timeit(once, repeats=repeats))
            rep = box["res"].report
            t20.add(locations=locs, mode=pol.mode_name, fit_blocks=fit.num_blocks,
                    structures=rep.dispatches - rep.merges,  # approx
                    dispatches=rep.dispatches, merges=rep.merges,
                    bytes_moved=box["prep_bytes"], **stats)

    # -- Fig 21: fit-dataset scaling (blocks per second) -----------------------
    t21 = Table("knn_fit_scaling", "paper Fig. 21")
    locs = 4
    qry = _blocked(rng.random((locs * 2 * 256, d)).astype(np.float32), 256, locs)
    for bpl in (2, 4, 8, 12):
        fit = _blocked(
            rng.random((locs * bpl * 512, d)).astype(np.float32), 512, locs
        )
        for pol in POLICIES:
            ex = engine("local")   # persistent: amortized prepare + live tuner
            box = {}

            def once():
                box["res"] = knn(fit, qry, k=k, policy=pol, executor=ex)
                return box["res"].indices

            stats = winsorized(timeit(once, repeats=repeats))
            rep = box["res"].report
            t21.add(fit_blocks_per_loc=bpl, mode=pol.mode_name, fit_blocks=fit.num_blocks,
                    blocks_per_s=fit.num_blocks / stats["median_s"],
                    dispatches=rep.dispatches, **stats)

    return [t18, t20, t21]
