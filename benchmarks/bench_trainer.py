"""Framework-level benchmarks: SplIter at the trainer (L2) and dispatch
overhead microbenchmark (the "scheduler stress" cost the paper attacks).

``trainer_accum_modes`` — identical training math under the paper's three
execution strategies: per_block (baseline, N dispatches/step), spliter
(1 dispatch/step, scan), materialized (1 dispatch, fused batch, max
memory).  Mirrors the paper's baseline/SplIter/rechunk triangle at the
gradient-accumulation level.

``dispatch_overhead`` — cost of one executable invocation vs payload size:
quantifies why granularity coupling hurts (paper §1: "the runtime
invocation overhead increases").
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import _preset
from repro.runtime.trainer import TrainConfig, Trainer

from benchmarks.harness import Table, timeit, winsorized


def trainer_accum_modes(quick: bool = True) -> Table:
    t = Table("trainer_accum_modes", "paper Listing 4/5 at trainer level")
    steps = 8 if quick else 30
    for num_blocks in (4, 16):
        for mode in ("per_block", "spliter", "materialized"):
            cfg = TrainConfig(
                global_batch=16, num_blocks=num_blocks, seq_len=64,
                steps=steps, accum_mode=mode, warmup_steps=2,
            )
            tr = Trainer(_preset("lm1m"), cfg)
            out = tr.run(resume=False)
            t.add(num_blocks=num_blocks, mode=mode,
                  dispatches=out["dispatches"],
                  dispatches_per_step=out["dispatches"] / steps,
                  wall_s=round(out["wall_s"], 3),
                  ms_per_step=round(out["wall_s"] / steps * 1e3, 1),
                  final_loss=round(out["losses"][-1], 4))
    return t


def dispatch_overhead(quick: bool = True) -> Table:
    t = Table("dispatch_overhead", "runtime invocation cost (paper §1)")
    repeats = 20 if quick else 100

    for rows in (256, 4_096, 65_536):
        x = jnp.asarray(np.random.default_rng(0).random((rows, 32), np.float32))
        f = jax.jit(lambda a: jnp.sum(a * a, axis=1))
        f(x).block_until_ready()  # compile

        # one dispatch of the full payload
        one = winsorized(timeit(lambda: f(x).block_until_ready(),
                                repeats=repeats, warmup=2))
        # 16 dispatches of 1/16 payloads (fragmented)
        xs = [x[i::16] for i in range(16)]
        f(xs[0]).block_until_ready()

        def frag():
            outs = [f(s) for s in xs]
            jax.block_until_ready(outs)

        many = winsorized(timeit(frag, repeats=repeats, warmup=2))
        t.add(rows=rows,
              one_dispatch_ms=one["median_s"] * 1e3,
              sixteen_dispatch_ms=many["median_s"] * 1e3,
              overhead_ratio=round(many["median_s"] / max(one["median_s"], 1e-9), 2))
    return t


def smoke() -> list[dict]:
    """Tiny accum-mode sweep for the CI smoke job (BENCH_trainer).

    The trainer has no executor axis; ``policy`` carries the accumulation
    mode (the trainer-level baseline/SplIter/materialized triangle).
    """
    rows = []
    for mode in ("per_block", "spliter", "materialized"):
        cfg = TrainConfig(
            global_batch=8, num_blocks=4, seq_len=32,
            steps=2, accum_mode=mode, warmup_steps=1,
        )
        out = Trainer(_preset("lm1m"), cfg).run(resume=False)
        rows.append({
            "policy": mode,
            "executor": "trainer",
            "wall_s": round(out["wall_s"], 5),
            "dispatches": out["dispatches"],
            "merges": 0,
            "traces": 0,
            "bytes_moved": 0,
            "prep_bytes": 0,
            "remote_dispatches": 0,
            "shm_bytes": 0,
            "p2p_bytes": 0,
            "driver_merge_bytes": 0,
            "retries": 0,
            "jobs": 0,
            "resumes": 0,
            "overlapped_launches": 0,
            "steals": 0,
            "scale_events": 0,
        })
    rows.extend(_pipelined_sgd_rows())
    return rows


# -- pipelined training step (DESIGN.md §14) ---------------------------------
#
# The Trainer's inner loop is pure jitted JAX, so the pipelined-iteration
# axis is exercised at the level the paper targets: an executor-driven
# gradient-accumulation loop where each optimizer step is one execute —
# map_blocks computes per-microbatch (loss·n, grad·n, n) partials, reduce
# folds them, and the SGD update rides on the merged value.  Pipelined,
# the next step's execute is submitted before the current one finishes
# (params carried as a Deferred), which is exactly the
# parameter-broadcast-gated overlap a distributed trainer needs.


_SGD_LR = 0.05


def _sgd_block(b, w):
    """Per-microbatch partials: (loss·n, grad·n, n, w).

    The current params ride along in the partials so the post-merge update
    is a *pure function of the merged value* — exactly what ``fut.map``
    needs to chain steps without re-entering the executor.
    """
    y = b.sum(axis=1)  # deterministic target: recoverable by w = ones
    err = b @ w - y
    n = jnp.asarray(float(b.shape[0]))
    return (err @ err, b.T @ err * 2.0, n, w)


def _sgd_combine(a, b):
    return (a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3])


def _sgd_step(partials):
    """SGD update from merged partials: w - lr · Σgrad / Σn."""
    _loss, gsum, n, w = partials
    return w - _SGD_LR * gsum / n


def _pipelined_sgd_rows() -> list[dict]:
    """Depth-2 pipelined SGD steps vs the barriered loop: params bit-equal.

    Structural acceptance mirrors the kmeans pipelined rows: on both the
    Threaded and Cluster backends, final params must match the barriered
    run bit-for-bit (same TaskGraph, same fold order, update applied to
    the same merged partials) and every step past the first must overlap
    with its predecessor.  Both arms are warmed and timed whole-loop;
    ``barriered_wall_s`` rides in the row next to the pipelined
    ``wall_s`` so the per-step barrier cost the pipeline removes is
    visible in the same row (informational, never baseline-diffed).
    """
    from repro.api import Collection, SplIter, engine
    from repro.api.futures import resolve_deferred

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.random((512, 8), np.float32))
    w0 = jnp.zeros((8,), jnp.float32)
    steps = 3
    pol = SplIter(partitions_per_location=2)

    def step_plan(w):
        return (Collection.from_array(x, block_rows=64, num_locations=2)
                .split(pol)
                .map_blocks(_sgd_block, extra_args=(w,))
                .reduce(_sgd_combine))

    def barriered(ex):
        # Barriered reference: compute() per step, update on the host.
        w = w0
        reports = []
        for _ in range(steps):
            res = step_plan(w).compute(executor=ex)
            w = _sgd_step(res.value)
            reports.append(res.report)
        return w, reports

    def pipelined(ex):
        # Pipelined: params flow as a Deferred; executes overlap.
        w_op = w0
        futs = []
        for _ in range(steps):
            fut = step_plan(w_op).compute_async(executor=ex)
            futs.append(fut)
            w_op = fut.map(_sgd_step)
        w = resolve_deferred(w_op)
        return w, [f.result() for f in futs]

    rows = []
    for name, ex in (("threaded", engine("threaded")), ("cluster", engine("cluster"))):
        try:
            barriered(ex)  # warm both arms: traces + prepare paid up front
            pipelined(ex)
            t0 = time.perf_counter()
            w_ref, ref_reports = barriered(ex)
            t_bar = time.perf_counter() - t0
            t0 = time.perf_counter()
            w_pipe, results = pipelined(ex)
            t_pipe = time.perf_counter() - t0
        finally:
            ex.close()

        assert bool(jnp.all(w_pipe == w_ref)), (
            f"pipelined SGD params diverged on {name}"
        )
        overlapped = sum(r.report.overlapped_launches for r in results)
        assert overlapped > 0, f"pipelined SGD steps never overlapped on {name}"
        reports = [r.report for r in results]
        rows.append({
            "policy": "sgd-pipelined",
            "executor": name,
            "wall_s": round(t_pipe, 5),
            "barriered_wall_s": round(t_bar, 5),
            "dispatches": sum(r.dispatches for r in reports),
            "merges": sum(r.merges for r in reports),
            "traces": sum(r.traces for r in reports),
            "bytes_moved": sum(r.bytes_moved for r in reports),
            "prep_bytes": sum(r.bytes_moved for r in ref_reports),
            "remote_dispatches": sum(r.remote_dispatches for r in reports),
            "shm_bytes": sum(r.shm_bytes for r in reports),
            "p2p_bytes": sum(r.p2p_bytes for r in reports),
            "driver_merge_bytes": sum(r.driver_merge_bytes for r in reports),
            "retries": sum(r.retries for r in reports),
            "jobs": 0,
            "resumes": 0,
            "overlapped_launches": overlapped,
            "steals": sum(r.steals for r in reports),
            "scale_events": sum(r.scale_events for r in reports),
        })
    return rows


def bench(quick: bool = True) -> list[Table]:
    return [trainer_accum_modes(quick), dispatch_overhead(quick)]
