"""Framework-level benchmarks: SplIter at the trainer (L2) and dispatch
overhead microbenchmark (the "scheduler stress" cost the paper attacks).

``trainer_accum_modes`` — identical training math under the paper's three
execution strategies: per_block (baseline, N dispatches/step), spliter
(1 dispatch/step, scan), materialized (1 dispatch, fused batch, max
memory).  Mirrors the paper's baseline/SplIter/rechunk triangle at the
gradient-accumulation level.

``dispatch_overhead`` — cost of one executable invocation vs payload size:
quantifies why granularity coupling hurts (paper §1: "the runtime
invocation overhead increases").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import _preset
from repro.runtime.trainer import TrainConfig, Trainer

from benchmarks.harness import Table, timeit, winsorized


def trainer_accum_modes(quick: bool = True) -> Table:
    t = Table("trainer_accum_modes", "paper Listing 4/5 at trainer level")
    steps = 8 if quick else 30
    for num_blocks in (4, 16):
        for mode in ("per_block", "spliter", "materialized"):
            cfg = TrainConfig(
                global_batch=16, num_blocks=num_blocks, seq_len=64,
                steps=steps, accum_mode=mode, warmup_steps=2,
            )
            tr = Trainer(_preset("lm1m"), cfg)
            out = tr.run(resume=False)
            t.add(num_blocks=num_blocks, mode=mode,
                  dispatches=out["dispatches"],
                  dispatches_per_step=out["dispatches"] / steps,
                  wall_s=round(out["wall_s"], 3),
                  ms_per_step=round(out["wall_s"] / steps * 1e3, 1),
                  final_loss=round(out["losses"][-1], 4))
    return t


def dispatch_overhead(quick: bool = True) -> Table:
    t = Table("dispatch_overhead", "runtime invocation cost (paper §1)")
    repeats = 20 if quick else 100

    for rows in (256, 4_096, 65_536):
        x = jnp.asarray(np.random.default_rng(0).random((rows, 32), np.float32))
        f = jax.jit(lambda a: jnp.sum(a * a, axis=1))
        f(x).block_until_ready()  # compile

        # one dispatch of the full payload
        one = winsorized(timeit(lambda: f(x).block_until_ready(),
                                repeats=repeats, warmup=2))
        # 16 dispatches of 1/16 payloads (fragmented)
        xs = [x[i::16] for i in range(16)]
        f(xs[0]).block_until_ready()

        def frag():
            outs = [f(s) for s in xs]
            jax.block_until_ready(outs)

        many = winsorized(timeit(frag, repeats=repeats, warmup=2))
        t.add(rows=rows,
              one_dispatch_ms=one["median_s"] * 1e3,
              sixteen_dispatch_ms=many["median_s"] * 1e3,
              overhead_ratio=round(many["median_s"] / max(one["median_s"], 1e-9), 2))
    return t


def smoke() -> list[dict]:
    """Tiny accum-mode sweep for the CI smoke job (BENCH_trainer).

    The trainer has no executor axis; ``policy`` carries the accumulation
    mode (the trainer-level baseline/SplIter/materialized triangle).
    """
    rows = []
    for mode in ("per_block", "spliter", "materialized"):
        cfg = TrainConfig(
            global_batch=8, num_blocks=4, seq_len=32,
            steps=2, accum_mode=mode, warmup_steps=1,
        )
        out = Trainer(_preset("lm1m"), cfg).run(resume=False)
        rows.append({
            "policy": mode,
            "executor": "trainer",
            "wall_s": round(out["wall_s"], 5),
            "dispatches": out["dispatches"],
            "merges": 0,
            "traces": 0,
            "bytes_moved": 0,
            "prep_bytes": 0,
            "remote_dispatches": 0,
            "shm_bytes": 0,
            "retries": 0,
        })
    return rows


def bench(quick: bool = True) -> list[Table]:
    return [trainer_accum_modes(quick), dispatch_overhead(quick)]
