"""Hypothesis property tests for the SplIter invariants (DESIGN.md §7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api import Collection, ThreadedExecutor, as_policy
from repro.core import (
    BlockedArray,
    contiguous_placement,
    rechunk,
    round_robin_placement,
    spliter,
)


def _map_reduce(ba, block_fn, combine, *, mode, partitions_per_location=1,
                executor=None):
    res = (
        Collection.from_blocked(ba)
        .split(as_policy(mode, partitions_per_location=partitions_per_location))
        .map_blocks(block_fn)
        .reduce(combine)
        .compute(executor=executor)
    )
    return res.value, res.report

POLICIES = [round_robin_placement, contiguous_placement]


@st.composite
def blocked_arrays(draw, max_rows=200):
    n = draw(st.integers(1, max_rows))
    d = draw(st.integers(1, 4))
    block_rows = draw(st.integers(1, max(1, n)))
    locs = draw(st.integers(1, 8))
    policy = draw(st.sampled_from(POLICIES))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return BlockedArray.from_array(x, block_rows, num_locations=locs, policy=policy)


@given(blocked_arrays(), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_partitions_disjoint_cover(ba, ppl):
    """(i) partitions form a disjoint cover of the block set."""
    parts = spliter(ba, partitions_per_location=ppl)
    seen = sorted(b for p in parts for b in p.block_ids)
    assert seen == list(range(ba.num_blocks))


@given(blocked_arrays(), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_partitions_single_location(ba, ppl):
    """(ii) every partition is single-placement (locality)."""
    for p in spliter(ba, partitions_per_location=ppl):
        assert all(ba.placements[b] == p.location for b in p.block_ids)


@given(blocked_arrays(), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_item_indexes_form_permutation(ba, ppl):
    """(iii) union of get_item_indexes is a permutation of arange(n)."""
    parts = spliter(ba, partitions_per_location=ppl)
    allidx = np.concatenate([p.get_item_indexes() for p in parts])
    assert sorted(allidx.tolist()) == list(range(ba.num_rows))


@given(blocked_arrays())
@settings(max_examples=30, deadline=None)
def test_materialize_matches_global_gather(ba):
    """materialize() == gathering the rows named by get_item_indexes."""
    full = np.asarray(ba.collect())
    for p in spliter(ba):
        np.testing.assert_array_equal(
            np.asarray(p.materialize()), full[p.get_item_indexes()]
        )


@given(blocked_arrays(), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_rechunk_preserves_data(ba, new_rows):
    """(v) rechunk at any block size preserves the concatenated dataset."""
    nb, st_ = rechunk(ba, new_rows)
    np.testing.assert_array_equal(np.asarray(nb.collect()), np.asarray(ba.collect()))
    assert nb.num_rows == ba.num_rows


@given(blocked_arrays())
@settings(max_examples=15, deadline=None)
def test_modes_agree_on_reduction(ba):
    """(iv) baseline / spliter / spliter_mat / rechunk agree numerically.

    Reduction: per-block (sum, sumsq, count) — associative monoid, so any
    grouping must agree up to float reassociation.
    """

    def block_fn(b):
        return jnp.sum(b, 0), jnp.sum(b * b, 0), jnp.asarray(b.shape[0], jnp.float32)

    def combine(a, b):
        return a[0] + b[0], a[1] + b[1], a[2] + b[2]

    results = {}
    for mode in ["baseline", "spliter", "spliter_mat", "rechunk"]:
        r, rep = _map_reduce(ba, block_fn, combine, mode=mode)
        results[mode] = jax.tree.map(np.asarray, r)
        assert rep.bytes_moved == 0 or mode == "rechunk"
        # ThreadedExecutor must be bit-identical to LocalExecutor.
        rt, _ = _map_reduce(ba, block_fn, combine, mode=mode,
                            executor=ThreadedExecutor())
        for a, b in zip(jax.tree.map(np.asarray, rt), results[mode]):
            np.testing.assert_array_equal(a, b)
    base = results["baseline"]
    for mode, r in results.items():
        for a, b in zip(r, base):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4), mode


@given(blocked_arrays(), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_spliter_dispatch_bound(ba, ppl):
    """#dispatches(spliter) ≤ #partitions + 1 merge — never scales with blocks."""

    def block_fn(b):
        return jnp.sum(b, 0)

    parts = spliter(ba, partitions_per_location=ppl)
    _, rep = _map_reduce(
        ba, block_fn, lambda a, b: a + b, mode="spliter",
        partitions_per_location=ppl,
    )
    # ≤ one extra dispatch per partition for a ragged tail's shape run.
    bound = len(parts) + 1 if ba.uniform else 2 * len(parts) + 1
    assert rep.dispatches <= bound
