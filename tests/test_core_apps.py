"""The paper's four applications: mode equivalence + dispatch accounting."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Baseline, LocalExecutor, MeshExecutor, Rechunk, SplIter, ThreadedExecutor
from repro.core import BlockedArray, round_robin_placement
from repro.core.apps import cascade_svm, histogram, kmeans, knn

POLICIES = (Baseline(), SplIter(), SplIter(materialize=True), Rechunk())


@pytest.fixture(scope="module")
def points():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(0, 1, (512, 3)).astype(np.float32))
    return x, BlockedArray.from_array(
        x, 32, num_locations=4, policy=round_robin_placement
    )


class TestHistogram:
    def test_all_modes_exact_match(self, points):
        x, ba = points
        ref = None
        for pol in POLICIES:
            h, rep = histogram(ba, bins=4, policy=pol)
            assert int(h.sum()) == 512
            if ref is None:
                ref = np.asarray(h)
            np.testing.assert_array_equal(np.asarray(h), ref)

    def test_matches_numpy_histogramdd(self, points):
        x, ba = points
        h, _ = histogram(ba, bins=4, lo=0.0, hi=1.0, policy=SplIter())
        expected, _ = np.histogramdd(
            np.asarray(x), bins=4, range=[(0, 1)] * 3
        )
        np.testing.assert_array_equal(np.asarray(h), expected.astype(np.int32))

    def test_dispatch_counts(self, points):
        _, ba = points
        _, rb = histogram(ba, policy=Baseline())
        _, rs = histogram(ba, policy=SplIter())
        assert rb.dispatches == ba.num_blocks + 1       # per block + merge
        assert rs.dispatches == ba.num_locations + 1    # per partition + merge
        assert rs.bytes_moved == 0

    def test_rechunk_moves_bytes_under_round_robin(self, points):
        _, ba = points
        _, rr = histogram(ba, policy=Rechunk())
        assert rr.bytes_moved > 0


class TestKMeans:
    def test_modes_converge_identically(self, points):
        _, ba = points
        res = {p: kmeans(ba, k=4, iters=5, policy=p) for p in POLICIES}
        base = np.asarray(res[Baseline()].centers)
        for p in POLICIES:
            np.testing.assert_allclose(
                np.asarray(res[p].centers), base, rtol=2e-4, atol=2e-5
            )
        # ThreadedExecutor is bit-identical to LocalExecutor on the same policy
        thr = kmeans(ba, k=4, iters=5, policy=SplIter(), executor=ThreadedExecutor())
        np.testing.assert_array_equal(
            np.asarray(thr.centers), np.asarray(res[SplIter()].centers)
        )

    def test_iterative_dispatch_amortization(self, points):
        """Task definitions are traced once; dispatches scale with iterations
        for the baseline but stay at #partitions for SplIter."""
        _, ba = points
        rb = kmeans(ba, k=4, iters=5, policy=Baseline())
        rs = kmeans(ba, k=4, iters=5, policy=SplIter())
        assert rb.total_dispatches == 5 * (ba.num_blocks + 1)
        assert rs.total_dispatches == 5 * (ba.num_locations + 1)
        # one trace of the block task + one of the merge across ALL iters
        assert sum(r.traces for r in rs.reports) <= 2

    def test_centers_reduce_inertia(self, points):
        x, ba = points
        r = kmeans(ba, k=8, iters=10, policy=SplIter())
        xs = np.asarray(x)
        d2 = ((xs[:, None, :] - np.asarray(r.centers)[None]) ** 2).sum(-1)
        inertia = d2.min(1).mean()
        rng = np.random.default_rng(0)
        rand = xs[rng.choice(len(xs), 8, replace=False)]
        d2r = ((xs[:, None, :] - rand[None]) ** 2).sum(-1)
        assert inertia < d2r.min(1).mean()


class TestCascadeSVM:
    @pytest.fixture(scope="class")
    def labeled(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(256, 4)).astype(np.float32)
        w = rng.normal(size=(4,)).astype(np.float32)
        y = np.sign(x @ w + 0.1).astype(np.float32)
        xb = BlockedArray.from_array(
            jnp.asarray(x), 32, num_locations=4, policy=round_robin_placement
        )
        yb = BlockedArray.from_array(
            jnp.asarray(y), 32, num_locations=4, policy=round_robin_placement
        )
        return x, y, xb, yb

    def test_classifies_train_data(self, labeled):
        x, y, xb, yb = labeled
        r = cascade_svm(
            xb, yb, num_sv=128, steps=300, iterations=2, policy=SplIter(), c=10.0
        )
        pred = np.sign(np.asarray(r.decision(jnp.asarray(x))))
        acc = (pred == y).mean()
        assert acc > 0.85, acc

    def test_label_alignment_via_get_indexes(self, labeled):
        """Shuffled-placement labels stay aligned with their points."""
        x, y, xb, yb = labeled
        for pol in (Baseline(), SplIter(), Rechunk()):
            r = cascade_svm(xb, yb, num_sv=16, steps=100, iterations=1, policy=pol)
            # every reported SV must be an actual (x, y) pair from the data
            svx, svy = np.asarray(r.sv_x), np.asarray(r.sv_y)
            for i in range(len(svx)):
                row = np.nonzero((x == svx[i]).all(1))[0]
                assert len(row) >= 1
                assert y[row[0]] == svy[i]

    def test_spliter_fewer_dispatches(self, labeled):
        _, _, xb, yb = labeled
        rb = cascade_svm(xb, yb, num_sv=16, steps=50, iterations=1, policy=Baseline())
        rs = cascade_svm(xb, yb, num_sv=16, steps=50, iterations=1, policy=SplIter())
        assert rs.report.dispatches < rb.report.dispatches


class TestKNN:
    @pytest.fixture(scope="class")
    def data(self):
        rng = np.random.default_rng(11)
        fit = rng.normal(size=(300, 3)).astype(np.float32)
        q = rng.normal(size=(64, 3)).astype(np.float32)
        fb = BlockedArray.from_array(
            jnp.asarray(fit), 25, num_locations=4, policy=round_robin_placement
        )
        qb = BlockedArray.from_array(jnp.asarray(q), 16, num_locations=4)
        return fit, q, fb, qb

    def test_matches_bruteforce_numpy(self, data):
        fit, q, fb, qb = data
        r = knn(fb, qb, k=5, policy=SplIter())
        d2 = ((q[:, None, :] - fit[None]) ** 2).sum(-1)
        expected = np.argsort(d2, axis=1)[:, :5]
        got = np.asarray(r.indices)
        # compare as sets per row (ties may reorder)
        for i in range(len(q)):
            assert set(got[i]) == set(expected[i]), i
        np.testing.assert_allclose(
            np.asarray(r.distances), np.sort(d2, 1)[:, :5], rtol=1e-4, atol=1e-4
        )

    def test_global_item_indexes(self, data):
        """Returned ids are GLOBAL fit rows — the get_item_indexes contract."""
        fit, q, fb, qb = data
        for pol in POLICIES:
            r = knn(fb, qb, k=3, policy=pol)
            ids = np.asarray(r.indices)
            assert ids.min() >= 0 and ids.max() < len(fit)
            d = np.asarray(r.distances)
            # distance of the reported id must equal the reported distance
            for qi in range(0, len(q), 16):
                for j in range(3):
                    true = ((q[qi] - fit[ids[qi, j]]) ** 2).sum()
                    np.testing.assert_allclose(d[qi, j], true, rtol=1e-4, atol=1e-4)

    def test_consolidation_shrinks_tasks_and_merges(self, data):
        _, _, fb, qb = data
        rb = knn(fb, qb, k=5, policy=Baseline()).report
        rs = knn(fb, qb, k=5, policy=SplIter()).report
        # paper Table 1 / Fig 21: tasks = #structures x #query blocks
        assert rs.dispatches < rb.dispatches
        assert rs.merges < rb.merges


class TestPallasFusionApps:
    """Acceptance: histogram and k-means end-to-end through
    SplIter(fusion="pallas") on LocalExecutor AND MeshExecutor, equal to
    Baseline within float32 reassociation, dispatches within the C1 bound."""

    def test_histogram_pallas_local_and_mesh(self, points):
        _, ba = points
        ref, _ = histogram(ba, bins=4, policy=Baseline())
        for ex in (LocalExecutor(), ThreadedExecutor(), MeshExecutor()):
            h, rep = histogram(
                ba, bins=4, policy=SplIter(fusion="pallas"), executor=ex
            )
            np.testing.assert_array_equal(
                np.asarray(h), np.asarray(ref), err_msg=type(ex).__name__
            )
            assert rep.dispatches <= ba.num_locations + 1  # C1
            assert rep.bytes_moved == 0                    # 1 host device

    def test_kmeans_pallas_local_and_mesh(self, points):
        _, ba = points
        base = kmeans(ba, k=4, iters=5, policy=Baseline())
        for ex in (LocalExecutor(), MeshExecutor()):
            r = kmeans(
                ba, k=4, iters=5, policy=SplIter(fusion="pallas"), executor=ex
            )
            np.testing.assert_allclose(
                np.asarray(r.centers), np.asarray(base.centers),
                rtol=2e-4, atol=2e-4, err_msg=type(ex).__name__,
            )
            assert r.total_dispatches <= 5 * (ba.num_locations + 1)  # C1

    def test_knn_and_svm_run_on_mesh_executor(self):
        """Apps built on scope()/task()/map_partitions use the fallback
        scheduling path — every plan the other backends accept runs here."""
        rng = np.random.default_rng(2)
        fit = rng.normal(size=(120, 3)).astype(np.float32)
        q = rng.normal(size=(32, 3)).astype(np.float32)
        fb = BlockedArray.from_array(
            jnp.asarray(fit), 16, num_locations=4, policy=round_robin_placement
        )
        qb = BlockedArray.from_array(jnp.asarray(q), 16, num_locations=4)
        r_mesh = knn(fb, qb, k=3, policy=SplIter(), executor=MeshExecutor())
        r_loc = knn(fb, qb, k=3, policy=SplIter(), executor=LocalExecutor())
        np.testing.assert_array_equal(
            np.asarray(r_mesh.indices), np.asarray(r_loc.indices)
        )
