"""Optimizer substrate: AdamW math, schedules, accumulation-mode equivalence,
gradient compression + error feedback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    accumulate_gradients,
    adamw_init,
    adamw_update,
    cosine_schedule,
)
from repro.optim.compression import (
    ErrorFeedback,
    compress_with_feedback,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)


def _quad_loss(params, batch):
    # simple convex objective: || w·x - y ||²
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _problem(seed=0, n=64, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d,)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.standard_normal(n).astype(np.float32)
    params = {"w": jnp.zeros((d,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    return params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_adamw_converges_on_quadratic():
    params, batch = _problem()
    opt = adamw_init(params)
    for _ in range(300):
        loss, g = jax.value_and_grad(_quad_loss)(params, batch)
        params, opt = adamw_update(params, g, opt, lr=3e-2, weight_decay=0.0)
    assert float(_quad_loss(params, batch)) < 1e-2


def test_adamw_weight_decay_shrinks_weights():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = adamw_init(params)
    zero_g = {"w": jnp.zeros((4,), jnp.float32)}
    p2, _ = adamw_update(params, zero_g, opt, lr=1e-1, weight_decay=0.5)
    assert float(jnp.max(p2["w"])) < 1.0  # decoupled decay applied


def test_cosine_schedule_shape():
    peak, warm, total = 1e-3, 10, 100
    lrs = [float(cosine_schedule(s, peak_lr=peak, warmup_steps=warm,
                                 total_steps=total)) for s in range(total)]
    assert lrs[0] < lrs[9] <= peak * 1.0001
    assert abs(lrs[10] - peak) < 1e-9 or lrs[9] <= peak
    assert lrs[-1] < 0.11 * peak  # decayed to ~10% floor or below
    assert all(l >= 0 for l in lrs)


def test_accumulation_modes_equivalent():
    """spliter scan vs materialized fused batch: same loss/grads (C-invariant
    at L2, the trainer analogue of the engine modes)."""
    params, batch = _problem(n=64)
    blocks = {k: v.reshape((4, 16) + v.shape[1:]) for k, v in batch.items()}
    l1, g1 = accumulate_gradients(_quad_loss, params, blocks, mode="spliter")
    l2, g2 = accumulate_gradients(_quad_loss, params, blocks, mode="materialized")
    # materialized computes the mean over the fused batch; spliter averages
    # per-block means — equal here because blocks are equal-sized
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32))
    q, s = int8_compress(x)
    back = int8_decompress(q, s)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(s) / 2 * 1.01 + 1e-7
    assert (err <= bound).all()


def test_topk_roundtrip():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64,)).astype(np.float32))
    v, i = topk_compress(x, 8)
    back = topk_decompress(v, i, (64,))
    nz = np.nonzero(np.asarray(back))[0]
    assert len(nz) == 8
    top8 = np.argsort(-np.abs(np.asarray(x)))[:8]
    assert set(nz) == set(top8)


def test_error_feedback_preserves_sum():
    """EF: Σ_t decompressed_t == Σ_t grad_t + residual_T (unbiased over time)."""
    rng = np.random.default_rng(3)
    grads = [{"w": jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))}
             for _ in range(20)]
    ef = ErrorFeedback.init(grads[0])
    sent_sum = np.zeros((4, 32), np.float32)
    true_sum = np.zeros((4, 32), np.float32)
    for g in grads:
        sent, ef = compress_with_feedback(g, ef)
        sent_sum += np.asarray(sent["w"])
        true_sum += np.asarray(g["w"])
    drift = np.abs(sent_sum + np.asarray(ef.residual["w"]) - true_sum)
    assert drift.max() < 1e-3  # exact up to fp accumulation


def test_error_feedback_training_converges():
    """SGD with int8+EF gradients still converges on the quadratic."""
    params, batch = _problem(seed=4)
    opt = adamw_init(params)
    ef = None
    for _ in range(300):
        _, g = jax.value_and_grad(_quad_loss)(params, batch)
        if ef is None:
            ef = ErrorFeedback.init(g)
        g, ef = compress_with_feedback(g, ef)
        params, opt = adamw_update(params, g, opt, lr=3e-2, weight_decay=0.0)
    assert float(_quad_loss(params, batch)) < 2e-2


def test_hoist_params_matches_baseline():
    """bf16 gather-hoisted accumulation ≈ baseline (mixed-precision cast)."""
    params, batch = _problem(seed=5, n=32)
    blocks = {k: v.reshape((2, 16) + v.shape[1:]) for k, v in batch.items()}
    l0, g0 = accumulate_gradients(_quad_loss, params, blocks, mode="spliter")
    l1, g1 = accumulate_gradients(
        _quad_loss, params, blocks, mode="spliter", hoist=True
    )
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-2, atol=3e-2)


def test_unrolled_accumulation_equals_scan():
    params, batch = _problem(seed=6, n=48)
    blocks = {k: v.reshape((3, 16) + v.shape[1:]) for k, v in batch.items()}
    l0, g0 = accumulate_gradients(_quad_loss, params, blocks, mode="spliter")
    l1, g1 = accumulate_gradients(
        _quad_loss, params, blocks, mode="spliter_unrolled"
    )
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
