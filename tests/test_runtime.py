"""Runtime layer: trainer loss descent, preemption→resume bit-exactness,
checkpoint retention/atomicity, pipeline determinism, server decode, FT
machinery (heartbeats, stragglers)."""

import os

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data.pipeline import BlockedBatchPipeline
from repro.launch.train import _preset
from repro.runtime.ft import HeartbeatMonitor, PreemptionGuard, StragglerDetector
from repro.runtime.server import Server
from repro.runtime.trainer import TrainConfig, Trainer


def _cfg(**kw) -> TrainConfig:
    base = dict(global_batch=8, num_blocks=2, seq_len=32, steps=10,
                peak_lr=1e-3, warmup_steps=2)
    base.update(kw)
    return TrainConfig(**base)


def test_trainer_loss_decreases():
    tr = Trainer(_preset("lm1m"), _cfg(steps=20))
    out = tr.run(resume=False)
    first = np.mean(out["losses"][:4])
    last = np.mean(out["losses"][-4:])
    assert last < first, (first, last)
    assert out["dispatches"] == 20  # spliter: ONE dispatch per step


def test_preemption_resume_bit_identical(tmp_path):
    """Uninterrupted run == (run-to-preemption; restart; finish), exactly."""
    mc = _preset("lm1m")

    full = Trainer(mc, _cfg(steps=12)).run(resume=False)

    ck = str(tmp_path / "ck")
    t1 = Trainer(mc, _cfg(steps=12, ckpt_dir=ck))
    guard = PreemptionGuard(install=False)

    def stop_at_6(step, loss):
        if step == 5:
            guard.request_stop()

    out1 = t1.run(guard=guard, on_step=stop_at_6)
    assert out1["preempted"] and out1["stopped_at"] == 6

    t2 = Trainer(mc, _cfg(steps=12, ckpt_dir=ck))
    out2 = t2.run(resume=True)
    assert out2["stopped_at"] == 12

    # bit-identical parameters and identical loss tail
    for a, b in zip(jax.tree.leaves(full["params"]), jax.tree.leaves(out2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(full["losses"][6:], out2["losses"])


def test_checkpointer_atomic_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jax.numpy.arange(8.0)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, extras={"s": s}, blocking=True)
    ck.keep_last(2)
    assert ck.latest_step() == 4
    steps = sorted(int(f[5:-10]) for f in os.listdir(tmp_path)
                   if f.endswith(".COMMITTED"))
    assert steps == [3, 4]
    # uncommitted directory (simulated crash) is ignored
    os.makedirs(tmp_path / "step_000000099")
    assert ck.latest_step() == 4


def test_pipeline_deterministic_and_resumable():
    kw = dict(vocab_size=128, seq_len=16, global_batch=8, num_blocks=2, seed=3)
    p1 = BlockedBatchPipeline(**kw)
    it = iter(p1)
    batches = [next(it) for _ in range(5)]
    p1.close()

    # peek() reproduces any step without state
    np.testing.assert_array_equal(batches[3]["tokens"], p1.peek(3)["tokens"])

    # resume from step 3 replays exactly
    p2 = BlockedBatchPipeline(**kw)
    p2.state.step = 3
    it2 = iter(p2)
    np.testing.assert_array_equal(next(it2)["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(next(it2)["labels"], batches[4]["labels"])
    p2.close()

    # labels are next-token shifted
    b = batches[0]
    np.testing.assert_array_equal(b["tokens"][:, :, 1:], b["labels"][:, :, :-1])


def test_pipeline_reiteration_does_not_leak_threads():
    """Re-iterating must stop the previous prefetch worker (one live thread),
    keep yielding from the current cursor, and close() must be idempotent."""
    import threading

    base = threading.active_count()
    p = BlockedBatchPipeline(
        vocab_size=128, seq_len=16, global_batch=8, num_blocks=2, seed=3
    )
    first = next(iter(p))
    for _ in range(3):  # each re-entry must retire the previous worker
        restarted = next(iter(p))
    assert threading.active_count() <= base + 1
    # cursor advanced one step per consumed batch; replay confirms identity
    np.testing.assert_array_equal(first["tokens"], p.peek(0)["tokens"])
    p.close()
    p.close()  # idempotent
    assert threading.active_count() == base


def test_server_greedy_decode_extends_prefill():
    """Server generation == one-shot forward argmax at every position."""
    from repro.models import build_model
    import dataclasses as dc
    import jax.numpy as jnp

    mc = dc.replace(_preset("lm1m"), dtype="float32")
    model = build_model(mc)
    params = model.init(jax.random.key(0))
    srv = Server(mc, max_len=48)
    srv.load(params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mc.vocab_size, (2, 8), dtype=np.int32)
    toks, stats = srv.generate(prompts, steps=8, greedy=True)
    assert toks.shape == (2, 8)
    assert stats.dispatches == 9  # 1 prefill + 8 fused decode steps

    # reference: full-forward argmax, teacher-forced with the served tokens
    # (so a genuine logit tie cannot cascade); any mismatch must be a tie.
    cur = jnp.asarray(prompts, jnp.int32)
    for t in range(8):
        logits = np.asarray(model.forward(params, {"tokens": cur}, remat=False))[:, -1]
        ref = logits.argmax(-1)
        for b in range(toks.shape[0]):
            if ref[b] != toks[b, t]:  # near-tie: cached path may pick the other
                assert abs(logits[b, ref[b]] - logits[b, toks[b, t]]) < 1e-3, (
                    t, b, logits[b, ref[b]], logits[b, toks[b, t]]
                )
        cur = jnp.concatenate(
            [cur, jnp.asarray(toks[:, t : t + 1], jnp.int32)], 1
        )


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(["w0", "w1"], timeout=10.0)
    hb.beat("w0", now=100.0)
    hb.beat("w1", now=100.0)
    assert hb.dead_workers(now=105.0) == []
    hb.beat("w0", now=115.0)
    assert hb.dead_workers(now=115.0) == ["w1"]


def test_straggler_detector_and_resplit_weights():
    sd = StragglerDetector(["w0", "w1", "w2"], threshold=1.5, patience=2)
    v = sd.record_step({"w0": 1.0, "w1": 1.0, "w2": 2.0})
    assert not v.is_straggler  # patience not reached
    v = sd.record_step({"w0": 1.0, "w1": 1.0, "w2": 2.2})
    assert v.is_straggler and v.worker == "w2"
    w = sd.capacity_weights(["w0", "w1", "w2"])
    assert w["w2"] < w["w0"]  # slow worker gets fewer partitions
    assert abs(sum(w.values()) - 3.0) < 1e-6
