"""The plan-based execution layer: policy equivalence, executors, the shim.

Covers DESIGN.md §7.4/§6: every policy agrees on associative reductions up
to fp reassociation — including ragged tails and partitions_per_location>1
— and ThreadedExecutor is bit-identical to LocalExecutor; plus the
deprecated run_map_reduce shim (warns, matches the new API).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    Collection,
    LocalExecutor,
    PlanError,
    Rechunk,
    SplIter,
    ThreadedExecutor,
    as_policy,
)
from repro.core.blocked import BlockedArray, contiguous_placement, round_robin_placement
from repro.core.engine import run_map_reduce

POLICIES = [
    Baseline(),
    SplIter(),
    SplIter(materialize=True),
    SplIter(partitions_per_location=3),
    SplIter(partitions_per_location=3, materialize=True),
    Rechunk(),
    Rechunk(target_rows=17),
]

# (rows, block_rows, locations, placement) — uniform, ragged tail, ragged with
# many locations, single location, more locations than blocks.
DATASETS = [
    (96, 8, 4, round_robin_placement),
    (97, 12, 3, round_robin_placement),      # ragged tail
    (341, 100, 5, contiguous_placement),     # ragged, uneven fill
    (40, 7, 1, contiguous_placement),        # single location, ragged
    (5, 2, 8, round_robin_placement),        # locations > blocks
]


def _blocked(rows, block_rows, locs, placement, d=3, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(rows, d)).astype(np.float32)
    return pts, BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=locs, policy=placement
    )


def _moments_fn(b):
    return jnp.sum(b, 0), jnp.sum(b * b, 0), jnp.asarray(b.shape[0], jnp.float32)


def _moments_combine(a, b):
    return a[0] + b[0], a[1] + b[1], a[2] + b[2]


class TestModeEquivalence:
    @pytest.mark.parametrize("ds", DATASETS, ids=lambda d: f"n{d[0]}b{d[1]}l{d[2]}")
    def test_all_policies_agree(self, ds):
        """C4: any policy grouping agrees up to float reassociation."""
        pts, ba = _blocked(*ds)
        ref = (pts.sum(0), (pts * pts).sum(0), np.float32(len(pts)))
        for pol in POLICIES:
            res = (
                Collection.from_blocked(ba)
                .split(pol)
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute()
            )
            for got, want in zip(res.value, ref):
                np.testing.assert_allclose(
                    np.asarray(got), want, rtol=2e-4, atol=2e-4, err_msg=repr(pol)
                )
            assert res.report.bytes_moved == 0 or isinstance(pol, Rechunk)

    @pytest.mark.parametrize("ds", DATASETS, ids=lambda d: f"n{d[0]}b{d[1]}l{d[2]}")
    @pytest.mark.parametrize("pol", POLICIES, ids=lambda p: repr(p))
    def test_threaded_identical_to_local(self, ds, pol):
        """Local vs Threaded on the SAME policy must be bit-identical."""
        _, ba = _blocked(*ds)
        plan = (
            Collection.from_blocked(ba)
            .split(pol)
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        seq = plan.compute(executor=LocalExecutor())
        thr = plan.compute(executor=ThreadedExecutor())
        for a, b in zip(seq.value, thr.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert thr.report.dispatches == seq.report.dispatches
        assert thr.report.bytes_moved == seq.report.bytes_moved

    def test_spliter_dispatch_bound(self):
        """C1: spliter dispatches ≤ partitions + ragged-tail extras + merge."""
        _, ba = _blocked(97, 12, 3, round_robin_placement)
        for ppl in (1, 2, 4):
            res = (
                Collection.from_blocked(ba)
                .split(SplIter(partitions_per_location=ppl))
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute()
            )
            # ≤ 2 shape-runs per partition (body + tail) + 1 merge.
            assert res.report.dispatches <= 2 * 3 * ppl + 1


class TestExecutorStatefulness:
    def test_rechunk_paid_once_with_persistent_executor(self):
        """C3: the prepare cache bills rechunk traffic exactly once."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        data = Collection.from_blocked(ba).split(Rechunk())
        plan = data.map_blocks(_moments_fn).reduce(_moments_combine)
        first = plan.compute(executor=ex)
        second = plan.compute(executor=ex)
        assert first.report.bytes_moved > 0
        assert second.report.bytes_moved == 0
        assert second.report.dispatches == first.report.dispatches

    def test_traces_attributed_to_paying_report(self):
        """Per-report traces are the delta over the report's window."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        plan = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        r1 = plan.compute(executor=ex).report
        r2 = plan.compute(executor=ex).report
        assert r1.traces == 2          # partition task + merge task
        assert r2.traces == 0          # cache hits only
        assert ex.engine.traces_total == 2

    def test_scope_accumulates_custom_dispatches(self):
        _, ba = _blocked(40, 7, 1, contiguous_placement)
        ex = LocalExecutor()
        with ex.scope("spliter") as report:
            res = (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute(executor=ex)
            )
            assert res.report is report
            t = ex.task(lambda v: v * 2, key="double")
            t(jnp.ones(3))
        assert report.dispatches >= 2
        assert report.wall_s > 0


class TestMapPartitions:
    @pytest.mark.parametrize("pol", [Baseline(), SplIter(), SplIter(2), Rechunk()],
                             ids=lambda p: p.mode_name + str(getattr(p, "partitions_per_location", "")))
    def test_views_cover_all_rows_once(self, pol):
        pts, ba = _blocked(97, 12, 3, round_robin_placement)
        views = (
            Collection.from_blocked(ba)
            .split(pol)
            .map_partitions(lambda v: (v.location, v.item_indexes))
            .compute()
            .value
        )
        allidx = np.concatenate([idx for _, idx in views])
        assert sorted(allidx.tolist()) == list(range(97))

    def test_zip_materialized_stays_aligned(self):
        rng = np.random.default_rng(5)
        pts = rng.normal(size=(60, 2)).astype(np.float32)
        lab = np.arange(60, dtype=np.float32)
        xb = BlockedArray.from_array(jnp.asarray(pts), 7, num_locations=3,
                                     policy=round_robin_placement)
        yb = BlockedArray.from_array(jnp.asarray(lab), 7, num_locations=3,
                                     policy=round_robin_placement)
        groups = (
            Collection.zip(Collection.from_blocked(xb), Collection.from_blocked(yb))
            .split(SplIter())
            .map_partitions(lambda v: (v.materialized, v.item_indexes))
            .compute()
            .value
        )
        for (bx, by), idx in groups:
            np.testing.assert_array_equal(np.asarray(by), lab[idx])
            np.testing.assert_array_equal(np.asarray(bx), pts[idx])


class TestPlanValidation:
    def test_reduce_without_map_fails(self):
        _, ba = _blocked(40, 7, 1, contiguous_placement)
        with pytest.raises(PlanError):
            Collection.from_blocked(ba).reduce(lambda a, b: a + b).plan()

    def test_misaligned_zip_fails(self):
        _, a = _blocked(40, 7, 2, contiguous_placement)
        _, b = _blocked(40, 5, 2, contiguous_placement)
        with pytest.raises(PlanError):
            (Collection.zip(Collection.from_blocked(a), Collection.from_blocked(b))
             .map_blocks(_moments_fn).plan())

    def test_describe_names_every_stage(self):
        _, ba = _blocked(40, 7, 2, contiguous_placement)
        text = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .plan()
            .describe()
        )
        for token in ("Source", "Split", "MapBlocks", "Reduce", "SplIter"):
            assert token in text

    def test_as_policy_coercion(self):
        assert as_policy("baseline") == Baseline()
        assert as_policy("spliter_mat", partitions_per_location=2) == SplIter(2, True)
        assert as_policy(Rechunk()) == Rechunk()
        with pytest.raises(ValueError):
            as_policy("warp-drive")


class TestDeprecatedShim:
    def test_warns_and_matches_new_api(self):
        pts, ba = _blocked(97, 12, 3, round_robin_placement)
        with pytest.warns(DeprecationWarning, match="run_map_reduce"):
            old_val, old_rep = run_map_reduce(
                [ba], _moments_fn, _moments_combine, mode="spliter"
            )
        new = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .compute()
        )
        for a, b in zip(old_val, new.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert old_rep.dispatches == new.report.dispatches
        assert old_rep.mode == "spliter"

    @pytest.mark.parametrize("mode", ["baseline", "spliter", "spliter_mat", "rechunk"])
    def test_all_legacy_modes_still_run(self, mode):
        pts, ba = _blocked(96, 8, 4, round_robin_placement)
        with pytest.warns(DeprecationWarning):
            val, rep = run_map_reduce([ba], _moments_fn, _moments_combine, mode=mode)
        np.testing.assert_allclose(
            np.asarray(val[0]), pts.sum(0), rtol=2e-4, atol=2e-4
        )
        assert rep.mode == mode
