"""The plan-based execution layer: policy equivalence, executors, the shim.

Covers DESIGN.md §7.4/§6: every policy agrees on associative reductions up
to fp reassociation — including ragged tails and partitions_per_location>1
— and ThreadedExecutor is bit-identical to LocalExecutor; plus the
deprecated run_map_reduce shim (warns, matches the new API), the lowering
pass (TaskGraph kinds per fusion knob, Pallas fallback rules), the
MeshExecutor backend, the LRU-bounded prepare cache, stable task keys, and
the persistent threaded worker pool.
"""

import gc
import weakref
from functools import partial

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    Collection,
    LocalExecutor,
    MeshExecutor,
    PlanError,
    Rechunk,
    SplIter,
    ThreadedExecutor,
    as_policy,
    stable_task_key,
)
from repro.core.blocked import BlockedArray, contiguous_placement, round_robin_placement
from repro.core.engine import run_map_reduce

POLICIES = [
    Baseline(),
    SplIter(),
    SplIter(materialize=True),
    SplIter(partitions_per_location=3),
    SplIter(partitions_per_location=3, materialize=True),
    Rechunk(),
    Rechunk(target_rows=17),
]

# (rows, block_rows, locations, placement) — uniform, ragged tail, ragged with
# many locations, single location, more locations than blocks.
DATASETS = [
    (96, 8, 4, round_robin_placement),
    (97, 12, 3, round_robin_placement),      # ragged tail
    (341, 100, 5, contiguous_placement),     # ragged, uneven fill
    (40, 7, 1, contiguous_placement),        # single location, ragged
    (5, 2, 8, round_robin_placement),        # locations > blocks
]


def _blocked(rows, block_rows, locs, placement, d=3, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(rows, d)).astype(np.float32)
    return pts, BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=locs, policy=placement
    )


def _moments_fn(b):
    return jnp.sum(b, 0), jnp.sum(b * b, 0), jnp.asarray(b.shape[0], jnp.float32)


def _moments_combine(a, b):
    return a[0] + b[0], a[1] + b[1], a[2] + b[2]


class TestModeEquivalence:
    @pytest.mark.parametrize("ds", DATASETS, ids=lambda d: f"n{d[0]}b{d[1]}l{d[2]}")
    def test_all_policies_agree(self, ds):
        """C4: any policy grouping agrees up to float reassociation."""
        pts, ba = _blocked(*ds)
        ref = (pts.sum(0), (pts * pts).sum(0), np.float32(len(pts)))
        for pol in POLICIES:
            res = (
                Collection.from_blocked(ba)
                .split(pol)
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute()
            )
            for got, want in zip(res.value, ref):
                np.testing.assert_allclose(
                    np.asarray(got), want, rtol=2e-4, atol=2e-4, err_msg=repr(pol)
                )
            assert res.report.bytes_moved == 0 or isinstance(pol, Rechunk)

    @pytest.mark.parametrize("ds", DATASETS, ids=lambda d: f"n{d[0]}b{d[1]}l{d[2]}")
    @pytest.mark.parametrize("pol", POLICIES, ids=lambda p: repr(p))
    def test_threaded_identical_to_local(self, ds, pol):
        """Local vs Threaded on the SAME policy must be bit-identical."""
        _, ba = _blocked(*ds)
        plan = (
            Collection.from_blocked(ba)
            .split(pol)
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        seq = plan.compute(executor=LocalExecutor())
        thr = plan.compute(executor=ThreadedExecutor())
        for a, b in zip(seq.value, thr.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert thr.report.dispatches == seq.report.dispatches
        assert thr.report.bytes_moved == seq.report.bytes_moved

    def test_spliter_dispatch_bound(self):
        """C1: spliter dispatches ≤ partitions + ragged-tail extras + merge."""
        _, ba = _blocked(97, 12, 3, round_robin_placement)
        for ppl in (1, 2, 4):
            res = (
                Collection.from_blocked(ba)
                .split(SplIter(partitions_per_location=ppl))
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute()
            )
            # ≤ 2 shape-runs per partition (body + tail) + 1 merge.
            assert res.report.dispatches <= 2 * 3 * ppl + 1


class TestExecutorStatefulness:
    def test_rechunk_paid_once_with_persistent_executor(self):
        """C3: the prepare cache bills rechunk traffic exactly once."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        data = Collection.from_blocked(ba).split(Rechunk())
        plan = data.map_blocks(_moments_fn).reduce(_moments_combine)
        first = plan.compute(executor=ex)
        second = plan.compute(executor=ex)
        assert first.report.bytes_moved > 0
        assert second.report.bytes_moved == 0
        assert second.report.dispatches == first.report.dispatches

    def test_traces_attributed_to_paying_report(self):
        """Per-report traces are the delta over the report's window."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        plan = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        r1 = plan.compute(executor=ex).report
        r2 = plan.compute(executor=ex).report
        assert r1.traces == 2          # partition task + merge task
        assert r2.traces == 0          # cache hits only
        assert ex.engine.traces_total == 2

    def test_scope_accumulates_custom_dispatches(self):
        _, ba = _blocked(40, 7, 1, contiguous_placement)
        ex = LocalExecutor()
        with ex.scope("spliter") as report:
            res = (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute(executor=ex)
            )
            assert res.report is report
            t = ex.task(lambda v: v * 2, key="double")
            t(jnp.ones(3))
        assert report.dispatches >= 2
        assert report.wall_s > 0


class TestMapPartitions:
    @pytest.mark.parametrize("pol", [Baseline(), SplIter(), SplIter(2), Rechunk()],
                             ids=lambda p: p.mode_name + str(getattr(p, "partitions_per_location", "")))
    def test_views_cover_all_rows_once(self, pol):
        pts, ba = _blocked(97, 12, 3, round_robin_placement)
        views = (
            Collection.from_blocked(ba)
            .split(pol)
            .map_partitions(lambda v: (v.location, v.item_indexes))
            .compute()
            .value
        )
        allidx = np.concatenate([idx for _, idx in views])
        assert sorted(allidx.tolist()) == list(range(97))

    def test_zip_materialized_stays_aligned(self):
        rng = np.random.default_rng(5)
        pts = rng.normal(size=(60, 2)).astype(np.float32)
        lab = np.arange(60, dtype=np.float32)
        xb = BlockedArray.from_array(jnp.asarray(pts), 7, num_locations=3,
                                     policy=round_robin_placement)
        yb = BlockedArray.from_array(jnp.asarray(lab), 7, num_locations=3,
                                     policy=round_robin_placement)
        groups = (
            Collection.zip(Collection.from_blocked(xb), Collection.from_blocked(yb))
            .split(SplIter())
            .map_partitions(lambda v: (v.materialized, v.item_indexes))
            .compute()
            .value
        )
        for (bx, by), idx in groups:
            np.testing.assert_array_equal(np.asarray(by), lab[idx])
            np.testing.assert_array_equal(np.asarray(bx), pts[idx])


class TestPlanValidation:
    def test_reduce_without_map_fails(self):
        _, ba = _blocked(40, 7, 1, contiguous_placement)
        with pytest.raises(PlanError):
            Collection.from_blocked(ba).reduce(lambda a, b: a + b).plan()

    def test_misaligned_zip_fails(self):
        _, a = _blocked(40, 7, 2, contiguous_placement)
        _, b = _blocked(40, 5, 2, contiguous_placement)
        with pytest.raises(PlanError):
            (Collection.zip(Collection.from_blocked(a), Collection.from_blocked(b))
             .map_blocks(_moments_fn).plan())

    def test_describe_names_every_stage(self):
        _, ba = _blocked(40, 7, 2, contiguous_placement)
        text = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .plan()
            .describe()
        )
        for token in ("Source", "Split", "MapBlocks", "Reduce", "SplIter"):
            assert token in text

    def test_as_policy_coercion(self):
        assert as_policy("baseline") == Baseline()
        assert as_policy("spliter_mat", partitions_per_location=2) == SplIter(2, True)
        assert as_policy(Rechunk()) == Rechunk()
        with pytest.raises(ValueError):
            as_policy("warp-drive")


class TestDeprecatedShim:
    def test_warns_and_matches_new_api(self):
        pts, ba = _blocked(97, 12, 3, round_robin_placement)
        with pytest.warns(DeprecationWarning, match="run_map_reduce"):
            old_val, old_rep = run_map_reduce(
                [ba], _moments_fn, _moments_combine, mode="spliter"
            )
        new = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .compute()
        )
        for a, b in zip(old_val, new.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert old_rep.dispatches == new.report.dispatches
        assert old_rep.mode == "spliter"

    @pytest.mark.parametrize("mode", ["baseline", "spliter", "spliter_mat", "rechunk"])
    def test_all_legacy_modes_still_run(self, mode):
        pts, ba = _blocked(96, 8, 4, round_robin_placement)
        with pytest.warns(DeprecationWarning):
            val, rep = run_map_reduce([ba], _moments_fn, _moments_combine, mode=mode)
        np.testing.assert_allclose(
            np.asarray(val[0]), pts.sum(0), rtol=2e-4, atol=2e-4
        )
        assert rep.mode == mode


# ---------------------------------------------------------------------------
# lowering pass: TaskGraph kinds, the fusion knob, Pallas fallback rules
# ---------------------------------------------------------------------------


def _hist_plan(ba, pol, bins=4):
    from repro.core.apps.histogram import histogramdd_block

    fn = partial(histogramdd_block, bins=bins, lo=0.0, hi=1.0)
    return (
        Collection.from_blocked(ba)
        .split(pol)
        .map_blocks(fn)
        .reduce(lambda a, b: a + b)
    )


class TestLoweringFusion:
    def _kinds(self, ex, plan):
        return {t.kind for t in ex.lower(plan.plan()).tasks}

    def test_taskgraph_kinds_follow_fusion_knob(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        assert self._kinds(ex, _hist_plan(ba, SplIter(fusion="scan"))) == {
            "partition_scan"
        }
        assert self._kinds(ex, _hist_plan(ba, SplIter(fusion="pallas"))) == {
            "partition_pallas"
        }
        # "auto" on a non-TPU backend keeps the compiled scan
        assert self._kinds(ex, _hist_plan(ba, SplIter())) == {"partition_scan"}

    def test_pallas_falls_back_without_kernel(self):
        """fusion="pallas" on an unregistered fn lowers to the scan."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        plan = (
            Collection.from_blocked(ba)
            .split(SplIter(fusion="pallas"))
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        assert {t.kind for t in ex.lower(plan.plan()).tasks} == {"partition_scan"}
        res = plan.compute(executor=ex)
        ref = plan.compute(executor=LocalExecutor())
        for a, b in zip(res.value, ref.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_pallas_falls_back_when_kernel_rejects_shapes(self):
        """The kernel's supports() guard (bins**d too large) → scan."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)  # d=3
        ex = LocalExecutor()
        graph = ex.lower(_hist_plan(ba, SplIter(fusion="pallas"), bins=128).plan())
        assert {t.kind for t in graph.tasks} == {"partition_scan"}

    def test_pallas_histogram_exact_incl_ragged(self):
        """End-to-end C4 under fusion="pallas": exact int counts, ragged
        tails lower per same-shape run (at most one extra task per tail)."""
        _, ba = _blocked(97, 12, 3, round_robin_placement)
        base = _hist_plan(ba, Baseline()).compute()
        for ex in (LocalExecutor(), ThreadedExecutor(), MeshExecutor()):
            res = _hist_plan(ba, SplIter(fusion="pallas")).compute(executor=ex)
            np.testing.assert_array_equal(
                np.asarray(res.value), np.asarray(base.value), err_msg=repr(ex)
            )
            # C1 bound: <= 2 shape runs per partition + 1 merge
            assert res.report.dispatches <= 2 * 3 + 1

    def test_pallas_dispatch_counts_match_scan(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        r_scan = _hist_plan(ba, SplIter(fusion="scan")).compute(executor=ex).report
        r_pal = _hist_plan(ba, SplIter(fusion="pallas")).compute(executor=ex).report
        assert r_pal.dispatches == r_scan.dispatches == ba.num_locations + 1

    def test_describe_golden_per_policy(self):
        """Golden strings for TaskGraph.describe(): a lowering regression
        (placement, grouping, fusion kind, merge identity) must show up as
        a readable string diff, not a silent behaviour change."""
        _, ba = _blocked(40, 8, 2, round_robin_placement)

        def moments(b):
            return jnp.sum(b, 0)

        def combine(a, b):
            return a + b

        def describe(pol):
            plan = (
                Collection.from_blocked(ba)
                .split(pol)
                .map_blocks(moments)
                .reduce(combine)
                .plan()
            )
            return LocalExecutor().lower(plan).describe()

        assert describe(Baseline()) == "\n".join([
            "[0] loc=0 block blocks=(0,)",
            "[1] loc=1 block blocks=(1,)",
            "[2] loc=0 block blocks=(2,)",
            "[3] loc=1 block blocks=(3,)",
            "[4] loc=0 block blocks=(4,)",
            "[merge] combine=combine",
        ])
        assert describe(SplIter()) == "\n".join([
            "[0] loc=0 partition_scan blocks=(0, 2, 4)",
            "[1] loc=1 partition_scan blocks=(1, 3)",
            "[merge] combine=combine",
        ])
        assert describe(SplIter(partitions_per_location=2)) == "\n".join([
            "[0] loc=0 partition_scan blocks=(0, 4)",
            "[1] loc=0 partition_scan blocks=(2,)",
            "[2] loc=1 partition_scan blocks=(1,)",
            "[3] loc=1 partition_scan blocks=(3,)",
            "[merge] combine=combine",
        ])
        assert describe(Rechunk()) == "\n".join([
            "[0] loc=0 block blocks=(0,)",
            "[1] loc=1 block blocks=(1,)",
            "[merge] combine=combine",
        ])

    def test_taskgraph_is_placed_and_described(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        graph = LocalExecutor().lower(_hist_plan(ba, SplIter(fusion="pallas")).plan())
        assert graph.locations == (0, 1, 2, 3)
        assert all(t.kernel_name == "partition_histogramdd" for t in graph.tasks)
        text = graph.describe()
        assert "partition_pallas" in text and "merge" in text
        # every block appears exactly once across the graph
        covered = sorted(b for t in graph.tasks for b in t.block_ids)
        assert covered == list(range(ba.num_blocks))


# ---------------------------------------------------------------------------
# MeshExecutor: sharded scheduling agrees with per-task backends
# ---------------------------------------------------------------------------


class TestMeshExecutor:
    @pytest.mark.parametrize("ds", DATASETS, ids=lambda d: f"n{d[0]}b{d[1]}l{d[2]}")
    def test_matches_local_all_policies(self, ds):
        _, ba = _blocked(*ds)
        for pol in POLICIES:
            plan = (
                Collection.from_blocked(ba)
                .split(pol)
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
            )
            loc = plan.compute(executor=LocalExecutor())
            mesh = plan.compute(executor=MeshExecutor())
            for a, b in zip(mesh.value, loc.value):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4,
                    err_msg=repr(pol),
                )
            # sharded calls never exceed the per-task dispatch count
            assert mesh.report.dispatches <= loc.report.dispatches

    def test_uniform_spliter_is_one_sharded_dispatch(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)  # 12 uniform blocks
        res = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .compute(executor=MeshExecutor())
        )
        assert res.report.dispatches == 1  # all 4 partitions, one sharded call

    def test_map_partitions_fallback_covers_all_rows(self):
        _, ba = _blocked(97, 12, 3, round_robin_placement)
        views = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_partitions(lambda v: (v.location, v.item_indexes))
            .compute(executor=MeshExecutor())
            .value
        )
        allidx = np.concatenate([idx for _, idx in views])
        assert sorted(allidx.tolist()) == list(range(97))

    def test_unreduced_map_falls_back_to_block_order(self):
        pts, ba = _blocked(96, 8, 4, round_robin_placement)
        partials = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(lambda b: jnp.sum(b, 0))
            .compute(executor=MeshExecutor())
            .value
        )
        assert len(partials) == ba.num_blocks
        np.testing.assert_allclose(
            np.asarray(partials[0]), pts[:8].sum(0), rtol=2e-4, atol=2e-4
        )

    def test_iterative_reuses_compiled_sharded_call(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = MeshExecutor()
        plan = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        r1 = plan.compute(executor=ex).report
        r2 = plan.compute(executor=ex).report
        assert r1.traces >= 1 and r2.traces == 0
        assert r2.dispatches == r1.dispatches == 1


# ---------------------------------------------------------------------------
# prepare-cache LRU bound (no unbounded dataset pinning)
# ---------------------------------------------------------------------------


class TestPrepareCacheLRU:
    def test_cache_bounded_and_releases_evicted_inputs(self):
        ex = LocalExecutor()
        cap = ex.prepare_cache_size
        refs = []
        for i in range(cap + 4):
            _, ba = _blocked(40, 7, 2, contiguous_placement, seed=i)
            refs.append(weakref.ref(ba))
            (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute(executor=ex)
            )
            del ba
        assert len(ex._prepare_cache) == cap
        gc.collect()
        # evicted entries no longer pin their datasets; recent ones still do
        assert refs[0]() is None
        assert refs[-1]() is not None

    def test_recently_used_entry_survives_eviction(self):
        ex = LocalExecutor()
        cap = ex.prepare_cache_size
        _, hot = _blocked(40, 7, 2, contiguous_placement, seed=100)
        hot_plan = (
            Collection.from_blocked(hot)
            .split(Rechunk())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        first = hot_plan.compute(executor=ex)
        assert first.report.bytes_moved >= 0
        for i in range(cap - 1):  # fill the rest of the cache, touching hot
            _, ba = _blocked(40, 7, 2, contiguous_placement, seed=i)
            (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_blocks(_moments_fn)
                .reduce(_moments_combine)
                .compute(executor=ex)
            )
            hot_plan.compute(executor=ex)  # LRU touch
        again = hot_plan.compute(executor=ex)
        assert again.report.bytes_moved == 0  # still cached: rechunk not re-billed


# ---------------------------------------------------------------------------
# stable task keys: fresh lambdas / partials must hit the jit cache
# ---------------------------------------------------------------------------


class TestStableTaskKeys:
    def test_fresh_lambdas_hit_jit_cache(self):
        """The historical ("merge", combine) bug: app-level lambdas recreated
        per call must not defeat the jit cache / inflate trace counts."""
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()

        def once():
            return (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_blocks(lambda b: (jnp.sum(b, 0),))
                .reduce(lambda a, b: (a[0] + b[0],))
                .compute(executor=ex)
            )

        r1 = once().report
        r2 = once().report
        assert r1.traces == 2            # partition task + merge, traced once
        assert r2.traces == 0            # fresh lambdas, same stable keys
        assert ex.engine.traces_total == 2

    def test_histogram_app_traces_once_across_calls(self):
        from repro.core.apps.histogram import histogram

        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = LocalExecutor()
        _, r1 = histogram(ba, bins=4, policy=SplIter(), executor=ex)
        _, r2 = histogram(ba, bins=4, policy=SplIter(), executor=ex)
        assert r1.traces == 2 and r2.traces == 0

    def test_partial_statics_distinguish_keys(self):
        from repro.core.apps.histogram import histogramdd_block

        mk = lambda bins: partial(histogramdd_block, bins=bins, lo=0.0, hi=1.0)
        assert stable_task_key(mk(4)) == stable_task_key(mk(4))
        assert stable_task_key(mk(4)) != stable_task_key(mk(8))

    def test_closure_values_distinguish_keys(self):
        def mk(c):
            return lambda a, b: a + b * c

        assert stable_task_key(mk(2.0)) == stable_task_key(mk(2.0))
        assert stable_task_key(mk(2.0)) != stable_task_key(mk(3.0))

    def test_unhashable_closure_falls_back_to_identity(self):
        big = jnp.ones((4,))

        def mk():
            return lambda a: a + big  # closes over an array (unhashable)

        f = mk()
        assert stable_task_key(f) is f


# ---------------------------------------------------------------------------
# threaded executor: persistent per-location worker pool
# ---------------------------------------------------------------------------


class TestThreadedWorkerPool:
    def test_workers_persist_across_runs_and_close(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = ThreadedExecutor()
        plan = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )
        plan.compute(executor=ex)
        first = dict(ex._workers)
        assert len(first) == 4           # one worker per location
        plan.compute(executor=ex)
        assert dict(ex._workers) == first  # reused, not respawned
        ex.close()
        assert not ex._workers
        res = plan.compute(executor=ex)    # pool respawns transparently
        ref = plan.compute(executor=LocalExecutor())
        for a, b in zip(res.value, ref.value):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ex.close()

    def test_single_location_runs_inline(self):
        _, ba = _blocked(40, 7, 1, contiguous_placement)
        ex = ThreadedExecutor()
        (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
            .compute(executor=ex)
        )
        assert not ex._workers           # no threads for 1 location

    def test_worker_error_propagates(self):
        _, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = ThreadedExecutor()

        def boom(v):
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError, match="boom"):
            (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_partitions(boom)
                .compute(executor=ex)
            )
        ex.close()


class TestReviewRegressions:
    def test_mesh_cache_keyed_on_combine_identity(self):
        """Same map fn reduced by DIFFERENT combines on one MeshExecutor must
        not share a compiled sharded fold (regression: wrong values)."""
        pts, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = MeshExecutor()
        base = Collection.from_blocked(ba).split(Baseline()).map_blocks(
            lambda b: jnp.sum(b, 0)
        )
        s = base.reduce(lambda a, b: a + b).compute(executor=ex).value
        m = base.reduce(jnp.maximum).compute(executor=ex).value
        np.testing.assert_allclose(
            np.asarray(s), pts.sum(0), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(m),
            np.max(pts.reshape(12, 8, 3).sum(1), axis=0),
            rtol=2e-4, atol=2e-4,
        )

    def test_threaded_nested_compute_does_not_deadlock(self):
        """A map_partitions callback computing on the SAME ThreadedExecutor
        runs inline instead of deadlocking its own location worker."""
        pts, ba = _blocked(96, 8, 4, round_robin_placement)
        ex = ThreadedExecutor()
        inner_plan = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_blocks(_moments_fn)
            .reduce(_moments_combine)
        )

        def view_fn(view):
            inner = inner_plan.compute(executor=ex)  # nested, same executor
            return view.location, np.asarray(inner.value[0])

        res = (
            Collection.from_blocked(ba)
            .split(SplIter())
            .map_partitions(view_fn)
            .compute(executor=ex)
        )
        for _, total in res.value:
            np.testing.assert_allclose(total, pts.sum(0), rtol=2e-4, atol=2e-4)
        ex.close()

    def test_stable_key_distinguishes_globals(self):
        """Identical bytecode resolving different module globals must not
        share a key (two modules defining the same-looking fn)."""
        ns1 = {"SCALE": 2.0}
        ns2 = {"SCALE": 3.0}
        code = "def f(b):\n    return SCALE * b\n"
        exec(code, ns1)
        exec(code, ns2)
        assert stable_task_key(ns1["f"]) != stable_task_key(ns2["f"])
        # re-creating the fn in the SAME namespace keeps the key stable
        f_old = ns1["f"]
        exec(code, ns1)
        assert ns1["f"] is not f_old
        assert stable_task_key(ns1["f"]) == stable_task_key(f_old)
