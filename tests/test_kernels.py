"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles.

All kernels run in interpret=True (Pallas interpreter on CPU); the same
kernel bodies compile to Mosaic on TPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # only the property sweep at the bottom needs hypothesis
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.partition_reduce import partition_histogram, partition_kmeans
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("b,lq,lk,h,hkv,d", [
        (1, 32, 32, 2, 2, 8),      # MHA
        (2, 64, 64, 4, 2, 16),     # GQA 2:1
        (1, 128, 128, 8, 1, 32),   # MQA
        (2, 48, 96, 4, 4, 64),     # cross-length (q_offset-free, non-causal)
    ])
    def test_shapes_vs_ref(self, b, lq, lk, h, hkv, d):
        q, k, v = randn(b, lq, h, d), randn(b, lk, hkv, d), randn(b, lk, hkv, d)
        causal = lq == lk
        o = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
        r = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **TOL[jnp.float32])

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q = randn(2, 64, 4, 16).astype(dtype)
        k = randn(2, 64, 2, 16).astype(dtype)
        v = randn(2, 64, 2, 16).astype(dtype)
        o = flash_attention(q, k, v, block_q=32, block_k=32)
        r = ref.attention_ref(q, k, v)
        assert o.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), **TOL[dtype]
        )

    @pytest.mark.parametrize("window", [8, 24, 64])
    def test_sliding_window(self, window):
        q, k, v = randn(1, 64, 2, 16), randn(1, 64, 2, 16), randn(1, 64, 2, 16)
        o = flash_attention(q, k, v, window=window, block_q=16, block_k=16)
        r = ref.attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **TOL[jnp.float32])

    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 32), (32, 16), (64, 64)])
    def test_block_shape_invariance(self, bq, bk):
        """Output must not depend on the BlockSpec tiling."""
        q, k, v = randn(1, 64, 2, 16), randn(1, 64, 2, 16), randn(1, 64, 2, 16)
        o = flash_attention(q, k, v, block_q=bq, block_k=bk)
        r = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **TOL[jnp.float32])


class TestPartitionReduce:
    @pytest.mark.parametrize("nb,rows,d,bins", [
        (1, 16, 2, 8), (4, 32, 4, 16), (8, 64, 1, 128), (3, 8, 8, 32),
    ])
    def test_histogram_shapes(self, nb, rows, d, bins):
        st_ = jnp.asarray(RNG.uniform(0, 1, (nb, rows, d)).astype(np.float32))
        h = partition_histogram(st_, bins=bins, lo=0.0, hi=1.0)
        r = ref.histogram_ref(st_, bins=bins, lo=0.0, hi=1.0)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(r))
        assert int(h.sum()) == nb * rows * d

    def test_histogram_outliers_clamped(self):
        st_ = jnp.asarray(RNG.normal(0.5, 2.0, (2, 32, 2)).astype(np.float32))
        h = partition_histogram(st_, bins=8, lo=0.0, hi=1.0)
        r = ref.histogram_ref(st_, bins=8, lo=0.0, hi=1.0)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(r))

    @pytest.mark.parametrize("nb,rows,d,k", [
        (1, 16, 4, 2), (4, 32, 8, 4), (6, 24, 3, 8),
    ])
    def test_kmeans_shapes(self, nb, rows, d, k):
        st_ = randn(nb, rows, d)
        cen = randn(k, d)
        sums, counts = partition_kmeans(st_, cen)
        rs, rc = ref.kmeans_ref(st_, cen)
        np.testing.assert_allclose(np.asarray(sums), np.asarray(rs), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(rc))

    @pytest.mark.parametrize("nb,rows,d,bins", [
        (1, 16, 1, 8), (4, 32, 2, 4), (3, 8, 3, 4), (2, 64, 1, 128),
    ])
    def test_histogramdd_matches_block_fn(self, nb, rows, d, bins):
        """The fused-kernel contract: partition_histogramdd == folding the
        app's histogramdd_block over the stacked blocks with + (bit-exact)."""
        from repro.core.apps.histogram import histogramdd_block
        from repro.kernels.partition_reduce import partition_histogramdd

        st_ = jnp.asarray(RNG.uniform(0, 1, (nb, rows, d)).astype(np.float32))
        h = partition_histogramdd(st_, bins=bins, lo=0.0, hi=1.0)
        want = sum(
            histogramdd_block(st_[i], bins=bins, lo=0.0, hi=1.0) for i in range(nb)
        )
        assert h.shape == (bins,) * d and h.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(h), np.asarray(want))
        assert int(h.sum()) == nb * rows          # one cell per row

    def test_histogramdd_outliers_clamped(self):
        from repro.core.apps.histogram import histogramdd_block
        from repro.kernels.partition_reduce import partition_histogramdd

        st_ = jnp.asarray(RNG.normal(0.5, 2.0, (3, 16, 2)).astype(np.float32))
        h = partition_histogramdd(st_, bins=4, lo=0.0, hi=1.0)
        want = sum(histogramdd_block(st_[i], bins=4, lo=0.0, hi=1.0) for i in range(3))
        np.testing.assert_array_equal(np.asarray(h), np.asarray(want))

    def test_histogramdd_block_count_invariance(self):
        """Same data, different block counts → identical flat grid (the
        kernel-level granularity-decoupling claim, d-dimensional)."""
        from repro.kernels.partition_reduce import partition_histogramdd

        x = jnp.asarray(RNG.uniform(0, 1, (64, 2)).astype(np.float32))
        outs = [
            partition_histogramdd(x.reshape(nb, -1, 2), bins=4, lo=0.0, hi=1.0)
            for nb in (1, 2, 4, 8)
        ]
        for h in outs[1:]:
            np.testing.assert_array_equal(np.asarray(h), np.asarray(outs[0]))

    def test_kmeans_block_count_invariance(self):
        """Same data split into different block counts → identical result
        (the kernel-level SplIter granularity-decoupling claim)."""
        x = randn(8 * 16, 4)
        cen = randn(4, 4)
        outs = []
        for nb in (1, 2, 4, 8):
            st_ = x.reshape(nb, -1, 4)
            outs.append(partition_kmeans(st_, cen))
        for s, c in outs[1:]:
            np.testing.assert_allclose(
                np.asarray(s), np.asarray(outs[0][0]), rtol=1e-5, atol=1e-5
            )
            np.testing.assert_array_equal(np.asarray(c), np.asarray(outs[0][1]))


class TestSSDScan:
    @pytest.mark.parametrize("b,l,nh,p,n,chunk", [
        (1, 32, 1, 4, 8, 8),
        (2, 64, 3, 8, 16, 16),
        (1, 128, 2, 16, 32, 32),
        (2, 64, 4, 8, 16, 64),   # single chunk
    ])
    def test_shapes_vs_sequential_ref(self, b, l, nh, p, n, chunk):
        x = randn(b, l, nh, p)
        dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, l, nh)).astype(np.float32))
        a = jnp.asarray(-RNG.uniform(0.5, 1.5, (nh,)).astype(np.float32))
        bm, cm = randn(b, l, n), randn(b, l, n)
        y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
        yr, hr = ref.ssd_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=3e-4, atol=3e-4)

    def test_chunk_invariance(self):
        """Output independent of the chunking (BlockSpec) choice."""
        b, l, nh, p, n = 1, 64, 2, 8, 16
        x = randn(b, l, nh, p)
        dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, l, nh)).astype(np.float32))
        a = jnp.asarray(-RNG.uniform(0.5, 1.5, (nh,)).astype(np.float32))
        bm, cm = randn(b, l, n), randn(b, l, n)
        base, hbase = ssd_scan(x, dt, a, bm, cm, chunk=8)
        for chunk in (16, 32, 64):
            y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
            np.testing.assert_allclose(np.asarray(y), np.asarray(base), rtol=3e-4, atol=3e-4)
            np.testing.assert_allclose(np.asarray(hf), np.asarray(hbase), rtol=3e-4, atol=3e-4)


if HAVE_HYPOTHESIS:

    @given(
        lq=st.sampled_from([16, 32, 64]),
        h=st.sampled_from([2, 4]),
        hkv=st.sampled_from([1, 2]),
        d=st.sampled_from([8, 16]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=12, deadline=None)
    def test_flash_attention_property(lq, h, hkv, d, causal, seed):
        """Hypothesis sweep: kernel == oracle over random geometry."""
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(1, lq, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, lq, hkv, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, lq, hkv, d)).astype(np.float32))
        o = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
        r = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-5, atol=3e-5)

else:  # keep the skip visible in the report when hypothesis is absent

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flash_attention_property():
        pass
