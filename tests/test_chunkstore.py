"""Out-of-core chunk storage + streaming execution (DESIGN.md §10).

Covers the ChunkStore contract (LRU residency, pin/unpin, spill-on-
eviction, cleanup), the chunk-ref plumbing through BlockedArray/lowering,
and the StreamExecutor acceptance criterion: a dataset 4× the residency
budget completes with bounded resident bytes, bit-identical results vs
LocalExecutor, and a warm prefetch pipeline.
"""

from __future__ import annotations

import gc
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    ChunkPinnedError,
    ChunkRef,
    ChunkStore,
    ChunkStoreError,
    Collection,
    DiskStore,
    InMemoryStore,
    LocalExecutor,
    Rechunk,
    SplIter,
    StreamExecutor,
    ThreadedExecutor,
)
from repro.core.blocked import BlockedArray, round_robin_placement


def _dataset(rows=4096, d=8, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((rows, d)).astype(np.float32))


def _sum_plan(x, block_rows, locs, policy, ex, store=None):
    c = Collection.from_array(
        x, block_rows=block_rows, num_locations=locs,
        placement=round_robin_placement, store=store,
    )
    return (
        c.split(policy)
        .map_blocks(jnp.sum)
        .reduce(lambda a, b: a + b)
        .compute(executor=ex)
    )


# ---------------------------------------------------------------------------
# the store contract
# ---------------------------------------------------------------------------


class TestDiskStore:
    def test_put_get_roundtrip_bit_identical(self):
        with DiskStore(residency_bytes=1 << 20) as store:
            block = _dataset(rows=64)
            ref = store.put(block)
            assert isinstance(ref, ChunkRef)
            assert ref.shape == block.shape and ref.dtype == block.dtype
            assert bool(jnp.all(ref.resolve() == block))

    def test_reload_after_spill_bit_identical(self):
        blocks = [_dataset(rows=64, seed=i) for i in range(8)]
        nb = blocks[0].nbytes
        with DiskStore(residency_bytes=2 * nb) as store:
            refs = [store.put(b) for b in blocks]
            # ingest overflowed the budget: early chunks were spilled...
            assert store.stats.spills >= 6
            assert store.stats.resident_bytes <= 2 * nb
            # ...and reload to exactly the bytes that went in
            for ref, b in zip(refs, blocks):
                assert bool(jnp.all(ref.resolve() == b))

    def test_spill_file_written_once(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=b.nbytes) as store:
            r0 = store.put(b)
            store.put(b + 1)  # evicts r0 -> spill file
            assert store.stats.spills == 1
            r0.resolve()      # reload r0 (evicts the other)
            store.put(b + 2)  # evict r0 again: clean, no second write
            assert store.stats.spills == 2  # only the OTHER chunk spilled
            assert len(store.spill_files()) == 2

    def test_lru_prefers_cold_victims(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=2 * b.nbytes) as store:
            r0, r1 = store.put(b), store.put(b + 1)
            r0.resolve()            # r0 now most-recently-used
            store.put(b + 2)        # evicts r1, the LRU entry
            assert r0.chunk_id in store.resident_ids()
            assert r1.chunk_id not in store.resident_ids()

    def test_eviction_of_pinned_chunk_refused(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=4 * b.nbytes) as store:
            ref = store.put(b)
            store.pin(ref)
            with pytest.raises(ChunkPinnedError):
                store.evict(ref)
            # budget pressure skips pinned chunks too (overshoot, recorded)
            small = DiskStore(residency_bytes=b.nbytes)  # fits exactly one
            r2 = small.put(b)
            small.pin(r2)
            small.put(b + 1)  # r2 is pinned: survives; the newcomer evicts
            assert r2.chunk_id in small.resident_ids()
            assert small.stats.peak_resident_bytes > small.residency_bytes
            store.unpin(ref)
            store.evict(ref)  # now allowed
            assert ref.chunk_id not in store.resident_ids()
            small.close()

    def test_pins_are_refcounted(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=4 * b.nbytes) as store:
            ref = store.put(b)
            store.pin(ref)
            store.pin(ref)
            store.unpin(ref)
            assert store.is_pinned(ref)
            store.unpin(ref)
            assert not store.is_pinned(ref)

    def test_prefetch_marks_hits(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=b.nbytes) as store:
            r0 = store.put(b)
            store.put(b + 1)          # spill r0
            store.prefetch([r0])
            assert store.stats.prefetch_hits == 0
            r0.resolve()
            assert store.stats.prefetch_hits == 1
            r0.resolve()              # plain resident hit, not a prefetch hit
            assert store.stats.prefetch_hits == 1

    def test_prefetch_self_evicted_under_pin_pressure_is_not_a_hit(self):
        # Budget saturated by a pinned chunk: prefetching another chunk
        # loads it and immediately self-evicts it.  No marker must survive
        # — a later get that finds the chunk resident again (for other
        # reasons) is NOT a prefetch hit.
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=b.nbytes) as store:
            pinned = store.put(b)
            store.pin(pinned)
            c = store.put(b + 1)       # evicted at put (pinned fills budget)
            store.prefetch([c])        # loads, then self-evicts again
            assert c.chunk_id not in store.resident_ids()
            c.resolve()                # plain miss -> load
            c.resolve()                # still no phantom hit
            assert store.stats.prefetch_hits == 0

    def test_prefetch_during_inflight_spill_serves_pending(self):
        # White-box: freeze the two-phase eviction mid-flight (chunk moved
        # to the pending-spill queue, np.save not yet run) and prefetch it.
        # prefetch() must honor the pending queue like get() does — loading
        # from disk here would race the writer and see no file.
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=4 * b.nbytes) as store:
            ref = store.put(b)
            with store._lock:
                store._evict_one(ref.chunk_id)  # pending, write deferred
            store.prefetch([ref])               # must not raise (no _load race)
            # prefetch() flushed the deferred write on its way out, so the
            # chunk is durable and resolvable — and bit-identical.
            assert store.stats.spills == 1
            assert bool(jnp.all(ref.resolve() == b))

    def test_close_removes_spill_dir_and_rejects_use(self):
        store = DiskStore(residency_bytes=1)
        ref = store.put(_dataset(rows=64))
        d = store.spill_dir
        assert os.path.isdir(d)
        store.close()
        assert not os.path.exists(d)
        with pytest.raises(ChunkStoreError):
            ref.resolve()
        store.close()  # idempotent

    def test_gc_finalizer_removes_spill_dir(self):
        store = DiskStore(residency_bytes=1)
        store.put(_dataset(rows=64))
        d = store.spill_dir
        del store
        gc.collect()
        assert not os.path.exists(d)

    def test_trim_spills_everything_unpinned(self):
        b = _dataset(rows=64)
        with DiskStore(residency_bytes=4 * b.nbytes) as store:
            refs = [store.put(b + i) for i in range(3)]
            store.pin(refs[0])
            store.trim()
            assert store.resident_ids() == [refs[0].chunk_id]
            assert store.stats.resident_bytes == b.nbytes


class TestInMemoryStore:
    def test_contract_and_identity_semantics(self):
        store = InMemoryStore()
        assert isinstance(store, ChunkStore)
        b = _dataset(rows=64)
        ref = store.put(b)
        assert ref.resolve() is ref.resolve()  # same resident buffer
        store.pin(ref)
        store.unpin(ref)  # no-ops
        assert store.stats.bytes_loaded == 0 and store.stats.bytes_spilled == 0

    def test_plan_results_match_plain_arrays(self):
        x = _dataset()
        plain = _sum_plan(x, 256, 4, SplIter(), LocalExecutor())
        stored = _sum_plan(x, 256, 4, SplIter(), LocalExecutor(), store=InMemoryStore())
        assert bool(stored.value == plain.value)
        assert stored.report.dispatches == plain.report.dispatches
        assert stored.report.bytes_loaded == 0
        assert stored.report.prefetch_hits == 0


# ---------------------------------------------------------------------------
# chunk-ref plumbing: metadata stays zero-copy
# ---------------------------------------------------------------------------


class TestChunkRefPlumbing:
    def test_blocked_geometry_needs_no_loads(self):
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes)
        ba = BlockedArray.from_array(
            x, 256, num_locations=4, policy=round_robin_placement, store=store
        )
        loads0 = store.stats.loads
        assert ba.is_chunked
        assert ba.num_rows == x.shape[0]
        assert ba.row_shape == x.shape[1:]
        assert ba.nbytes == x.nbytes
        ba.row_offsets(), ba.blocks_at(0)
        assert store.stats.loads == loads0  # geometry is metadata-only
        store.close()

    def test_prepare_and_lower_are_zero_copy_over_refs(self):
        # Splits and regroups on a chunk-backed collection must be pure
        # metadata: the placement scan, striping and lowering never resolve
        # a single chunk (PrepareStats counts the splits; the store counts
        # the loads).
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes)
        c = Collection.from_array(
            x, 128, num_locations=4, placement=round_robin_placement, store=store
        )
        ex = StreamExecutor(close_stores=False)
        loads0 = store.stats.loads
        for ppl in (1, 2, 4):
            plan = c.split(SplIter(partitions_per_location=ppl)) \
                    .map_blocks(jnp.sum).reduce(lambda a, b: a + b).plan()
            graph = ex.lower(plan)
            assert all(t.chunk_refs for t in graph.tasks)
        assert store.stats.loads == loads0
        assert ex.prepare_stats.splits == 1          # one placement scan
        assert ex.prepare_stats.regroups == 2        # ppl=2,4 derived free
        ex.close()
        store.close()

    def test_chunk_refs_only_attached_for_out_of_core_backends(self):
        x = _dataset(rows=512)
        store = DiskStore(residency_bytes=x.nbytes)
        c = Collection.from_array(x, 128, num_locations=2, store=store)
        plan = c.split(SplIter()).map_blocks(jnp.sum).reduce(lambda a, b: a + b).plan()
        local_graph = LocalExecutor().lower(plan)
        stream_graph = StreamExecutor(close_stores=False).lower(plan)
        assert all(t.chunk_refs == () for t in local_graph.tasks)
        assert all(len(t.chunk_refs) > 0 for t in stream_graph.tasks)
        store.close()

    def test_prepare_cache_eviction_trims_store(self):
        x = _dataset(rows=512)
        store = DiskStore(residency_bytes=x.nbytes)
        ex = LocalExecutor()
        res = _sum_plan(x, 128, 2, SplIter(), ex, store=store)
        assert store.stats.resident_bytes > 0
        # flood the prepare cache until the chunked entry is evicted
        for i in range(ex.prepare_cache_size + 1):
            _sum_plan(_dataset(rows=64, seed=i), 32, 2, SplIter(), ex)
        assert store.stats.resident_bytes == 0  # trimmed on eviction
        assert res is not None
        store.close()

    def test_executor_close_trims_stores(self):
        x = _dataset(rows=512)
        store = DiskStore(residency_bytes=x.nbytes)
        ex = ThreadedExecutor()
        _sum_plan(x, 128, 2, SplIter(), ex, store=store)
        assert store.stats.resident_bytes > 0
        ex.close()
        assert store.stats.resident_bytes == 0
        assert len(store.spill_files()) == 4  # data survives as spill files
        store.close()


# ---------------------------------------------------------------------------
# StreamExecutor
# ---------------------------------------------------------------------------


POLICIES = (
    Baseline(),
    SplIter(),
    SplIter(partitions_per_location=2),
    SplIter(materialize=True),
    Rechunk(),
)


class TestStreamExecutor:
    @pytest.mark.parametrize("pol", POLICIES, ids=lambda p: p.mode_name)
    def test_bit_identical_to_local_across_policies(self, pol):
        x = _dataset()
        ref = _sum_plan(x, 256, 4, pol, LocalExecutor())
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor()
        res = _sum_plan(x, 256, 4, pol, ex, store=store)
        assert bool(res.value == ref.value)
        assert res.report.dispatches == ref.report.dispatches
        ex.close()

    def test_acceptance_4x_budget_bounded_residency(self):
        # THE acceptance criterion: a dataset 4x the residency budget
        # completes, peak resident block bytes stay <= 1.25x the budget,
        # results are bit-identical to LocalExecutor, and the prefetch
        # pipeline was warm (hits > 0).
        x = _dataset(rows=8192, d=8)
        budget = x.nbytes // 4
        ref = _sum_plan(x, 256, 4, SplIter(partitions_per_location=8), LocalExecutor())

        store = DiskStore(residency_bytes=budget)
        ex = StreamExecutor()
        res = _sum_plan(
            x, 256, 4, SplIter(partitions_per_location=8), ex, store=store
        )
        assert bool(res.value == ref.value)
        assert store.stats.peak_resident_bytes <= 1.25 * budget
        assert res.report.prefetch_hits > 0
        assert res.report.bytes_spilled > 0  # the dataset cannot fit: it spilled
        ex.close()

    def test_reiteration_after_spill_bit_identical(self):
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor()
        c = Collection.from_array(
            x, 256, num_locations=4, placement=round_robin_placement, store=store
        ).split(SplIter(partitions_per_location=4))
        plan = c.map_blocks(jnp.sum).reduce(lambda a, b: a + b)
        first = plan.compute(executor=ex)
        assert ex.report.bytes_spilled > 0 or store.stats.spills > 0
        second = plan.compute(executor=ex)   # every block re-read from spill
        third = plan.compute(executor=ex)
        assert bool(first.value == second.value) and bool(second.value == third.value)
        assert second.report.bytes_loaded > 0
        ex.close()

    def test_close_closes_streamed_stores(self):
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor()
        _sum_plan(x, 256, 4, SplIter(), ex, store=store)
        d = store.spill_dir
        assert os.path.isdir(d)
        ex.close()
        assert store.closed and not os.path.exists(d)  # no temp-file leaks

    def test_close_stores_false_keeps_store_usable(self):
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor(close_stores=False)
        r1 = _sum_plan(x, 256, 4, SplIter(), ex, store=store)
        ex.close()
        assert not store.closed
        ex2 = StreamExecutor(close_stores=False)
        r2 = _sum_plan(x, 256, 4, SplIter(), ex2, store=store)
        assert bool(r1.value == r2.value)
        ex2.close()
        store.close()

    def test_in_memory_inputs_degrade_gracefully(self):
        x = _dataset()
        ex = StreamExecutor()
        ref = _sum_plan(x, 256, 4, SplIter(), LocalExecutor())
        res = _sum_plan(x, 256, 4, SplIter(), ex)  # no store at all
        assert bool(res.value == ref.value)
        assert res.report.bytes_loaded == 0 and res.report.prefetch_hits == 0
        ex.close()

    def test_prefetch_depth_zero_still_correct(self):
        x = _dataset()
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor(prefetch_depth=0)
        ref = _sum_plan(x, 256, 4, SplIter(), LocalExecutor())
        res = _sum_plan(x, 256, 4, SplIter(), ex, store=store)
        assert bool(res.value == ref.value)
        assert res.report.prefetch_hits == 0  # no lookahead issued
        ex.close()

    def test_map_partitions_views_stream_too(self):
        x = _dataset()
        ref_rows = (
            Collection.from_array(x, 256, num_locations=4,
                                  placement=round_robin_placement)
            .split(SplIter())
            .map_partitions(lambda v: jnp.sum(v.materialized[0]))
            .compute(executor=LocalExecutor())
        )
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ex = StreamExecutor()
        got = (
            Collection.from_array(x, 256, num_locations=4,
                                  placement=round_robin_placement, store=store)
            .split(SplIter())
            .map_partitions(lambda v: jnp.sum(v.materialized[0]))
            .compute(executor=ex)
        )
        assert all(bool(a == b) for a, b in zip(got.value, ref_rows.value))
        ex.close()

    def test_error_in_task_propagates_and_releases_pins(self):
        x = _dataset(rows=1024)
        store = DiskStore(residency_bytes=x.nbytes // 4)
        ba = BlockedArray.from_array(
            x, 256, num_locations=4, policy=round_robin_placement, store=store
        )
        ex = StreamExecutor(close_stores=False)

        def boom(_):
            raise RuntimeError("task failed")

        with pytest.raises(RuntimeError, match="task failed"):
            (
                Collection.from_blocked(ba)
                .split(SplIter())
                .map_partitions(boom)
                .compute(executor=ex)
            )
        # every pin taken by prefetch/dispatch was dropped again
        assert not any(store.is_pinned(b) for b in ba.blocks)
        ex.close()
        store.close()


# ---------------------------------------------------------------------------
# apps over chunk-backed data
# ---------------------------------------------------------------------------


class TestAppsOutOfCore:
    def test_kmeans_streams_bit_identical(self):
        from repro.core.apps.kmeans import kmeans

        rng = np.random.default_rng(3)
        pts = jnp.asarray(rng.random((2048, 4)).astype(np.float32))
        x_mem = BlockedArray.from_array(
            pts, 128, num_locations=2, policy=round_robin_placement
        )
        ref = kmeans(x_mem, k=4, iters=3, policy=SplIter(partitions_per_location=4))

        store = DiskStore(residency_bytes=pts.nbytes // 4)
        x_disk = x_mem.to_store(store)
        ex = StreamExecutor()
        res = kmeans(
            x_disk, k=4, iters=3, policy=SplIter(partitions_per_location=4),
            executor=ex,
        )
        assert bool(jnp.all(res.centers == ref.centers))
        assert sum(r.bytes_loaded for r in res.reports) > 0
        ex.close()

    def test_histogram_streams_bit_exact(self):
        from repro.core.apps.histogram import histogram

        rng = np.random.default_rng(4)
        pts = jnp.asarray(rng.random((4096, 2)).astype(np.float32))
        x_mem = BlockedArray.from_array(
            pts, 256, num_locations=2, policy=round_robin_placement
        )
        h_ref, _ = histogram(x_mem, bins=8, policy=SplIter(partitions_per_location=4))

        store = DiskStore(residency_bytes=pts.nbytes // 4)
        ex = StreamExecutor()
        h, rep = histogram(
            x_mem.to_store(store), bins=8,
            policy=SplIter(partitions_per_location=4), executor=ex,
        )
        assert bool(jnp.all(h == h_ref))  # integer counts: exact
        assert rep.prefetch_hits > 0
        ex.close()
