"""Peer-to-peer partial exchange + worker-side merge folds (DESIGN.md §16).

The acceptance contract:

* with ``p2p=True`` every app stays bit-identical to LocalExecutor — the
  worker-side chain IS the driver's chain, just routed differently;
* the driver receives exactly ONE merged partial per location per
  execute: ``driver_merge_bytes`` collapses from N·S (one partial per
  unit) to L·S, and the member bytes reappear as ``p2p_bytes``;
* the fold tree is a pure function of the plan (replay/resume keep the
  exact shape), and a fold failure names the subtree's ORIGINATING task
  key — never the synthetic fold unit;
* kills mid-exchange replay the subtree with zero leaked ``/dev/shm``
  segments, and chaos rounds (kills + stragglers + steals) keep the
  ``p2p_bytes`` accounting exact, not approximate;
* ``p2p="auto"`` (the default) is cost-gated: small partials never leave
  the pinned path, big iterative partials switch over once observed.
"""

from __future__ import annotations

import contextlib
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    ChaosSchedule,
    ClusterFailedError,
    Collection,
    FaultPlan,
    SplIter,
    engine,
)
from repro.api import shm_available
from repro.api.lowering import fold_plan, lower
from repro.api.shm import leaked_segments
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.apps.knn import knn
from repro.core.blocked import BlockedArray, round_robin_placement

LOG_DIR = os.environ.get("REPRO_CLUSTER_LOG_DIR")  # CI fault lane artifacts
POL = SplIter(partitions_per_location=2)

pytestmark = pytest.mark.skipif(
    not shm_available(), reason="peer exchange needs POSIX shared memory"
)


def _cluster(**kw):
    kw.setdefault("log_dir", LOG_DIR)
    kw.setdefault("p2p", True)
    return engine("cluster", **kw)


@contextlib.contextmanager
def _pool(**kw):
    """A p2p cluster that must leak NOTHING of its own into ``/dev/shm``.

    The leak check is scoped to this pool's segment prefix — other live
    pools (the module fixture, a concurrent test) keep their arenas.
    """
    ex = _cluster(**kw)
    prefix = ex._shm.prefix
    try:
        yield ex
    finally:
        ex.close()
    assert leaked_segments(prefix) == []


def _blocked(a, block_rows=256, locs=2) -> BlockedArray:
    return BlockedArray.from_array(
        jnp.asarray(a), block_rows, num_locations=locs, policy=round_robin_placement
    )


@pytest.fixture(scope="module")
def points() -> BlockedArray:
    rng = np.random.default_rng(0)
    return _blocked(rng.random((2048, 4)).astype(np.float32))


@pytest.fixture(scope="module")
def cluster():
    """One shared p2p pool for the fault-free tests (spawn paid once)."""
    with _cluster() as ex:
        yield ex


def identical(a, b) -> bool:
    return bool(jnp.all(jnp.equal(a, b)))


# ---------------------------------------------------------------------------
# bit-identity vs LocalExecutor — all four apps, folds forced worker-side
# ---------------------------------------------------------------------------


class TestBitIdentity:
    def test_histogram(self, points, cluster):
        ref, _ = histogram(points, bins=8, policy=POL)
        h, rep = histogram(points, bins=8, policy=POL, executor=cluster)
        assert identical(h, ref)
        # 2 locations × 2 partitions: both fold chains ran worker-side
        assert rep.p2p_bytes > 0
        assert rep.merges == 3  # two peer folds + the root fold
        assert rep.driver_merge_bytes * 2 == rep.p2p_bytes  # L·S vs N·S

    def test_kmeans(self, points, cluster):
        ref = kmeans(points, k=4, iters=3, policy=POL)
        res = kmeans(points, k=4, iters=3, policy=POL, executor=cluster)
        assert identical(res.centers, ref.centers)
        assert all(r.p2p_bytes > 0 for r in res.reports)

    def test_knn(self, points, cluster):
        rng = np.random.default_rng(1)
        qry = _blocked(rng.random((256, 4)).astype(np.float32), 128)
        ref = knn(points, qry, k=4, policy=POL)
        res = knn(points, qry, k=4, policy=POL, executor=cluster)
        assert identical(res.indices, ref.indices)
        assert identical(res.distances, ref.distances)

    def test_svm(self, points, cluster):
        rng = np.random.default_rng(2)
        y = _blocked(np.where(rng.random(2048) > 0.5, 1.0, -1.0).astype(np.float32))
        ref = cascade_svm(points, y, num_sv=16, steps=30, iterations=1, policy=POL)
        res = cascade_svm(
            points, y, num_sv=16, steps=30, iterations=1, policy=POL,
            executor=cluster,
        )
        assert identical(res.sv_x, ref.sv_x)
        assert identical(res.sv_y, ref.sv_y)


# ---------------------------------------------------------------------------
# the acceptance numbers: one merged partial per location per execute
# ---------------------------------------------------------------------------


def test_driver_receives_one_merged_partial_per_location(points):
    """N units over L locations: p2p_bytes == N·S, driver_merge_bytes == L·S."""
    plan = (
        Collection.from_blocked(points)
        .split(POL)  # 2 locations × 2 partitions = 4 units
        .map_blocks(lambda b: jnp.sum(b, axis=0))
        .reduce(lambda a, p: a + p)
    )
    with engine("local") as ex:
        ref = plan.compute(executor=ex)
    pinned_bytes = ref.report.driver_merge_bytes
    with _pool() as ex:
        res = plan.compute(executor=ex)
    assert identical(res.value, ref.value)
    rep = res.report
    partial = rep.p2p_bytes // 4  # 4 member partials crossed peer-side...
    assert partial > 0 and rep.p2p_bytes == 4 * partial
    # ...and the driver folded exactly one merged value per location
    assert rep.driver_merge_bytes == 2 * partial
    assert pinned_bytes == 4 * partial  # the pinned path moved N·S


def test_fold_tree_shape_is_deterministic():
    """The fold plan is a pure function of (index, location) pairs — the
    replay/resume contract: any re-lowering of the same plan rebuilds the
    exact tree, so a resumed or replayed subtree folds in the same order.
    """
    entries = [(0, 1), (1, 1), (2, 0), (3, 0), (4, 1), (5, 2)]
    assert fold_plan(entries) == fold_plan(list(entries))
    assert fold_plan(entries) == ((1, (0, 1, 4)), (0, (2, 3)), (2, (5,)))


def test_materialized_fold_units_identical_across_builds(points):
    """Two independent executors materialize identical fold subtrees for
    the same plan — indices, groups, locations and origins all match."""
    plan = (
        Collection.from_blocked(points)
        .split(POL)
        .map_blocks(lambda b: jnp.sum(b, axis=0))
        .reduce(lambda a, p: a + p)
        .plan()
    )

    def shape(ex):
        # the executor's own lowering path, minus scheduling
        spec = plan.spec
        policy, _ = ex._resolve_policy(spec)
        report = ex.engine.new_report(spec.policy.mode_name)
        prepared = ex._prepare(spec.inputs, policy, report)
        graph = lower(spec, prepared.arrays, prepared.groups, ex.capabilities)
        units, _state, _merge = ex._build_units(graph)
        return [
            (u.index, u.location, u.fold_group, u.origin.key)
            for u in units
            if u.kind == "fold"
        ]

    with _pool() as a, _pool() as b:
        sa, sb = shape(a), shape(b)
    assert sa and sa == sb


# ---------------------------------------------------------------------------
# faults mid-exchange: replay, attribution, zero leaks
# ---------------------------------------------------------------------------


def test_kill_peer_mid_exchange_replays_subtree(points):
    """A worker killed between publishing and folding: the subtree replays
    on a survivor, the result stays bit-identical, and every published
    segment — including the dead attempt's — is swept."""
    ref, _ = histogram(points, bins=8, policy=POL)
    with _pool(fault_plan=FaultPlan(kill_after=((0, 2),))) as ex:
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        assert identical(h, ref)
        assert rep.retries >= 1
        assert rep.p2p_bytes > 0 or rep.driver_merge_bytes > 0


def test_fold_failure_names_originating_task_key(points):
    """The ClusterFailedError satellite: a failure inside a worker-side
    fold attributes to the subtree's ORIGINATING app task, never the
    synthetic fold unit."""

    def colsum(b):
        return jnp.sum(b, axis=0)

    def bad_combine(acc, p):
        raise ValueError("injected combine failure")

    plan = (
        # Baseline: the combine first runs inside the FOLD unit (SplIter
        # would fuse it into the partition tasks and fail there instead).
        Collection.from_blocked(points)
        .split(Baseline())
        .map_blocks(colsum)
        .reduce(bad_combine)
    )
    with _pool() as ex:
        with pytest.raises(ClusterFailedError) as ei:
            plan.compute(executor=ex)
    # task_key names the app-level map task the fold subtree folds over —
    # not the synthetic fold unit (which has no task of its own).
    assert ei.value.task_key is not None
    assert "colsum" in ei.value.task_key
    assert "merge fold of" in str(ei.value)
    assert "injected combine failure" in str(ei.value)


@pytest.mark.parametrize("seed", [3, 7])
def test_chaos_rounds_with_p2p_exact_accounting(points, seed):
    """ChaosSchedule rounds with p2p forced on: kills, stragglers and
    steals compose with the exchange — results stay bit-identical and
    ``p2p_bytes`` stays EXACT (every member partial consumed exactly
    once, however its unit was routed)."""
    cs = ChaosSchedule(seed=seed, rounds=3)
    ref, _ = histogram(points, bins=8, policy=POL)
    with _pool() as clean:
        _, clean_rep = histogram(points, bins=8, policy=POL, executor=clean)
    expected_p2p = clean_rep.p2p_bytes
    assert expected_p2p > 0
    with _pool(
        fault_plan=cs.fault_plan(), steal=True, max_workers=8
    ) as ex:
        applied = 0
        reports = []
        for action in cs.actions():
            if action == "grow":
                applied += ex.grow() is not None
            elif action == "shrink":
                applied += ex.shrink() is not None
            h, rep = histogram(points, bins=8, policy=POL, executor=ex)
            assert identical(h, ref)
            assert rep.p2p_bytes == expected_p2p  # exact, per execute
            reports.append(rep)
        assert sum(r.steals for r in reports) == len(ex.steal_log)
        assert sum(r.retries for r in reports) == len(ex.retry_log)
        assert len(ex.scale_log) == applied


# ---------------------------------------------------------------------------
# the cost gate: auto stays pinned for small partials, switches for big
# ---------------------------------------------------------------------------


def test_auto_gate_keeps_small_partials_pinned(points):
    """Default ``p2p="auto"``: tiny accumulators never leave the pinned
    path — the structural counters stay exactly PR 7's."""
    ref, ref_rep = histogram(points, bins=8, policy=POL)
    with _pool(p2p="auto") as ex:
        for _ in range(2):  # EMA populated after round 1; gate still says no
            h, rep = histogram(points, bins=8, policy=POL, executor=ex)
            assert identical(h, ref)
            assert rep.p2p_bytes == 0
            assert rep.dispatches == ref_rep.dispatches
            assert rep.merges == ref_rep.merges


def test_auto_gate_switches_on_for_large_partials(points):
    """Iterative app with ≥64KB partials: execute 1 runs pinned (no
    evidence yet), execute 2 switches to peer folds off the observed EMA."""

    def big_partial(b):
        col = jnp.sum(b, axis=0)  # (4,)
        return jnp.tile(col, 65536 // 4)  # 64Ki float32 = 256KB partial

    plan = (
        Collection.from_blocked(points)
        .split(POL)
        .map_blocks(big_partial)
        .reduce(lambda a, p: a + p)
    )
    with engine("local") as ex:
        ref = plan.compute(executor=ex)
    with _pool(p2p="auto") as ex:
        first = plan.compute(executor=ex)
        second = plan.compute(executor=ex)
    assert identical(first.value, ref.value)
    assert identical(second.value, ref.value)
    assert first.report.p2p_bytes == 0  # no EMA yet: pinned
    assert second.report.p2p_bytes > 0  # gate saw 256KB partials: peer folds
    assert (
        second.report.driver_merge_bytes < first.report.driver_merge_bytes
    )


def test_baseline_policy_groups_blocks_per_location(points):
    """Baseline (one unit per block) still folds per location worker-side:
    8 blocks over 2 locations collapse to 2 driver partials."""
    plan = (
        Collection.from_blocked(points)  # 8 blocks, round-robin over 2 locs
        .split(Baseline())
        .map_blocks(lambda b: jnp.sum(b, axis=0))
        .reduce(lambda a, p: a + p)
    )
    with engine("local") as ex:
        ref = plan.compute(executor=ex)
    with _pool() as ex:
        res = plan.compute(executor=ex)
    assert identical(res.value, ref.value)
    rep = res.report
    partial = rep.p2p_bytes // points.num_blocks
    assert partial > 0 and rep.p2p_bytes == points.num_blocks * partial
    assert rep.driver_merge_bytes == 2 * partial
