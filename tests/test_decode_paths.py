"""Decode-path equivalence: the decomposed (old-cache ⊕ new-token) attention
must match the write-then-attend baseline exactly, incl. SWA ring wrap."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.sharding import decode_rules, use_rules
from repro.models import build_model


def _mesh11():
    from repro.launch.mesh import compat_make_mesh

    return compat_make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x7b", "qwen2-72b"])
def test_decomposed_decode_matches_masked(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    b, prompt, steps = 2, 6, 40  # 40 steps: wraps mixtral's window=32 ring
    toks = rng.integers(0, cfg.vocab_size, (b, prompt + steps), dtype=np.int32)
    total = prompt + steps

    rules_dec = dataclasses.replace(decode_rules(_mesh11()), cache_impl="decomposed")

    def run(decomposed: bool):
        cache = m.init_cache(b, total, dtype=jnp.float32)
        logits, cache = m.prefill(
            params, {"tokens": jnp.asarray(toks[:, :prompt])}, cache
        )
        outs = [logits]
        for t in range(prompt, total):
            tok = jnp.asarray(toks[:, t : t + 1], jnp.int32)
            if decomposed:
                with use_rules(rules_dec):
                    logits, cache = m.decode_step(
                        params, cache, tok, jnp.asarray(t, jnp.int32)
                    )
            else:
                logits, cache = m.decode_step(
                    params, cache, tok, jnp.asarray(t, jnp.int32)
                )
            outs.append(logits)
        return np.stack([np.asarray(o) for o in outs], 1)

    base = run(False)
    dec = run(True)
    np.testing.assert_allclose(dec, base, rtol=2e-5, atol=2e-5)
