"""Multi-device distribution tests, subprocess-isolated so the main pytest
process keeps 1 device (dry-run spec): hierarchical/compressed collectives,
the GPipe executor, a sharded multi-pod train step, and elastic restore."""

import os
import subprocess
import sys

import pytest

CHILD = os.path.join(os.path.dirname(__file__), "_dist_child.py")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(mode: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, CHILD, mode],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"{mode} failed:\n{out.stdout}\n{out.stderr}"
    assert f"OK {mode}" in out.stdout


@pytest.mark.parametrize(
    "mode",
    [
        "hier_psum",
        "compressed_psum",
        "gpipe",
        "sharded_train",
        "elastic_restore",
        "cache_write",
        "heads_cache",
        "mesh_exec",
    ],
)
def test_distributed(mode):
    _run(mode)
