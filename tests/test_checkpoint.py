"""Checkpointer crash-mid-save semantics — now load-bearing (DESIGN.md §12).

The JobServer snapshots scheduler state through
:class:`repro.checkpoint.checkpointer.Checkpointer`, so the atomic-commit
contract graduates from dormant to tier-1:

* a ``.tmp`` directory (crash before the rename) is invisible to restore;
* a step directory WITHOUT its COMMITTED marker (crash between rename and
  marker) is equally invisible;
* the newest COMMITTED step wins, regardless of junk written after it;
* :meth:`load_manifest` reads extras template-free — the JobServer resume
  path, which persists no array leaves at all.
"""

from __future__ import annotations

import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(value: float):
    return {"w": jnp.full((4, 2), value), "b": jnp.full((2,), value)}


def _save(ckpt: Checkpointer, step: int, value: float, **extras):
    ckpt.save(step, _tree(value), extras=dict(extras) or None)


class TestCrashMidSave:
    def test_tmp_dir_without_commit_is_skipped(self, tmp_path):
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        _save(ckpt, 1, 1.0)
        # simulate a crash mid-save of step 2: the .tmp directory exists
        # (with a plausible manifest!) but was never renamed or committed
        tmp = os.path.join(root, "step_000000002.tmp")
        os.makedirs(tmp)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": 2, "extras": {"poison": True}}, f)
        assert ckpt.latest_step() == 1
        tree, extras, step = ckpt.restore(_tree(0.0))
        assert step == 1
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.full((4, 2), 1.0))

    def test_renamed_dir_without_marker_is_skipped(self, tmp_path):
        # crash in the window between os.rename and the marker write: the
        # final directory exists and looks complete, but was never committed
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        _save(ckpt, 1, 1.0)
        _save(ckpt, 2, 2.0)
        os.remove(os.path.join(root, "step_000000002.COMMITTED"))
        assert ckpt.latest_step() == 1
        _, _, step = ckpt.restore(_tree(0.0))
        assert step == 1

    def test_newest_committed_step_wins(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        for step, value in ((1, 1.0), (5, 5.0), (3, 3.0)):
            _save(ckpt, step, value)
        assert ckpt.latest_step() == 5
        tree, _, step = ckpt.restore(_tree(0.0))
        assert step == 5
        np.testing.assert_array_equal(np.asarray(tree["b"]), np.full((2,), 5.0))

    def test_restore_explicit_step_requires_its_marker(self, tmp_path):
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        _save(ckpt, 1, 1.0)
        _save(ckpt, 2, 2.0)
        os.remove(os.path.join(root, "step_000000002.COMMITTED"))
        with pytest.raises(AssertionError, match="uncommitted"):
            ckpt.restore(_tree(0.0), step=2)

    def test_empty_root_has_no_checkpoint(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        assert ckpt.latest_step() is None
        with pytest.raises(AssertionError, match="no committed checkpoint"):
            ckpt.restore(_tree(0.0))


class TestLoadManifest:
    def test_reads_extras_without_template(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        _save(ckpt, 7, 1.0, tenant_pass={"alice": 2.5}, jobs=3)
        manifest, step = ckpt.load_manifest()
        assert step == 7
        assert manifest["extras"] == {"tenant_pass": {"alice": 2.5}, "jobs": 3}
        assert len(manifest["leaves"]) == 2  # w and b, described not loaded

    def test_zero_leaf_snapshot_round_trips(self, tmp_path):
        # the JobServer shape: pure-JSON extras, empty pytree
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, {}, extras={"state": [1, 2, 3]})
        manifest, step = ckpt.load_manifest()
        assert (manifest["extras"]["state"], step) == ([1, 2, 3], 1)
        assert manifest["leaves"] == []

    def test_skips_uncommitted_and_raises_when_none(self, tmp_path):
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        with pytest.raises(FileNotFoundError):
            ckpt.load_manifest()
        _save(ckpt, 2, 2.0, marker="good")
        _save(ckpt, 4, 4.0, marker="uncommitted")
        os.remove(os.path.join(root, "step_000000004.COMMITTED"))
        manifest, step = ckpt.load_manifest()
        assert (step, manifest["extras"]["marker"]) == (2, "good")


class TestRetention:
    def test_keep_last_drops_old_committed_steps(self, tmp_path):
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        for step in (1, 2, 3, 4):
            _save(ckpt, step, float(step))
        ckpt.keep_last(2)
        assert sorted(
            int(f[len("step_"):-len(".COMMITTED")])
            for f in os.listdir(root)
            if f.endswith(".COMMITTED")
        ) == [3, 4]
        # the dropped steps' directories are gone too
        assert not os.path.exists(os.path.join(root, "step_000000001"))
        _, _, step = ckpt.restore(_tree(0.0))
        assert step == 4

    def test_keep_last_ignores_uncommitted_junk(self, tmp_path):
        root = str(tmp_path)
        ckpt = Checkpointer(root)
        _save(ckpt, 1, 1.0)
        os.makedirs(os.path.join(root, "step_000000009.tmp"))
        ckpt.keep_last(1)  # must not trip over the .tmp dir
        assert ckpt.latest_step() == 1
