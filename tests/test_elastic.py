"""Elastic ClusterExecutor — work stealing, autoscaling, chaos harness.

The acceptance contract of DESIGN.md §15:

* under a seeded :class:`ChaosSchedule` (kills + stragglers + grow/shrink
  between rounds) every run stays bit-identical to LocalExecutor, and the
  ``steals`` / ``retries`` / ``scale_events`` report counters reconcile
  EXACTLY against the executor's event logs — one log entry per billed
  event, no slop;
* a straggler (one worker slowed via the fault hook) triggers work
  stealing (``steals > 0``) with zero retries: a steal is a scheduling
  decision, not a failure;
* planned scale-down drains through the same requeue/replay path as a
  kill — bit-identical results, ``retries == 0`` (attempts refunded),
  ``scale_events`` billed;
* the heartbeat debouncer counts only *observed* silence, so a stalled
  driver (GC pause, laptop sleep) can no longer bury idle workers;
* ``_SchedulerState`` ownership invariants hold under arbitrary
  assign/steal/kill/preempt/complete interleavings: every unit completes
  exactly once, a live claim can never be doubled, attempts never go
  negative;
* no ``/dev/shm`` segment outlives any executor, and every dispatch pin
  is released exactly once (``ShmStore.pinned_segments()`` is empty once
  a run settles).

The CI ``elastic-chaos-lane`` job runs exactly this module with
``REPRO_CLUSTER_LOG_DIR`` set, uploading per-worker logs and junit on
failure and asserting ``/dev/shm`` is clean afterwards.

All block functions are module-level: ClusterExecutor workers are spawned
processes and must re-import them by qualified name.
"""

from __future__ import annotations

import os
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ChaosSchedule,
    ClusterExecutor,
    Collection,
    FaultPlan,
    LocalExecutor,
    SplIter,
    shm_available,
)
from repro.api.autotune import CostModel, should_steal, steal_cost_estimate
from repro.api.executors import _SchedulerState, _Unit
from repro.api.shm import leaked_segments
from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.blocked import BlockedArray, round_robin_placement

try:  # optional in the execution environment; CI installs it
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised where hypothesis is absent
    HAVE_HYPOTHESIS = False

LOG_DIR = os.environ.get("REPRO_CLUSTER_LOG_DIR")  # CI chaos lane artifacts
POL = SplIter(partitions_per_location=4)
CHAOS_SEEDS = (11, 23, 47)  # the CI lane's fixed, replayable seeds
needs_shm = pytest.mark.skipif(
    not shm_available(), reason="host has no POSIX shared memory"
)


def _cluster(**kw) -> ClusterExecutor:
    kw.setdefault("log_dir", LOG_DIR)
    return ClusterExecutor(**kw)


def _blocked(a, block_rows=256, locs=2) -> BlockedArray:
    return BlockedArray.from_array(
        jnp.asarray(a), block_rows, num_locations=locs, policy=round_robin_placement
    )


@pytest.fixture(scope="module")
def points() -> BlockedArray:
    rng = np.random.default_rng(0)
    return _blocked(rng.random((2048, 4)).astype(np.float32))


def identical(a, b) -> bool:
    return bool(jnp.all(jnp.equal(a, b)))


# -- module-level block fns for the mid-run preemption plan ------------------


def _partial(b, c):
    return (b * c).sum(axis=0)


def _combine(a, b):
    return a + b


# ---------------------------------------------------------------------------
# ChaosSchedule: seeded, replayable fault + elasticity schedules
# ---------------------------------------------------------------------------


class TestChaosSchedule:
    def test_deterministic(self):
        for seed in CHAOS_SEEDS:
            a, b = ChaosSchedule(seed=seed), ChaosSchedule(seed=seed)
            assert a.fault_plan() == b.fault_plan()
            assert a.actions() == b.actions()

    def test_seeds_differ(self):
        plans = {ChaosSchedule(seed=s).fault_plan() for s in range(16)}
        assert len(plans) > 1  # the seed actually steers the schedule

    def test_first_round_unscaled_and_shrink_never_outruns_growth(self):
        for seed in range(32):
            acts = ChaosSchedule(seed=seed, rounds=6).actions()
            assert acts[0] == "none"
            grown = 0
            for a in acts:
                grown += {"grow": 1, "shrink": -1}.get(a, 0)
                assert grown >= 0

    def test_kill_and_slow_target_different_workers(self):
        for seed in range(32):
            plan = ChaosSchedule(seed=seed).fault_plan()
            killed = {w for w, _ in plan.kill_after}
            slowed = {w for w, _ in plan.slow}
            assert not (killed & slowed)


# ---------------------------------------------------------------------------
# the chaos matrix: kills + stragglers + grow/shrink, bit-identical + exact
# accounting, zero leaked segments  (CI: elastic-chaos-lane)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_rounds_bit_identical_with_exact_accounting(points, seed):
    cs = ChaosSchedule(seed=seed, rounds=3)
    ref, _ = histogram(points, bins=8, policy=POL)
    ex = _cluster(fault_plan=cs.fault_plan(), steal=True, max_workers=8)
    applied = 0
    reports = []
    try:
        for action in cs.actions():
            if action == "grow":
                applied += ex.grow() is not None
            elif action == "shrink":
                applied += ex.shrink() is not None
            h, rep = histogram(points, bins=8, policy=POL, executor=ex)
            assert identical(h, ref)
            reports.append(rep)
        # the accounting contract: counters reconcile exactly vs the logs
        assert sum(r.steals for r in reports) == len(ex.steal_log)
        assert sum(r.retries for r in reports) == len(ex.retry_log)
        assert len(ex.scale_log) == applied
        if cs.fault_plan().kill_after:
            assert len(ex.retry_log) >= 1  # the kill really fired
    finally:
        ex.close()
    assert leaked_segments() == []


# ---------------------------------------------------------------------------
# the straggler: one slowed worker -> steals > 0, zero retries, identical
# ---------------------------------------------------------------------------


def test_straggler_triggers_steals_bit_identical(points):
    ref = kmeans(points, k=4, iters=3, policy=POL)
    ex = _cluster(fault_plan=FaultPlan(slow=((0, 0.05),)), steal=True)
    try:
        res = kmeans(points, k=4, iters=3, policy=POL, executor=ex)
        steals = sum(r.steals for r in res.reports)
        assert steals > 0  # the straggler's queue really was raided
        assert steals == len(ex.steal_log)  # exact, not approximate
        assert identical(res.centers, ref.centers)
        # a steal is a scheduling decision, not a failure
        assert sum(r.retries for r in res.reports) == 0
        assert ex.retry_log == []
        if ex._shm is not None:
            # every dispatch pin (including the voided victim dispatches)
            # was released exactly once: nothing stays pinned at rest
            assert ex._shm.pinned_segments() == {}
    finally:
        ex.close()
    assert leaked_segments() == []


def test_steal_disabled_by_default(points):
    ex = _cluster(fault_plan=FaultPlan(slow=((0, 0.02),)))
    try:
        res = kmeans(points, k=4, iters=2, policy=POL, executor=ex)
        assert sum(r.steals for r in res.reports) == 0
        assert ex.steal_log == []
    finally:
        ex.close()


# ---------------------------------------------------------------------------
# planned scale-down == deliberate preemption through the replay path
# ---------------------------------------------------------------------------


def test_midrun_preemption_is_bit_identical_and_free_of_retries():
    """Shrink a worker with units in flight: the drain is the kill path,
    but attempts are refunded and nothing bills retries."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.random((512, 8), np.float32))
    c = jnp.ones((8,))

    def plan():
        return (
            Collection.from_array(x, block_rows=64, num_locations=2)
            .split(SplIter(partitions_per_location=2))
            .map_blocks(_partial, extra_args=(c,))
            .reduce(_combine)
        )

    ref = plan().compute(executor=LocalExecutor())
    # worker 0 is slowed so its queue is provably non-empty at shrink time
    ex = _cluster(fault_plan=FaultPlan(slow=((0, 0.1),)))
    try:
        fut = plan().compute_async(executor=ex)
        assert ex.shrink(0) == 0  # preempt the busy owner mid-run
        res = fut.result()
        assert identical(res.value, ref.value)
        assert res.report.retries == 0 and ex.retry_log == []
        assert res.report.scale_events == 1
        assert ex.scale_log == [{"event": "shrink", "worker": 0}]
    finally:
        ex.close()
    assert leaked_segments() == []


def test_grow_shrink_between_runs(points):
    ref, _ = histogram(points, bins=8, policy=POL)
    ex = _cluster(steal=True, max_workers=8)
    try:
        h0, _ = histogram(points, bins=8, policy=POL, executor=ex)
        wid = ex.grow()
        assert wid is not None and wid in ex.workers_alive()
        h1, rep1 = histogram(points, bins=8, policy=POL, executor=ex)
        assert ex.shrink() == wid  # the idle roamer retires first
        assert wid not in ex.workers_alive()
        h2, rep2 = histogram(points, bins=8, policy=POL, executor=ex)
        assert identical(h0, ref) and identical(h1, ref) and identical(h2, ref)
        assert rep1.retries == 0 and rep2.retries == 0
        assert [e["event"] for e in ex.scale_log] == ["grow", "shrink"]
    finally:
        ex.close()
    assert leaked_segments() == []


def test_grow_respects_max_workers():
    ex = _cluster(max_workers=1)
    try:
        assert ex.grow() is not None  # pool empty: first roamer fits
        assert ex.grow() is None  # at the ceiling
        assert len(ex.workers_alive()) == 1
    finally:
        ex.close()


def test_autoscaler_grows_under_backlog(points):
    ref, _ = histogram(points, bins=8, policy=POL)
    ex = _cluster(autoscale=True, scale_up_backlog=1, max_workers=6)
    try:
        reports = []
        for _ in range(2):
            h, rep = histogram(points, bins=8, policy=POL, executor=ex)
            assert identical(h, ref)
            reports.append(rep)
        assert any(e["event"] == "grow" for e in ex.scale_log)
        # autoscaler events happen inside runs, so report sums reconcile
        assert sum(r.scale_events for r in reports) == len(ex.scale_log)
        assert sum(r.retries for r in reports) == 0
    finally:
        ex.close()
    assert leaked_segments() == []


# ---------------------------------------------------------------------------
# heartbeat debounce: a stalled driver must not bury idle workers
# ---------------------------------------------------------------------------


def test_stalled_driver_does_not_bury_idle_workers(points):
    """Regression: staleness used to be wall-clock since the last
    heartbeat, so a driver that did not pump for heartbeat_timeout_s
    (GC pause, laptop sleep, a long in-process merge) declared every
    idle worker hung and respawned the pool.  The debouncer counts only
    *observed* silence — time the driver actually spent pumping — capped
    per check, so a stall of any length adds at most one capped tick."""
    ref, _ = histogram(points, bins=8, policy=POL)
    ex = _cluster()
    try:
        histogram(points, bins=8, policy=POL, executor=ex)
        alive = ex.workers_alive()
        assert alive
        # simulate a 500s driver stall: both clocks say "ancient"
        before = dict(ex._silence)
        ex._last_pump -= 500.0
        for wid in list(ex._last_hb):
            ex._last_hb[wid] -= 500.0
        ex._check_workers()
        assert ex.workers_alive() == alive  # nobody buried
        # the stall contributed at most one capped tick of silence
        cap = max(ex.poll_s, ex.heartbeat_s) * 4
        assert all(
            s - before.get(wid, 0.0) <= cap + 1e-6
            for wid, s in ex._silence.items()
        )
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        assert identical(h, ref) and rep.retries == 0
    finally:
        ex.close()


def test_truly_silent_worker_is_still_buried(points):
    """The debouncer must not break real hang detection: a muted worker
    (replies suppressed by the fault hook) accumulates observed silence
    across pumps and exceeds the timeout."""
    ref, _ = histogram(points, bins=8, policy=POL)
    ex = _cluster(
        fault_plan=FaultPlan(mute_after=((0, 2),)), heartbeat_timeout_s=2.0
    )
    try:
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        assert identical(h, ref)
        assert rep.retries >= 1  # the mute was detected and replayed
        assert len(ex.retry_log) == rep.retries
    finally:
        ex.close()


# ---------------------------------------------------------------------------
# the steal cost gate (autotune)
# ---------------------------------------------------------------------------


class TestStealCostGate:
    def test_fitted_model_waits_vs_fetch(self):
        model = CostModel(c0=0.0, c1=0.01, c2=0.0)
        wait, fetch = steal_cost_estimate(model, queued_tasks=4, span=1)
        assert wait == pytest.approx(0.04)
        assert fetch >= 0.01  # one extra dispatch to the thief
        assert should_steal(model, queued_tasks=4)
        assert not should_steal(model, queued_tasks=0)

    def test_bytes_bite_only_off_the_shm_plane(self):
        model = CostModel(c0=0.0, c1=0.001, c2=0.0)
        # descriptors (shm on): cheap fetch, steal approved
        assert should_steal(model, queued_tasks=8, operand_bytes=0)
        # raw operands over a pipe: fetch dwarfs the wait, steal rejected
        assert not should_steal(
            model, queued_tasks=8, operand_bytes=1 << 30
        )

    def test_fallback_profile_estimate(self):
        wait, fetch = steal_cost_estimate(
            None, queued_tasks=3, fallback_task_s=0.2
        )
        assert wait == pytest.approx(0.6)
        assert should_steal(None, queued_tasks=3, fallback_task_s=0.2)


# ---------------------------------------------------------------------------
# _SchedulerState ownership invariants (the property suite)
# ---------------------------------------------------------------------------


def _make_state(n=6):
    units = [
        _Unit(index=i, location=i % 2, tasks=(), run=None) for i in range(n)
    ]
    units.append(
        _Unit(
            index=n, location=-1, tasks=(), run=None,
            deps=tuple(range(n)), kind="merge",
        )
    )
    return _SchedulerState(units), units


class TestOwnershipInvariants:
    def test_live_double_claim_raises(self):
        state, units = _make_state()
        state.assign(units[0], "w1")
        with pytest.raises(RuntimeError, match="double-claimed"):
            state.assign(units[0], "w2")
        state.assign(units[0], "w1")  # same owner re-assign is idempotent

    def test_assign_after_completion_raises(self):
        state, units = _make_state()
        state.assign(units[0], "w1")
        state.complete(units[0], 0)
        with pytest.raises(RuntimeError, match="after completion"):
            state.assign(units[0], "w2")

    def test_release_moves_ownership_and_refunds_the_attempt(self):
        state, units = _make_state()
        state.assign(units[0], "w1")
        assert state.release(units[0])  # the steal grant
        assert units[0].index not in state.owner
        assert state.attempts[units[0].index] == 0  # refunded
        state.assign(units[0], "w2")  # the thief's claim is legal
        assert state.attempts[units[0].index] == 1  # net zero for the steal

    def test_release_is_stale_safe(self):
        state, units = _make_state()
        assert not state.release(units[0])  # never owned
        state.assign(units[0], "w1")
        state.complete(units[0], 0)
        assert not state.release(units[0])  # completed: grant is stale

    def test_requeue_then_reassign(self):
        state, units = _make_state()
        state.assign(units[0], "w1")
        state.assign(units[1], "w1")
        state.complete(units[1], 1)
        lost = state.requeue("w1")
        assert [u.index for u in lost] == [0]  # completed unit not replayed
        state.assign(units[0], "w2")  # post-death claim is legal

    def test_refund_never_goes_negative(self):
        state, units = _make_state()
        state.refund_attempt(0)
        assert state.attempts[0] == 0
        state.assign(units[0], "w1")
        state.refund_attempt(0)
        state.refund_attempt(0)
        assert state.attempts[0] == 0

    def _chaos_run(self, rng: random.Random, n=6, steps=200):
        """Drive one seeded interleaving of assign / steal / kill /
        preempt / complete; return completion counts per unit."""
        state, units = _make_state(n)
        owners = ["w0", "w1", "w2"]
        completed = [0] * len(units)
        for _ in range(steps):
            op = rng.choice(("assign", "steal", "kill", "preempt", "complete"))
            u = units[rng.randrange(len(units))]
            if op == "assign":
                prev = state.owner.get(u.index)
                owner = rng.choice(owners)
                if state.is_done(u.index) or (prev is not None and prev != owner):
                    with pytest.raises(RuntimeError):
                        state.assign(u, owner)
                else:
                    state.assign(u, owner)
            elif op == "steal":
                before = state.attempts[u.index]
                if state.release(u):
                    assert state.attempts[u.index] == max(0, before - 1)
                    state.assign(u, rng.choice(owners))  # thief re-claims
            elif op == "kill":
                owner = rng.choice(owners)
                for lost in state.requeue(owner):
                    assert not state.is_done(lost.index)
                    state.assign(lost, rng.choice(owners))  # survivor replay
            elif op == "preempt":
                if state.release(u):
                    state.assign(u, rng.choice(owners))
            elif op == "complete" and u.index in state.owner:
                if not state.is_done(u.index):
                    state.complete(u, u.index)
                    completed[u.index] += 1
            assert all(v >= 0 for v in state.attempts.values())
        # drain: everything completes exactly once, whatever happened above
        for u in units:
            if not state.is_done(u.index):
                if u.index not in state.owner:
                    state.assign(u, "w0")
                state.complete(u, u.index)
                completed[u.index] += 1
            assert state.complete(u, -1) == []  # duplicates are dropped
        assert completed == [1] * len(units)
        assert state.done.is_set()

    def test_seeded_interleavings(self):
        """Deterministic fallback for environments without hypothesis —
        the same invariants over a fixed fan of seeds."""
        for seed in range(25):
            self._chaos_run(random.Random(seed))

    if HAVE_HYPOTHESIS:

        @settings(max_examples=200, deadline=None)
        @given(st.integers(min_value=0, max_value=2**31 - 1))
        def test_property_interleavings(self, seed):
            self._chaos_run(random.Random(seed))

    else:  # pragma: no cover - the gated twin of the property test

        @pytest.mark.skip(reason="hypothesis not installed")
        def test_property_interleavings(self):
            pass
