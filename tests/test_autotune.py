"""Adaptive granularity: profile store, cost model, tuner schedule, and the
regroup-without-resplit prepare-cache contract (DESIGN.md §9).

The fast lane (`pytest -q tests/test_autotune.py` — its own CI job): these
tests avoid the full policy×dataset grid and assert the *structural*
guarantees of the subsystem — deterministic probe schedules, ≤3 retunes,
zero re-splits and zero bytes moved across retunes — plus end-to-end
`SplIter(partitions_per_location="auto")` runs on all three backends.
"""


import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Autotuner,
    Collection,
    CostModel,
    LocalExecutor,
    MeshExecutor,
    SplIter,
    ThreadedExecutor,
    as_policy,
    fit_cost_model,
)
from repro.api.autotune import granularity_features
from repro.core.blocked import BlockedArray, round_robin_placement
from repro.core.spliter import spliter

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _blocked(rows, block_rows, locs, d=3, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(rows, d)).astype(np.float32)
    return pts, BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )


def _sum_plan(ba, pol):
    return (
        Collection.from_blocked(ba)
        .split(pol)
        .map_blocks(lambda b: jnp.sum(b, 0))
        .reduce(lambda a, b: a + b)
    )


AUTO = SplIter(partitions_per_location="auto")


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


class TestCostModel:
    def test_granularity_features(self):
        # 3 locations holding 8/8/5 blocks
        counts = (8, 8, 5)
        assert granularity_features(counts, 1) == (3, 8)
        assert granularity_features(counts, 2) == (6, 4)
        assert granularity_features(counts, 8) == (8 + 8 + 5, 1)
        # ppl beyond the block count saturates per location
        assert granularity_features(counts, 100) == (21, 1)
        # empty locations contribute nothing
        assert granularity_features((4, 0, 4), 1) == (2, 4)

    def test_fit_recovers_synthetic_model(self):
        true = CostModel(c0=0.05, c1=0.002, c2=0.010)
        counts = (16, 16)
        samples = [
            (*granularity_features(counts, p), true.predict(*granularity_features(counts, p)))
            for p in (1, 4, 16)
        ]
        fit = fit_cost_model(samples)
        for p in (1, 2, 8, 16):
            n, s = granularity_features(counts, p)
            assert fit.predict(n, s) == pytest.approx(true.predict(n, s), rel=1e-6)

    def test_fit_clamps_negative_coefficients(self):
        # Walls DECREASING with task count would fit c1 < 0 — clamped so the
        # model never predicts that infinite tasks are free.
        samples = [(2, 8, 1.0), (4, 4, 0.6), (16, 1, 0.1)]
        fit = fit_cost_model(samples)
        assert fit.c1 >= 0.0 and fit.c2 >= 0.0 and fit.c0 >= 0.0

    def test_underdetermined_fit_uses_overhead_hint(self):
        assert fit_cost_model([(2, 8, 1.0)]) is None
        hinted = fit_cost_model([(2, 8, 1.0)], overhead_hint_s=0.01)
        assert hinted is not None
        assert hinted.c1 == pytest.approx(0.01)
        # anchored at the sample: predict(sample) == sample wall
        assert hinted.predict(2, 8) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# the tuner schedule
# ---------------------------------------------------------------------------


def _drive(tuner, wall_fn, iters):
    """Run the propose/observe loop against a synthetic wall model."""
    trajectory = []
    for _ in range(iters):
        p = tuner.propose()
        trajectory.append(p)
        tuner.observe(p, wall_fn(p))
    return trajectory


class TestAutotunerSchedule:
    def test_probe_ladder_is_deterministic(self):
        t1 = Autotuner([8, 8], seed=0)
        t2 = Autotuner([8, 8], seed=0)
        assert t1.ladder == t2.ladder == [1, 2, 4, 8]
        assert t1.probe_plan == t2.probe_plan == [1, 2, 4]

    def test_seed_rotates_probe_order_not_set(self):
        plans = {tuple(Autotuner([8, 8], seed=s).probe_plan) for s in range(3)}
        assert len(plans) == 3                      # different orders
        assert all(sorted(p) == [1, 2, 4] for p in plans)  # same set

    def test_converges_within_three_retunes(self):
        # Tiny-Tasks-shaped truth: overhead per task + straggler span cost.
        true = CostModel(c0=0.01, c1=0.004, c2=0.003)
        counts = (16, 16, 16, 16)
        wall = lambda p: true.predict(*granularity_features(counts, p))
        tuner = Autotuner(counts, seed=0)
        traj = _drive(tuner, wall, iters=10)
        assert tuner.retunes <= 3
        # converged: the trajectory is constant once the schedule settles
        tail = traj[-4:]
        assert len(set(tail)) == 1
        # within 10% of the best hand-picked ppl on the synthetic truth
        best = min(wall(p) for p in tuner.ladder)
        assert wall(tail[0]) <= 1.10 * best

    @pytest.mark.parametrize("seed", [0, 1, 2, 5])
    def test_convergence_quality_any_seed(self, seed):
        true = CostModel(c0=0.02, c1=0.0015, c2=0.008)
        counts = (32, 32)
        wall = lambda p: true.predict(*granularity_features(counts, p))
        tuner = Autotuner(counts, seed=seed)
        traj = _drive(tuner, wall, iters=8)
        best = min(wall(p) for p in tuner.ladder)
        assert wall(traj[-1]) <= 1.10 * best
        assert tuner.retunes <= 3

    def test_budget_exhaustion_freezes(self):
        # walls rising with ppl: probes 1→2→4 (2 retunes) then back to 1 (3rd)
        tuner = Autotuner([8, 8], seed=0)
        _drive(tuner, lambda p: 0.1 + 0.01 * p, iters=6)
        assert tuner.retunes == 3 and tuner.propose() == 1
        # evidence for another granularity arriving AFTER the budget is
        # spent must never move the proposal
        tuner.observe(8, 1e-6)
        assert tuner.propose() == 1 and tuner.retunes == 3
        # the retarget gate itself: a blocked move freezes the schedule
        tuner._retarget(8)
        assert tuner.frozen and tuner.propose() == 1
        tuner.observe(2, 1e-9)                 # frozen: observe is inert
        assert tuner.propose() == 1

    def test_steady_state_revisit_can_refit_before_budget_runs_out(self):
        # Probe walls are trace-polluted (first visit recompiles); once the
        # pollution is corrected by steady-state revisits the model refits
        # and may still move — the docstring's measure→model→retune loop
        # stays closed after probing.
        counts = (8, 8)
        tuner = Autotuner(counts, seed=0)
        tuner.observe(1, 0.10, traced=True)
        tuner.observe(2, 0.12, traced=True)
        tuner.observe(4, 0.50, traced=True)   # pathological traced outlier
        p = tuner.propose()
        assert p == 1 and tuner.retunes == 3
        # honest steady-state walls: ppl=4 was actually the fast one
        tuner.observe(1, 0.10)                # incumbent revisit: no change
        assert tuner.propose() == 1
        tuner.observe(4, 0.01)                # untraced supersedes the outlier
        # refit happened; budget is spent so the proposal cannot move, but
        # the model now reflects the corrected sample
        assert tuner.samples[4].wall_s == 0.01
        assert tuner.propose() == 1 and tuner.retunes == 3

    def test_single_candidate_needs_no_retunes(self):
        tuner = Autotuner([1, 1, 1], seed=0)   # 1 block/location: ladder [1]
        traj = _drive(tuner, lambda p: 0.1, iters=3)
        assert traj == [1, 1, 1]
        assert tuner.retunes == 0 and tuner.propose() == 1

    def test_untraced_sample_supersedes_traced(self):
        tuner = Autotuner([8, 8], seed=0)
        tuner.observe(1, 5.0, traced=True)     # first visit pays re-tracing
        assert tuner.samples[1].wall_s == 5.0
        tuner.observe(1, 0.5, traced=False)    # steady state replaces it
        assert tuner.samples[1].wall_s == 0.5
        tuner.observe(1, 9.0, traced=True)     # later traced never regresses it
        assert tuner.samples[1].wall_s == 0.5


# ---------------------------------------------------------------------------
# regroup-without-resplit: the prepare-cache contract
# ---------------------------------------------------------------------------


class TestRegroupWithoutResplit:
    def test_ppl_change_regroups_without_resplit(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        for ppl in (1, 2, 4, 2, 1):
            res = _sum_plan(ba, SplIter(partitions_per_location=ppl)).compute(executor=ex)
            assert res.report.bytes_moved == 0
        st = ex.prepare_stats
        assert st.splits == 1          # ONE placement scan for five granularities
        assert st.regroups == 2        # ppl 2 and 4 derived logically; revisits cached
        assert st.hits == 4            # every execute after the first hit the base

    def test_regrouped_groups_equal_fresh_split(self):
        """The regroup path must yield block-for-block what spliter() yields."""
        _, ba = _blocked(97, 12, 3)   # ragged tail, rr placement
        ex = LocalExecutor()
        for ppl in (1, 2, 3, 4):
            prepared = ex._prepare((ba,), SplIter(partitions_per_location=ppl),
                                   ex.engine.report)
            want = [(p.location, p.block_ids)
                    for p in spliter(ba, partitions_per_location=ppl)]
            got = [(g.location, g.block_ids) for g in prepared.groups]
            assert got == want, f"ppl={ppl}"

    def test_materialize_and_fusion_share_the_split_base(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        _sum_plan(ba, SplIter()).compute(executor=ex)
        _sum_plan(ba, SplIter(materialize=True)).compute(executor=ex)
        _sum_plan(ba, SplIter(fusion="scan")).compute(executor=ex)
        assert ex.prepare_stats.splits == 1

    def test_rechunk_and_baseline_paths_unchanged(self):
        _, ba = _blocked(96, 8, 4)
        from repro.api import Baseline, Rechunk

        ex = LocalExecutor()
        r1 = _sum_plan(ba, Rechunk()).compute(executor=ex)
        r2 = _sum_plan(ba, Rechunk()).compute(executor=ex)
        assert r1.report.bytes_moved > 0 and r2.report.bytes_moved == 0
        assert ex.prepare_stats.rechunks == 1
        _sum_plan(ba, Baseline()).compute(executor=ex)
        assert ex.prepare_stats.splits == 0  # rechunk/baseline build no split base


# ---------------------------------------------------------------------------
# profiling layer
# ---------------------------------------------------------------------------


class TestProfileStore:
    def test_scheduler_populates_profiles(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        _sum_plan(ba, SplIter()).compute(executor=ex)
        profs = ex.profile.snapshot()
        kinds = {p.kind for p in profs}
        assert "partition_scan" in kinds and "merge" in kinds
        scan = next(p for p in profs if p.kind == "partition_scan")
        assert scan.calls == 4 and scan.tasks == 4         # one per location
        assert scan.blocks == ba.num_blocks
        assert scan.rows == 96
        assert scan.nbytes == 96 * 3 * 4                   # float32 (96,3)
        assert scan.wall_s >= scan.dispatch_s >= 0.0
        assert ex.profile.mean_task_overhead_s(("partition_scan",)) >= 0.0

    def test_profiles_key_on_signature_not_call(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        plan = _sum_plan(ba, SplIter())
        plan.compute(executor=ex)
        plan.compute(executor=ex)
        scan = [p for p in ex.profile.snapshot() if p.kind == "partition_scan"]
        assert len(scan) == 1            # same signature aggregates
        assert scan[0].calls == 8        # 4 tasks × 2 iterations

    def test_all_backends_emit_events(self):
        _, ba = _blocked(96, 8, 4)
        for mk in (LocalExecutor, ThreadedExecutor, MeshExecutor):
            ex = mk()
            _sum_plan(ba, SplIter()).compute(executor=ex)
            assert ex.profile.events, mk.__name__
            if hasattr(ex, "close"):
                ex.close()

    def test_mesh_records_sharded_units(self):
        _, ba = _blocked(96, 8, 4)
        ex = MeshExecutor()
        _sum_plan(ba, SplIter()).compute(executor=ex)
        sharded = [p for p in ex.profile.snapshot() if p.kind == "sharded"]
        assert len(sharded) == 1
        assert sharded[0].tasks == 4     # all four partitions in one dispatch


# ---------------------------------------------------------------------------
# SplIter("auto") end to end
# ---------------------------------------------------------------------------


class TestAutoPolicy:
    def test_as_policy_spelling(self):
        pol = as_policy("spliter_auto")
        assert pol == AUTO and pol.autotuned
        assert pol.mode_name == "spliter_auto"
        assert AUTO.mode_name == "spliter_auto"
        assert SplIter(2).mode_name == "spliter"

    def test_auto_requires_no_knob_and_matches_fixed(self):
        pts, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        plan = _sum_plan(ba, AUTO)
        for _ in range(6):
            res = plan.compute(executor=ex)
            np.testing.assert_allclose(
                np.asarray(res.value), pts.sum(0), rtol=2e-4, atol=2e-4
            )
            assert res.report.bytes_moved == 0
            assert res.report.granularity >= 1

    def test_retunes_move_zero_bytes_and_never_resplit(self):
        """The acceptance contract: granularity retunes between iterations
        are logical regroups — prepare-cache hits, zero block re-splits,
        bytes_moved == 0."""
        _, ba = _blocked(2 * 8 * 64, 64, 2)   # 8 blocks/location
        ex = LocalExecutor()
        plan = _sum_plan(ba, AUTO)
        reports = [plan.compute(executor=ex).report for _ in range(6)]
        retunes = sum(r.retunes for r in reports)
        assert retunes >= 2                    # the ladder was actually walked
        assert retunes <= 3                    # ...within the retune budget
        st = ex.prepare_stats
        assert st.splits == 1                  # ZERO re-splits across retunes
        assert st.regroups >= 2                # granularities served logically
        assert st.hits == 5                    # every later iteration hit the cache
        assert all(r.bytes_moved == 0 for r in reports)
        assert all(r.granularity >= 1 for r in reports)

    def test_auto_probes_ladder_then_settles(self):
        _, ba = _blocked(2 * 8 * 64, 64, 2)
        ex = LocalExecutor()
        plan = _sum_plan(ba, AUTO)
        traj = [plan.compute(executor=ex).report.granularity for _ in range(7)]
        assert traj[:3] == [1, 2, 4]           # deterministic probe prefix (seed 0)
        assert all(g in (1, 2, 4, 8) for g in traj)  # ladder members only
        (_, tuner), = ex._tuners.values()
        assert tuner.retunes <= 3              # bounded: ≤3 changes ever
        # eventual constancy is structural: executed changes never exceed
        # the tuner's retune count (a final observe may retarget once more
        # without another execution showing it), which is capped at 3
        changes = sum(a != b for a, b in zip(traj, traj[1:]))
        assert changes <= tuner.retunes

    def test_auto_seed_changes_probe_order(self):
        _, ba = _blocked(2 * 8 * 64, 64, 2)
        ex = LocalExecutor()
        plan = _sum_plan(ba, SplIter(partitions_per_location="auto", autotune_seed=1))
        traj = [plan.compute(executor=ex).report.granularity for _ in range(3)]
        assert traj == [2, 4, 1]               # rotated probe prefix

    @pytest.mark.parametrize("mk", [LocalExecutor, ThreadedExecutor, MeshExecutor],
                             ids=lambda c: c.__name__)
    def test_auto_matches_fixed_on_every_backend(self, mk):
        pts, ba = _blocked(97, 12, 3)          # ragged tail
        ex = mk()
        plan = _sum_plan(ba, AUTO)
        for _ in range(4):
            res = plan.compute(executor=ex)
            np.testing.assert_allclose(
                np.asarray(res.value), pts.sum(0), rtol=2e-4, atol=2e-4
            )
        assert ex.prepare_stats.splits == 1
        if hasattr(ex, "close"):
            ex.close()

    def test_distinct_workloads_get_distinct_tuners(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        _sum_plan(ba, AUTO).compute(executor=ex)
        (
            Collection.from_blocked(ba)
            .split(AUTO)
            .map_blocks(lambda b: jnp.max(b, 0))
            .reduce(jnp.maximum)
            .compute(executor=ex)
        )
        assert len(ex._tuners) == 2

    def test_lower_resolves_auto_for_inspection(self):
        _, ba = _blocked(96, 8, 4)
        ex = LocalExecutor()
        graph = ex.lower(_sum_plan(ba, AUTO).plan())
        assert all(t.kind == "partition_scan" for t in graph.tasks)


# ---------------------------------------------------------------------------
# the converging-ppl integration test (k-means, the paper's iterative app)
# ---------------------------------------------------------------------------


class TestKMeansAutoIntegration:
    def test_kmeans_auto_converges_and_never_resplits(self):
        from repro.core.apps.kmeans import kmeans

        rng = np.random.default_rng(0)
        pts = rng.random((2 * 4 * 256, 4)).astype(np.float32)
        x = BlockedArray.from_array(
            jnp.asarray(pts), 256, num_locations=2, policy=round_robin_placement
        )
        ex = LocalExecutor()
        res = kmeans(x, k=4, iters=8, policy=AUTO, executor=ex)

        # correctness: identical clustering to a hand-picked granularity
        ref = kmeans(x, k=4, iters=8, policy=SplIter(), executor=LocalExecutor())
        np.testing.assert_allclose(
            np.asarray(res.centers), np.asarray(ref.centers), rtol=2e-3, atol=2e-3
        )

        # convergence: ≤3 granularity changes ever — eventual constancy is
        # structural, not statistical
        assert res.total_retunes <= 3
        traj = res.granularity_trajectory
        assert all(g >= 1 for g in traj)
        assert sum(a != b for a, b in zip(traj, traj[1:])) <= 3

        # regroup-without-resplit: one split, zero bytes, later iters cached
        st = ex.prepare_stats
        assert st.splits == 1
        assert res.total_bytes_moved == 0
        assert st.hits == len(traj) - 1
