"""Doctests of the public ``repro.api`` surface, wired into tier-1.

Every ``>>>`` example in the API docstrings is executable documentation:
this module runs them all under the tier-1 command (plain
``pytest -x -q``), and the CI ``docs`` job additionally runs the literal
``pytest --doctest-modules src/repro/api`` form, so an example that drifts
from the implementation fails the build instead of lying in the docs.
"""

from __future__ import annotations

import doctest
import importlib

import pytest

API_MODULES = (
    "repro.api.autotune",
    "repro.api.chunkstore",
    "repro.api.cluster_executor",
    "repro.api.cluster_worker",
    "repro.api.collection",
    "repro.api.executors",
    "repro.api.fnref",
    "repro.api.kernels",
    "repro.api.lowering",
    "repro.api.mesh_executor",
    "repro.api.plan",
    "repro.api.policy",
    "repro.api.profile",
    "repro.api.stream_executor",
)


@pytest.mark.parametrize("module_name", API_MODULES)
def test_api_doctests(module_name):
    module = importlib.import_module(module_name)
    result = doctest.testmod(
        module,
        optionflags=doctest.NORMALIZE_WHITESPACE | doctest.ELLIPSIS,
        verbose=False,
    )
    assert result.failed == 0, f"{result.failed} doctest failure(s) in {module_name}"


def test_public_surface_has_examples():
    """The satellite contract: the named public objects carry runnable
    examples (at least one ``>>>`` in their docstring)."""
    from repro.api import (
        Autotuner,
        ChunkStore,
        Collection,
        Executor,
        SplIter,
    )

    for obj in (SplIter, Collection, Executor, Autotuner, ChunkStore):
        doc = obj.__doc__ or ""
        assert ">>>" in doc, f"{obj.__name__} docstring has no runnable example"
