"""Child process for multi-device distribution tests.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set by
the parent (tests/test_distributed.py) so the main pytest process keeps
seeing 1 device (per the dry-run spec).  Each mode asserts internally and
exits 0 on success.
"""

import sys

import numpy as np


def _mesh(shape, axes):
    from repro.launch.mesh import compat_make_mesh

    return compat_make_mesh(shape, axes)


def check_hierarchical_psum() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map
    from repro.distributed.collectives import hierarchical_psum

    mesh = _mesh((2, 4), ("pod", "data"))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)), jnp.float32)

    def flat(v):
        return jax.lax.psum(v, ("data", "pod"))

    def hier(v):
        return hierarchical_psum(v, fast_axis="data", slow_axis="pod")

    sm = lambda f: shard_map(
        f, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False
    )
    got = sm(hier)(x)
    want = sm(flat)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # and it matches 8 × x (replicated input summed over 8 ranks)
    np.testing.assert_allclose(np.asarray(got), 8 * np.asarray(x), rtol=1e-5)


def check_compressed_psum() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map
    from repro.distributed.collectives import compressed_psum_pod

    mesh = _mesh((2, 4), ("pod", "data"))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((16, 32)), jnp.float32)

    got = shard_map(
        lambda v: compressed_psum_pod(v, fast_axis="data", slow_axis="pod"),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
    )(x)
    want = 8 * np.asarray(x)
    err = np.abs(np.asarray(got) - want)
    # int8 per-row quantization: |err| ≤ pods · scale/2, scale = rowmax/127
    bound = 2 * (np.abs(want).max(axis=-1, keepdims=True) / 127.0) * 1.01 + 1e-6
    assert (err <= bound).all(), (err.max(), bound.min())


def check_gpipe() -> None:
    import jax
    import jax.numpy as jnp

    from repro.distributed.pipeline_par import gpipe

    mesh = _mesh((4, 2), ("pipe", "data"))
    s, t, mb, d = 4, 6, 8, 16
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((s, d, d)) / np.sqrt(d), jnp.float32)
    b = jnp.asarray(rng.standard_normal((s, d)) * 0.1, jnp.float32)
    xs = jnp.asarray(rng.standard_normal((t, mb, d)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    got = gpipe(stage_fn, {"w": w, "b": b}, xs, mesh=mesh, axis="pipe")

    ref = xs
    for i in range(s):  # sequential application of the 4 stages
        ref = jnp.tanh(ref @ w[i] + b[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def check_sharded_train_step() -> None:
    """Small end-to-end sharded train step on a (2,2,2) multi-pod mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed.sharding import params_shardings, train_rules, use_rules
    from repro.models import build_model
    from repro.optim import accumulate_gradients, adamw_init, adamw_update

    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen3-32b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(3)
    nb, mb, seq = 2, 8, 16
    blocks = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (nb, mb, seq)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (nb, mb, seq)), jnp.int32
        ),
    }

    def step(params, opt, blocks):
        loss, grads = accumulate_gradients(model.loss, params, blocks, mode="spliter")
        p2, o2 = adamw_update(params, grads, opt, lr=1e-3)
        return p2, o2, loss

    p_sh = params_shardings(params, mesh)
    b_sh = {
        k: NamedSharding(mesh, P(None, ("pod", "data"), *(None,) * (v.ndim - 2)))
        for k, v in blocks.items()
    }
    params = jax.device_put(params, p_sh)
    blocks = jax.device_put(blocks, b_sh)
    with use_rules(train_rules(mesh)):
        jstep = jax.jit(step, in_shardings=(p_sh, None, b_sh))
        p2, o2, loss_sharded = jstep(params, opt, blocks)

    # compare against the unsharded single-device step
    loss_ref, _ = accumulate_gradients(
        model.loss, jax.device_get(params), jax.device_get(blocks), mode="spliter"
    )
    np.testing.assert_allclose(
        float(loss_sharded), float(loss_ref), rtol=5e-3, atol=5e-3
    )


def check_elastic_restore() -> None:
    """Save under an 8-device sharded layout, restore onto a 2-device mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import Checkpointer
    import tempfile

    mesh8 = _mesh((8,), ("data",))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P("data")))
    tree = {"w": xs, "b": jnp.ones((3,), jnp.float32)}

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(7, tree, extras={"note": "elastic"}, blocking=True)

        mesh2 = _mesh((2,), ("data",))
        sh2 = {
            "w": NamedSharding(mesh2, P("data")),
            "b": NamedSharding(mesh2, P()),
        }
        got, extras, step = ck.restore(
            {"w": jnp.zeros_like(x), "b": jnp.zeros((3,), jnp.float32)},
            shardings=sh2,
        )
        assert step == 7 and extras["note"] == "elastic"
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x))
        assert got["w"].sharding.num_devices == 2


def check_sharded_cache_write() -> None:
    """sharded_dus cache write == masked write, decoded token by token."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import decode_rules, use_rules
    from repro.models.layers import cache_write

    mesh = _mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(5)
    b, s, h, d = 4, 16, 2, 8  # seq 16 shards over model=4
    cache0 = jnp.zeros((b, s, h, d), jnp.float32)
    rules = dataclasses.replace(decode_rules(mesh), cache_impl="sharded_dus")

    c_sh = NamedSharding(mesh, P(("data",), "model", None, None))
    masked = jax.device_put(cache0, c_sh)
    sharded = jax.device_put(cache0, c_sh)

    def write_masked(c, n, p):
        return cache_write(c, n, p)

    def write_sharded(c, n, p):
        with use_rules(rules):
            return cache_write(c, n, p)

    jm = jax.jit(write_masked, donate_argnums=(0,))
    js = jax.jit(write_sharded, donate_argnums=(0,))
    for pos in range(s):
        new = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        masked = jm(masked, new, jnp.asarray(pos, jnp.int32))
        sharded = js(sharded, new, jnp.asarray(pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(sharded))
    assert not np.allclose(np.asarray(masked), 0)


def check_heads_dus_cache_write() -> None:
    """heads_dus (in-place DUS, head-sharded cache) == masked write."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import decode_rules_headsharded, use_rules
    from repro.models.layers import cache_write

    mesh = _mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(7)
    b, s, h, d = 4, 16, 4, 8  # 4 kv heads shard over model=4
    cache0 = jnp.zeros((b, s, h, d), jnp.float32)
    rules = decode_rules_headsharded(mesh)
    assert rules.cache_impl == "heads_dus"

    c_sh = NamedSharding(mesh, P(("data",), None, "model", None))
    masked = cache0
    sharded = jax.device_put(cache0, c_sh)

    def write_h(c, n, p):
        with use_rules(rules):
            return cache_write(c, n, p)

    jh = jax.jit(write_h, donate_argnums=(0,))
    for pos in range(s):
        new = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        masked = cache_write(masked, new, jnp.asarray(pos, jnp.int32))
        sharded = jh(sharded, new, jnp.asarray(pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(sharded))
    assert not np.allclose(np.asarray(sharded), 0)


def check_mesh_executor() -> None:
    """MeshExecutor on a real 8-device mesh: one sharded dispatch per run,
    psum-style cross-rank merge billed to bytes_moved, values == Baseline."""
    import jax
    import jax.numpy as jnp

    from repro.api import Baseline, MeshExecutor, SplIter
    from repro.core.apps.histogram import histogram
    from repro.core.apps.kmeans import kmeans
    from repro.core.blocked import BlockedArray, round_robin_placement

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, (512, 3)).astype(np.float32))
    ba = BlockedArray.from_array(x, 16, num_locations=8, policy=round_robin_placement)

    hb, _ = histogram(ba, bins=4, policy=Baseline())
    for fusion in ("scan", "pallas"):
        hm, rm = histogram(
            ba, bins=4, policy=SplIter(fusion=fusion), executor=MeshExecutor()
        )
        np.testing.assert_array_equal(np.asarray(hm), np.asarray(hb))
        # C1: dispatches bounded by locations x ppl + merge; here the 8
        # uniform partitions stack into ONE sharded call
        assert rm.dispatches == 1, (fusion, rm.dispatches)
        assert rm.bytes_moved > 0, fusion        # collective traffic estimate
        assert rm.merges >= 1, fusion

    rb = kmeans(ba, k=4, iters=3, policy=Baseline())
    rm_ = kmeans(
        ba, k=4, iters=3, policy=SplIter(fusion="pallas"), executor=MeshExecutor()
    )
    np.testing.assert_allclose(
        np.asarray(rm_.centers), np.asarray(rb.centers), rtol=2e-4, atol=2e-4
    )
    assert rm_.total_dispatches == 3            # one sharded call per iteration


MODES = {
    "hier_psum": check_hierarchical_psum,
    "compressed_psum": check_compressed_psum,
    "gpipe": check_gpipe,
    "sharded_train": check_sharded_train_step,
    "elastic_restore": check_elastic_restore,
    "cache_write": check_sharded_cache_write,
    "heads_cache": check_heads_dus_cache_write,
    "mesh_exec": check_mesh_executor,
}

if __name__ == "__main__":
    import jax

    assert jax.device_count() == 8, jax.device_count()
    MODES[sys.argv[1]]()
    print(f"OK {sys.argv[1]}")
