"""Sharding rule units: param pspecs (stacked/unstacked by rank), cache
pspecs, non-divisible fallbacks, and the logical-axis shard() constraint."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed.sharding import (
    cache_shardings,
    decode_rules,
    long_decode_rules,
    param_pspec,
    params_shardings,
    shard,
    train_rules,
    use_rules,
)
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    # 1 real device: a (1, 1) mesh — axis *names* drive pspec construction,
    # extent-1 axes make every dim "divisible" so rules resolve fully.
    from repro.launch.mesh import compat_make_mesh

    return compat_make_mesh((1, 1), ("data", "model"))


def test_param_pspec_stacked_by_rank(mesh):
    # (L, D, H, Dh) — stacked attention projection
    assert param_pspec("/seg0/0/mixer/wq", (4, 64, 8, 16), mesh) == P(
        None, "data", "model", None
    )
    # (D, H, Dh) — unstacked (repeats==1 segment or unrolled probe)
    assert param_pspec("/seg0/0/mixer/wq", (64, 8, 16), mesh) == P(
        "data", "model", None
    )


def test_param_pspec_norms_replicated(mesh):
    assert param_pspec("/seg0/0/ln1", (4, 64), mesh) == P(None, None)
    assert param_pspec("/final_norm", (64,), mesh) == P(None)


def test_param_pspec_embed_and_head(mesh):
    assert param_pspec("/embed", (1024, 64), mesh) == P("model", "data")
    assert param_pspec("/lm_head", (64, 1024), mesh) == P("data", "model")


def test_param_pspec_fsdp_disable(mesh):
    got = param_pspec("/seg0/0/mixer/wq", (64, 8, 16), mesh, fsdp_axis=None)
    assert got == P(None, "model", None)


def test_param_pspec_nondivisible_replicates():
    from repro.launch.mesh import compat_make_mesh

    mesh2 = compat_make_mesh((1, 1), ("data", "model"))
    # simulate extent via a fake mesh is moot at extent 1; use rank mismatch:
    # a rank the rules don't expect must fully replicate, never crash
    assert param_pspec("/seg0/0/mixer/wq", (3, 4, 64, 8, 16), mesh2) == P(
        None, None, None, None, None
    )


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-v0.1-52b"])
def test_params_shardings_cover_whole_tree(mesh, arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    sh = params_shardings(params, mesh)
    # same structure, every leaf a NamedSharding of matching rank
    jax.tree.map(
        lambda l, s: (_ for _ in ()).throw(AssertionError((l.shape, s.spec)))
        if len(s.spec) != l.ndim and len(s.spec) != 0
        else None,
        params,
        sh,
    )


def test_cache_shardings_stacked_vs_unstacked(mesh):
    cfg = get_smoke_config("deepseek-v2-236b")  # seg0 repeats=1 + seg1 stacked
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(4, 32, jnp.float32))
    sh = cache_shardings(cache, mesh)

    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    specs = {
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path): s.spec
        for path, s in flat
    }
    # unstacked first-layer MLA cache: (B, S, R) → batch, seq(model), none
    unstacked = [v for k, v in specs.items() if k.startswith("seg0") and "ckv" in k]
    stacked = [v for k, v in specs.items() if k.startswith("seg1") and "ckv" in k]
    assert unstacked and stacked
    assert unstacked[0][1] == "model" and len(unstacked[0]) == 3
    assert stacked[0][0] is None and stacked[0][2] == "model"  # stack dim first


def test_shard_constraint_drops_nondivisible(mesh):
    rules = train_rules(mesh)
    with use_rules(rules):
        x = jnp.zeros((2, 8, 16))
        y = shard(x, "batch", "seq", "embed")  # extent-1 axes: all divisible
        assert y.shape == x.shape
    # outside a rules context shard() is the identity
    z = shard(jnp.zeros((3,)), "batch")
    assert z.shape == (3,)


def test_rule_presets_differ_where_expected(mesh):
    tr = train_rules(mesh).logical
    dr = decode_rules(mesh).logical
    lr = long_decode_rules(mesh).logical
    assert tr["heads"] == "model" and dr["heads"] is None
    assert dr["kv_seq"] == "model" and lr["kv_seq"] == "data"
    assert tr["batch"] == ("data",) and lr["batch"] is None
