"""MoE dispatch equivalence: onehot (production) vs ragged (reference),
virtual-expert splitting exactness, and capacity-drop behavior."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import init_moe, moe_mlp


def _cfg(**kw) -> ModelConfig:
    base = dict(
        name="moe-test", family="moe", source="[test]",
        num_layers=1, d_model=32, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=64, moe_experts=8, moe_top_k=2, moe_d_ff=64,
        dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def _x(cfg, b=2, l=16, seed=0):
    return jax.random.normal(jax.random.key(seed), (b, l, cfg.d_model), jnp.float32)


def test_onehot_matches_ragged_when_dropless():
    """cf = E/k ⇒ capacity = group ⇒ no drops ⇒ identical math."""
    cfg_r = _cfg(moe_impl="ragged")
    cfg_o = _cfg(moe_impl="onehot", moe_capacity_factor=4.0)  # E/k = 8/2
    p = init_moe(jax.random.key(1), cfg_r)
    x = _x(cfg_r)
    np.testing.assert_allclose(
        np.asarray(moe_mlp(p, cfg_o, x)),
        np.asarray(moe_mlp(p, cfg_r, x)),
        rtol=2e-5, atol=2e-5,
    )


def test_onehot_matches_ragged_with_shared_experts():
    cfg_r = _cfg(moe_impl="ragged", moe_shared_experts=1)
    cfg_o = _cfg(moe_impl="onehot", moe_capacity_factor=4.0, moe_shared_experts=1)
    p = init_moe(jax.random.key(2), cfg_r)
    x = _x(cfg_r, seed=3)
    np.testing.assert_allclose(
        np.asarray(moe_mlp(p, cfg_o, x)),
        np.asarray(moe_mlp(p, cfg_r, x)),
        rtol=2e-5, atol=2e-5,
    )


def test_virtual_split_is_exact():
    """vs=2 on reshaped weights == vs=1: the MLP is separable over F."""
    cfg1 = _cfg(moe_impl="onehot", moe_capacity_factor=4.0)
    cfg2 = dataclasses.replace(cfg1, moe_virtual_split=2)
    p1 = init_moe(jax.random.key(4), cfg1)
    e, d, f = p1["experts_gate"].shape

    def split_ef(w):  # (E, D, F) -> (2E, D, F/2)
        return w.reshape(e, d, 2, f // 2).transpose(0, 2, 1, 3).reshape(2 * e, d, f // 2)

    def split_fd(w):  # (E, F, D) -> (2E, F/2, D)
        return w.reshape(e, 2, f // 2, d).reshape(2 * e, f // 2, d)

    p2 = {
        "router": p1["router"],
        "experts_gate": split_ef(p1["experts_gate"]),
        "experts_up": split_ef(p1["experts_up"]),
        "experts_down": split_fd(p1["experts_down"]),
    }
    x = _x(cfg1, seed=5)
    np.testing.assert_allclose(
        np.asarray(moe_mlp(p2, cfg2, x)),
        np.asarray(moe_mlp(p1, cfg1, x)),
        rtol=2e-5, atol=2e-5,
    )


def test_capacity_drops_are_bounded_and_finite():
    """With a tight capacity, output stays finite and dropped tokens pass
    through as zeros (residual identity at the layer level)."""
    cfg = _cfg(moe_impl="onehot", moe_capacity_factor=0.5)
    p = init_moe(jax.random.key(6), cfg)
    x = _x(cfg, b=4, l=32, seed=7)
    y = moe_mlp(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    # capacity 0.5 ⇒ at most half the token-choices land; some output rows
    # must differ from the dropless run
    cfg_nd = dataclasses.replace(cfg, moe_capacity_factor=4.0)
    y_nd = moe_mlp(p, cfg_nd, x)
    assert not np.allclose(np.asarray(y), np.asarray(y_nd))


def test_onehot_grads_finite():
    cfg = _cfg(moe_impl="onehot", moe_capacity_factor=1.25)
    p = init_moe(jax.random.key(8), cfg)
    x = _x(cfg, b=2, l=64, seed=9)

    def loss(p):
        return jnp.sum(moe_mlp(p, cfg, x) ** 2)

    g = jax.grad(loss)(p)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    assert sum(float(jnp.sum(jnp.abs(l))) for l in leaves) > 0


@pytest.mark.parametrize("tokens", [1, 2, 128])
def test_onehot_tiny_token_counts(tokens):
    """Decode-shaped inputs: groups of 1–128 tokens must work."""
    cfg = _cfg(moe_impl="onehot", moe_capacity_factor=1.25)
    p = init_moe(jax.random.key(10), cfg)
    x = jax.random.normal(jax.random.key(11), (tokens, 1, cfg.d_model), jnp.float32)
    y = moe_mlp(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
