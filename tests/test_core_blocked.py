"""Unit tests: BlockedArray geometry, placement, spliter partitions, rechunk."""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockedArray,
    contiguous_placement,
    rechunk,
    round_robin_placement,
    spliter,
)


def make(n=100, d=3, block_rows=16, locs=4, policy=round_robin_placement, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return x, BlockedArray.from_array(x, block_rows, num_locations=locs, policy=policy)


class TestBlockedArray:
    def test_geometry_ragged_tail(self):
        x, ba = make(n=100, block_rows=16)
        assert ba.num_blocks == 7
        assert ba.block_rows == (16,) * 6 + (4,)
        assert ba.num_rows == 100
        assert not ba.uniform

    def test_geometry_uniform(self):
        x, ba = make(n=96, block_rows=16)
        assert ba.uniform
        assert ba.stacked().shape == (6, 16, 3)

    def test_collect_roundtrip(self):
        x, ba = make()
        np.testing.assert_array_equal(np.asarray(ba.collect()), np.asarray(x))

    def test_row_offsets(self):
        _, ba = make(n=100, block_rows=16)
        np.testing.assert_array_equal(ba.row_offsets(), [0, 16, 32, 48, 64, 80, 96])

    def test_placement_policies(self):
        rr = round_robin_placement(10, 4)
        np.testing.assert_array_equal(rr, [0, 1, 2, 3, 0, 1, 2, 3, 0, 1])
        cg = contiguous_placement(10, 4)
        np.testing.assert_array_equal(cg, [0, 0, 0, 1, 1, 1, 2, 2, 2, 3])

    def test_blocks_at_is_who_has(self):
        _, ba = make(n=96, block_rows=16, locs=3)
        for loc in range(3):
            for b in ba.blocks_at(loc):
                assert ba.placements[b] == loc

    def test_nbytes(self):
        _, ba = make(n=96, d=3, block_rows=16)
        assert ba.nbytes == 96 * 3 * 4


class TestSpliter:
    def test_locality_invariant(self):
        _, ba = make(n=256, block_rows=8, locs=4)
        for p in spliter(ba, partitions_per_location=3):
            for b in p.block_ids:
                assert ba.placements[b] == p.location

    def test_disjoint_cover(self):
        _, ba = make(n=256, block_rows=8, locs=4)
        parts = spliter(ba, partitions_per_location=3)
        seen = sorted(b for p in parts for b in p.block_ids)
        assert seen == list(range(ba.num_blocks))

    def test_zero_copy_references(self):
        """Partitions hold references to the original buffers — no movement."""
        _, ba = make(n=96, block_rows=16)
        for p in spliter(ba):
            for bid, blk in zip(p.block_ids, p.blocks):
                assert blk is ba.blocks[bid]

    def test_get_indexes_matches_paper_fig4(self):
        # Fig. 4: a partition over blocks {1, 3} reports indexes [1, 3].
        _, ba = make(n=64, block_rows=16, locs=2, policy=round_robin_placement)
        parts = spliter(ba)
        assert parts[0].get_indexes() == [0, 2]
        assert parts[1].get_indexes() == [1, 3]

    def test_get_item_indexes_global_rows(self):
        x, ba = make(n=64, block_rows=16, locs=2, policy=round_robin_placement)
        p = spliter(ba)[1]  # blocks 1, 3 -> rows 16..31 and 48..63
        np.testing.assert_array_equal(
            p.get_item_indexes(), list(range(16, 32)) + list(range(48, 64))
        )
        # materialize() must agree with gathering those global rows
        np.testing.assert_array_equal(
            np.asarray(p.materialize()), np.asarray(x)[p.get_item_indexes()]
        )

    def test_partitions_per_location_caps_at_local_blocks(self):
        _, ba = make(n=32, block_rows=16, locs=2)
        parts = spliter(ba, partitions_per_location=8)
        assert len(parts) == 2  # only one block per location exists

    def test_empty_locations_yield_no_partition(self):
        _, ba = make(n=32, block_rows=16, locs=8)
        parts = spliter(ba)
        assert len(parts) == 2
        assert all(len(p) == 1 for p in parts)


class TestRechunk:
    def test_content_preserved(self):
        x, ba = make(n=100, block_rows=16)
        nb, st = rechunk(ba, 7)
        np.testing.assert_array_equal(np.asarray(nb.collect()), np.asarray(x))
        assert st.blocks_after == 15

    def test_noop_keeps_buffers(self):
        _, ba = make(n=96, block_rows=16, locs=1)
        nb, st = rechunk(ba, 16)
        assert st.is_noop
        for a, b in zip(ba.blocks, nb.blocks):
            assert a is b

    def test_round_robin_rechunk_moves_bytes(self):
        """Dask-style scatter + consolidation must move inter-node bytes."""
        _, ba = make(n=256, block_rows=8, locs=4, policy=round_robin_placement)
        _, st = rechunk(ba, 64)
        assert st.bytes_moved > 0
        # 3/4 of the rows change location under round-robin -> contiguous.
        assert st.bytes_moved == 192 * 3 * 4

    def test_spliter_never_moves_vs_rechunk_moves(self):
        """DESIGN.md claim C3, structural form."""
        _, ba = make(n=256, block_rows=8, locs=4, policy=round_robin_placement)
        parts = spliter(ba)
        for p in parts:  # references only
            for bid, blk in zip(p.block_ids, p.blocks):
                assert blk is ba.blocks[bid]
        _, st = rechunk(ba, 64)
        assert st.bytes_moved > 0
