"""The public-surface contract (DESIGN.md §16 satellite).

``repro.api.__all__`` is curated — it IS the supported API.  These tests
keep three promises:

* every exported name resolves (no stale ``__all__`` entries);
* every ``from repro.api import ...`` in the docs and examples names only
  exported symbols — documentation cannot quietly lean on internals;
* every backend constructed through :func:`repro.api.engine` supports the
  uniform ``with engine(...) as ex:`` idiom.
"""

from __future__ import annotations

import pathlib
import re
import warnings

import pytest

import repro.api as api

REPO = pathlib.Path(__file__).resolve().parent.parent

# single-line and parenthesized multi-line forms, in .md fences or .py
_IMPORT_RE = re.compile(
    r"^\s*from\s+repro\.api\s+import\s+(\(([^)]*)\)|([^(\n]+))",
    re.MULTILINE | re.DOTALL,
)


def _imported_names(text: str) -> set[str]:
    names: set[str] = set()
    for m in _IMPORT_RE.finditer(text):
        body = m.group(2) if m.group(2) is not None else m.group(3)
        for part in body.split(","):
            part = part.split("#", 1)[0].strip()
            if not part:
                continue
            # "name as alias" exports under "name"
            names.add(part.split()[0])
    return names


def _surface_files():
    yield from sorted((REPO / "docs").rglob("*.md"))
    yield from sorted((REPO / "examples").glob("*.py"))
    for name in ("README.md", "DESIGN.md"):
        p = REPO / name
        if p.exists():
            yield p


def test_all_exports_resolve():
    missing = [n for n in api.__all__ if not hasattr(api, n)]
    assert missing == [], f"__all__ names without a binding: {missing}"


def test_no_duplicate_exports():
    assert len(api.__all__) == len(set(api.__all__))


def test_factory_is_exported():
    assert {"engine", "EngineConfig", "BACKENDS"} <= set(api.__all__)


@pytest.mark.parametrize("path", list(_surface_files()), ids=lambda p: str(p.relative_to(REPO)))
def test_docs_and_examples_use_only_exported_symbols(path):
    used = _imported_names(path.read_text())
    unexported = sorted(used - set(api.__all__))
    assert unexported == [], (
        f"{path.relative_to(REPO)} imports unexported repro.api names: "
        f"{unexported} — export them in repro/api/__init__.py or rewrite "
        f"the doc against the public surface"
    )


def test_every_backend_is_a_context_manager():
    """``with engine(backend) as ex:`` works uniformly — exit closes."""
    for backend in api.BACKENDS:
        overrides = {}
        if backend == "server":
            overrides = {"root": None, "autostart": False}
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            obj = api.engine(backend, **overrides)
        assert hasattr(obj, "__enter__") and hasattr(obj, "__exit__"), backend
        with obj as entered:
            assert entered is obj
