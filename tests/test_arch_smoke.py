"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For every assigned arch: forward/train-step shape + finiteness, and
prefill+decode consistency with the training forward (the serving-path
correctness contract).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

B, S = 2, 24


def make_batch(cfg, rng, with_labels=True):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    }
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.image_tokens, cfg.image_embed_dim)).astype(
                np.float32
            )
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite_loss(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg, np.random.default_rng(0))
    logits = m.forward(params, batch, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    # random-init CE should sit near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg, np.random.default_rng(1))
    loss, g = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in flat)
    # gradients actually flow to every segment
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in flat)
    assert gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, rng, with_labels=False)
    toks = batch["tokens"]
    memory = batch.get("image_embeds")
    full = m.forward(params, batch, remat=False)
    p = S - 4
    cache = m.init_cache(B, S, dtype=jnp.float32)
    logits_p, cache = jax.jit(m.prefill)(
        params, dict(batch, tokens=toks[:, :p]), cache
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, p - 1]), rtol=3e-4, atol=3e-4
    )
    dec = jax.jit(m.decode_step)
    for t in range(p, S):
        logits_d, cache = dec(
            params, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32), memory
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, t]), rtol=5e-4, atol=5e-4
        )


def test_mixtral_swa_ring_buffer_beyond_window():
    """Prefill longer than the sliding window must still decode exactly."""
    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x7b"), dtype="float32", sliding_window=8
    )
    m = build_model(cfg)
    params = m.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = m.forward(params, {"tokens": toks}, remat=False)
    p = 20
    cache = m.init_cache(B, S, dtype=jnp.float32)
    logits_p, cache = m.prefill(params, {"tokens": toks[:, :p]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, p - 1]), rtol=5e-4, atol=5e-4
    )
    for t in range(p, S):
        logits_d, cache = m.decode_step(
            params, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, t]), rtol=5e-4, atol=5e-4
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_math(arch):
    """Full configs: analytic param counts vs eval_shape (no allocation)."""
    cfg = get_config(arch)
    m = build_model(cfg)
    shapes = jax.eval_shape(lambda k: m.init(k), jax.random.key(0))
    actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic = cfg.param_counts()["total"]
    # analytic formula ignores norms and small scalars: within 2%
    assert abs(actual - analytic) / analytic < 0.02, (actual, analytic)


def test_param_counts_match_model_names():
    """The headline sizes are in the right ballpark for the named models."""
    expect = {
        "qwen2-72b": 72e9,
        "command-r-35b": 35e9,
        "qwen3-32b": 32e9,
        "deepseek-7b": 7e9,
        "deepseek-v2-236b": 236e9,
        "mixtral-8x7b": 47e9,  # total (active ~13B)
        "jamba-v0.1-52b": 52e9,
        "mamba2-1.3b": 1.3e9,
        "llama-3.2-vision-11b": 10e9,  # text trunk + cross-attn (frontend stubbed)
    }
    for arch, target in expect.items():
        total = get_config(arch).param_counts()["total"]
        assert 0.7 < total / target < 1.45, (arch, total, target)
