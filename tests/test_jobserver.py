"""JobServer — multi-tenant multiplexing, fairness, durability, resume.

The acceptance contract of DESIGN.md §12:

* two concurrent clients (kmeans + histogram) on ONE shared pool both
  complete bit-identically vs direct LocalExecutor runs, with interleaved
  progress events proving neither job starves;
* admission control is a typed :class:`JobRejected`, not an unbounded
  queue;
* killing the server after ≥1 completed unit and restarting resumes from
  journal + snapshot, recomputing ONLY unfinished units (asserted via the
  restored/recomputed unit counters) with a bit-identical final result;
* :class:`EngineReport` serializes over the client channel and merges
  across resumed segments;
* the journal tolerates a torn tail (crash mid-append).
"""

from __future__ import annotations

import os
import threading
import time
from functools import partial

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    Collection,
    Executor,
    JobClient,
    JobFailedError,
    JobJournal,
    JobRejected,
    JobServer,
    LocalExecutor,
    SplIter,
    ThreadedExecutor,
)
from repro.core.apps.histogram import histogram, histogramdd_block
from repro.core.apps.kmeans import kmeans
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport

POL = SplIter(partitions_per_location=2)
WATCHDOG_S = 120.0  # every wait in this module is bounded


def _points(n=240, d=4, block_rows=30, locations=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
    return BlockedArray.from_array(x, block_rows, num_locations=locations)


def _hist_plan(ba, bins=4, policy=POL):
    return (
        Collection.from_blocked(ba)
        .split(policy)
        .map_blocks(partial(histogramdd_block, bins=bins, lo=0.0, hi=1.0))
        .reduce(lambda a, b: a + b)
        .plan()
    )


def identical(a, b) -> bool:
    return bool(jnp.all(jnp.asarray(a) == jnp.asarray(b)))


# ---------------------------------------------------------------------------
# satellite: EngineReport channel serialization + segment merging
# ---------------------------------------------------------------------------


class TestEngineReportChannel:
    def test_json_round_trip_is_exact(self):
        rep = EngineReport(
            mode="spliter", dispatches=12, merges=2, traces=3, bytes_moved=640,
            wall_s=1.25, granularity=4, retunes=1, bytes_loaded=100,
            bytes_spilled=50, prefetch_hits=7, remote_dispatches=8,
            ipc_bytes=4096, retries=1,
        )
        back = EngineReport.from_json(rep.to_json())
        assert back == rep
        assert back is not rep

    def test_from_json_ignores_unknown_keys(self):
        # forward-compat: a journal written by a newer build still replays
        payload = EngineReport(mode="x", dispatches=1).to_json()
        payload = payload.replace("{", '{"counter_from_the_future": 9, ', 1)
        assert EngineReport.from_json(payload).dispatches == 1

    def test_merge_sums_counters_without_mutating_inputs(self):
        a = EngineReport(mode="spliter", dispatches=5, traces=2, granularity=2)
        b = EngineReport(mode="spliter", dispatches=3, traces=0, granularity=4)
        out = a.merge(b)
        assert (out.dispatches, out.traces, out.granularity) == (8, 2, 4)
        assert (a.dispatches, b.dispatches) == (5, 3)  # inputs untouched

    def test_merge_joins_disagreeing_modes(self):
        out = EngineReport(mode="spliter").merge(EngineReport(mode="rechunk"))
        assert out.mode == "spliter+rechunk"


# ---------------------------------------------------------------------------
# satellite: the write-ahead journal
# ---------------------------------------------------------------------------


class TestJobJournal:
    def test_append_replay_round_trip(self, tmp_path):
        path = str(tmp_path / "j.bin")
        with JobJournal(path, fsync=False) as j:
            j.append(("job", "job-0000", {"weight": 2}))
            j.append(("unit", "job-0000", "u0:abc:0,1", b"\x00payload"))
        assert list(JobJournal.replay(path)) == [
            ("job", "job-0000", {"weight": 2}),
            ("unit", "job-0000", "u0:abc:0,1", b"\x00payload"),
        ]

    def test_torn_tail_is_dropped_not_fatal(self, tmp_path):
        path = str(tmp_path / "j.bin")
        with JobJournal(path, fsync=False) as j:
            for i in range(3):
                j.append(("rec", i))
        size = os.path.getsize(path)
        with open(path, "ab") as f:  # crash mid-append: half a frame
            f.write(b"\x00\x00\x01\x00garbage")
        assert [r[1] for r in JobJournal.replay(path)] == [0, 1, 2]
        # corrupting the LAST record's payload drops only that record
        with open(path, "r+b") as f:
            f.seek(size - 1)
            f.write(b"\xff")
        assert [r[1] for r in JobJournal.replay(path)] == [0, 1]

    def test_missing_file_is_empty_history(self, tmp_path):
        assert list(JobJournal.replay(str(tmp_path / "absent.bin"))) == []


# ---------------------------------------------------------------------------
# multiplexing: concurrent tenants on one pool
# ---------------------------------------------------------------------------


class TestMultiplexing:
    def test_jobclient_satisfies_executor_protocol(self):
        server = JobServer()
        assert isinstance(JobClient(server), Executor)
        server.close()

    def test_two_clients_bit_identical_and_interleaved(self):
        """The headline acceptance case: kmeans + histogram, one pool."""
        kdata = _points(seed=0)
        hdata = _points(n=400, d=2, block_rows=40, seed=1)
        ref_k = kmeans(kdata, k=4, iters=3, policy=POL, executor=LocalExecutor())
        ref_h, _ = histogram(hdata, bins=4, policy=POL, executor=LocalExecutor())

        # submit both BEFORE the scheduler starts so their units provably
        # coexist in the run queue, then let the stride scheduler drain
        server = JobServer(autostart=False)
        alice = JobClient(server, tenant="alice")
        bob = JobClient(server, tenant="bob")
        results: dict[str, object] = {}

        def run_kmeans():
            results["k"] = kmeans(kdata, k=4, iters=3, policy=POL, executor=alice)

        def run_hist():
            results["h"] = histogram(hdata, bins=4, policy=POL, executor=bob)[0]

        threads = [
            threading.Thread(target=run_kmeans),
            threading.Thread(target=run_hist),
        ]
        for t in threads:
            t.start()
        while len(server.jobs()) < 2:  # both tenants admitted...
            time.sleep(0.002)
        server.start()                 # ...before a single unit runs
        for t in threads:
            t.join(WATCHDOG_S)
            assert not t.is_alive()
        assert identical(results["k"].centers, ref_k.centers)
        assert identical(results["h"], ref_h)

        # interleaving: within the window where both jobs were open, unit
        # progress events of the two tenants alternate (neither starves)
        jobs = server.jobs()
        a_id, b_id = jobs[0].id, jobs[1].id
        unit_owners = [
            e.job_id for e in server.event_log
            if e.kind in ("running", "merged") and e.total
        ]
        first_b = unit_owners.index(b_id)
        last_a = len(unit_owners) - 1 - unit_owners[::-1].index(a_id)
        assert first_b < last_a, "tenant B's units never ran between A's"
        server.close()

    def test_per_job_reports_are_channel_copies(self):
        server = JobServer()
        client = JobClient(server, tenant="t")
        data = _points()
        res = client.execute(_hist_plan(data))
        job = server.jobs()[0]
        assert res.report is not job.report           # crossed by value
        assert res.report.dispatches == job.report.dispatches
        assert res.report.dispatches > 0
        server.close()

    def test_weighted_tenant_gets_more_unit_slots(self):
        # submit two identical jobs under weights 1 and 3 before starting;
        # the heavier tenant's units must lead in the event prefix
        data = _points(n=480, block_rows=30, locations=2)
        server = JobServer(autostart=False)
        light = server.submit(_hist_plan(data), tenant="light", weight=1)
        heavy = server.submit(_hist_plan(data), tenant="heavy", weight=3)
        server.start()
        server.wait(light, WATCHDOG_S)
        server.wait(heavy, WATCHDOG_S)
        owners = [
            e.job_id for e in server.event_log
            if e.kind in ("running", "merged") and e.total
        ]
        n = len(owners) // 2
        heavy_early = sum(1 for j in owners[:n] if j == heavy.id)
        assert heavy_early > n // 2, "weight-3 tenant did not lead the schedule"
        server.close()

    def test_scope_and_task_on_the_client(self):
        server = JobServer()
        client = JobClient(server, tenant="t")
        data = _points()
        double = client.task(lambda x: x * 2.0, key="double")
        with client.scope("spliter") as report:
            client.execute(_hist_plan(data))
            double(jnp.ones((2,)))
        assert report.dispatches > 1  # job dispatches + the local task
        server.close()

    def test_shared_assets_reuse_probes_across_tenants(self):
        # Two tenants, two distinct-but-equal-geometry datasets, same auto
        # policy: the geometry-keyed shared tuner must be created ONCE, so
        # tenant B starts from tenant A's probe history.
        auto = SplIter(partitions_per_location="auto")
        a = _points(n=256, d=2, block_rows=16, seed=2)
        b = _points(n=256, d=2, block_rows=16, seed=3)
        server = JobServer()
        ca = JobClient(server, tenant="a")
        cb = JobClient(server, tenant="b")
        for _ in range(2):
            histogram(a, bins=4, policy=auto, executor=ca)
        for _ in range(2):
            histogram(b, bins=4, policy=auto, executor=cb)
        assert len(server.assets.tuners) == 1
        (_, tuner), = server.assets.tuners.values()
        assert len(tuner.samples) >= 2  # B's runs extended A's schedule
        server.close()

    def test_pool_backend_is_pluggable(self):
        # same contract on a ThreadedExecutor pool
        data = _points()
        ref, _ = histogram(data, bins=4, policy=POL, executor=LocalExecutor())
        server = JobServer(executor=ThreadedExecutor())
        h, _ = histogram(data, bins=4, policy=POL,
                         executor=JobClient(server, tenant="t"))
        assert identical(h, ref)
        server.close()
        server.executor.close()

    def test_failed_job_raises_typed_error(self):
        def boom(block):
            raise ValueError("deliberate block failure")

        plan = (
            Collection.from_blocked(_points())
            .split(Baseline())
            .map_blocks(boom)
            .reduce(lambda a, b: a)
            .plan()
        )
        server = JobServer()
        client = JobClient(server, tenant="t")
        job = client.submit(plan)
        with pytest.raises(JobFailedError, match="deliberate"):
            client.wait(job, WATCHDOG_S)
        assert job.status == "failed"
        assert server.event_log[-1].kind == "failed"
        server.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_queue_full_is_typed_rejection(self):
        data = _points()
        server = JobServer(max_pending=2, autostart=False)  # nothing drains
        server.submit(_hist_plan(data), tenant="t")
        server.submit(_hist_plan(data), tenant="t")
        with pytest.raises(JobRejected) as ei:
            server.submit(_hist_plan(data), tenant="t")
        assert ei.value.reason == "queue_full"
        server.start()
        for job in server.jobs():
            server.wait(job, WATCHDOG_S)
        # drained below the bound: admission reopens
        server.submit(_hist_plan(data), tenant="t")
        server.close()

    def test_closed_server_rejects(self):
        server = JobServer()
        server.close()
        with pytest.raises(JobRejected) as ei:
            server.submit(_hist_plan(_points()))
        assert ei.value.reason == "closed"

    def test_lifecycle_event_order(self):
        server = JobServer()
        job = server.submit(_hist_plan(_points()), tenant="t")
        server.wait(job, WATCHDOG_S)
        kinds = [e.kind for e in job.events]
        assert kinds[0] == "queued"
        assert kinds[1] == "preparing"
        assert kinds[-2] == "merged"
        assert kinds[-1] == "done"
        assert all(k == "running" for k in kinds[2:-2])
        # running events carry monotone k/n progress
        progress = [e.completed for e in job.events if e.total]
        assert progress == sorted(progress)
        assert job.events[-1].completed == job.total_units
        server.close()


# ---------------------------------------------------------------------------
# durability: kill + restart resumes from journal + snapshot
# ---------------------------------------------------------------------------


class TestDurability:
    def test_kill_and_resume_recomputes_only_unfinished_units(self, tmp_path):
        data = _points(n=800, d=2, block_rows=50, locations=4, seed=5)
        ref, _ = histogram(data, bins=4, policy=POL, executor=LocalExecutor())
        plan = _hist_plan(data)

        server = JobServer(root=str(tmp_path), snapshot_every=2, autostart=False)
        job = server.submit(plan, tenant="alice")
        server.start()
        deadline = time.monotonic() + WATCHDOG_S
        while job.recomputed_units < 2:  # ≥1 completed unit journaled
            assert time.monotonic() < deadline, "no unit completed in time"
            assert job.status != "failed", job.error
            time.sleep(0.005)
        server.kill()  # crash: no terminal records, journal left as-is
        done_at_kill = job.recomputed_units
        assert job.status in ("preparing", "running")
        assert done_at_kill < job.total_units

        # restart in a fresh server (fresh executor, fresh engine)
        server2 = JobServer(root=str(tmp_path))
        assert server2.resumed_jobs == 1
        job2 = server2.jobs()[0]
        res = server2.wait(job2, WATCHDOG_S)
        # only unfinished units recomputed; journaled ones restored
        assert job2.restored_units >= done_at_kill
        assert job2.restored_units + job2.recomputed_units == job2.total_units
        assert job2.recomputed_units < job2.total_units
        assert identical(res.value, ref)
        assert any(e.kind == "resumed" for e in job2.events)
        server2.close()

    def test_resumed_report_merges_segments(self, tmp_path):
        # snapshot_every=1 ⇒ the pre-kill segment is always snapshotted, so
        # the final report must aggregate both segments' dispatches
        data = _points(n=400, d=2, block_rows=50, locations=2, seed=6)
        server = JobServer(root=str(tmp_path), snapshot_every=1, autostart=False)
        job = server.submit(_hist_plan(data), tenant="t")
        server.start()
        deadline = time.monotonic() + WATCHDOG_S
        while job.recomputed_units < 2:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        server.kill()

        server2 = JobServer(root=str(tmp_path))
        job2 = server2.jobs()[0]
        res = server2.wait(job2, WATCHDOG_S)
        # dispatches: every task unit + the merge, across both segments
        assert res.report.dispatches == job2.total_units
        server2.close()

    def test_completed_job_survives_restart_without_rerun(self, tmp_path):
        data = _points()
        server = JobServer(root=str(tmp_path))
        job = server.submit(_hist_plan(data), tenant="t")
        ref = server.wait(job, WATCHDOG_S)
        server.close()

        server2 = JobServer(root=str(tmp_path))
        assert server2.resumed_jobs == 0
        job2 = server2.jobs()[0]
        assert job2.status == "done"
        res = server2.wait(job2, WATCHDOG_S)
        assert identical(res.value, ref.value)
        assert job2.recomputed_units == 0
        server2.close()

    def test_non_durable_job_fails_cleanly_at_restart(self, tmp_path):
        # a closure over un-picklable state is accepted and runs, but
        # cannot be replayed; after a kill it must fail with a clear error
        lock = threading.Lock()  # unpicklable cell value

        def opaque(block):
            with lock:
                return jnp.sum(block, 0)

        plan = (
            Collection.from_blocked(_points())
            .split(POL)
            .map_blocks(opaque)
            .reduce(lambda a, b: a + b)
            .plan()
        )
        server = JobServer(root=str(tmp_path), autostart=False)
        job = server.submit(plan, tenant="t")
        assert not job.durable
        server.kill()

        server2 = JobServer(root=str(tmp_path))
        job2 = server2.jobs()[0]
        with pytest.raises(JobFailedError, match="not durable"):
            server2.wait(job2, WATCHDOG_S)
        server2.close()

    def test_snapshots_use_committed_marker_layout(self, tmp_path):
        data = _points(n=400, d=2, block_rows=25, locations=2)
        server = JobServer(root=str(tmp_path), snapshot_every=2)
        job = server.submit(_hist_plan(data), tenant="t")
        server.wait(job, WATCHDOG_S)
        snaps = os.path.join(str(tmp_path), "snapshots")
        committed = [f for f in os.listdir(snaps) if f.endswith(".COMMITTED")]
        assert committed, "no committed scheduler snapshot written"
        manifest, _ = server.checkpointer.load_manifest()
        assert "tenant_pass" in manifest["extras"]
        server.close()
