"""Pipelined iteration — ``execute_async`` and cross-iteration edges.

The acceptance contract of DESIGN.md §14:

* bit-identical results with pipelining on vs off across all five
  backends — the pipeline reorders *launches*, never the merge fold;
* ``overlapped_launches`` > 0 on the pipelined backends (Threaded,
  Cluster, Stream) and exactly 0 on the barriered ones (Local, Mesh),
  with the deterministic submit-time-frozen pattern [0, n, n, ...];
* the autotuner probe guard: an ``"auto"`` policy's probe iterations run
  barriered (depth 1) so profiled walls never measure contention;
* failure semantics under overlap: iteration *k*'s failure raises the
  original error on *k*'s future and poisons *k+1* with a typed
  :class:`PipelineBrokenError` naming the originating iteration;
* ``close()`` with in-flight futures drains cleanly — no leaked
  ``/dev/shm`` segments (the PR 7 fault-lane assertion).

The CI ``pipeline-lane`` job runs exactly this module on the cluster +
threaded backends.

All block functions are module-level: ClusterExecutor workers are
spawned processes and must re-import them by qualified name.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (
    ClusterExecutor,
    Collection,
    DiskStore,
    LocalExecutor,
    MeshExecutor,
    SplIter,
    StreamExecutor,
    ThreadedExecutor,
    shm_available,
)
from repro.api.futures import Deferred, PipelineBrokenError, resolve_deferred
from repro.api.lowering import cross_iteration_edges, partition_key
from repro.api.shm import leaked_segments

needs_shm = pytest.mark.skipif(
    not shm_available(), reason="host has no POSIX shared memory"
)

POL = SplIter(partitions_per_location=2)


# -- the iterative app under test (Lloyd-shaped: partials -> merge -> map) ----


def _partial(b, c):
    return (b * c).sum(axis=0), jnp.ones(())


def _combine(a, b):
    return a[0] + b[0], a[1] + b[1]


def _ratio(v):
    return v[0] / v[1]


def _boom(b, c):
    raise ValueError("injected unit failure")


def _data():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.random((512, 8), np.float32))


def _plan(x, c, *, fn=_partial, policy=POL):
    return (
        Collection.from_array(x, block_rows=64, num_locations=2)
        .split(policy)
        .map_blocks(fn, extra_args=(c,))
        .reduce(_combine)
    )


def _barriered(x, ex, iters, *, policy=POL):
    """The reference loop: one synchronous execute per iteration."""
    c, out = jnp.ones((8,)), []
    for _ in range(iters):
        res = _plan(x, c, policy=policy).compute(executor=ex)
        c = _ratio(res.value)
        out.append(np.asarray(c))
    return out


def _pipelined(x, ex, iters, *, policy=POL):
    """The async loop: params flow as a Deferred, executes overlap."""
    c_op, futs = jnp.ones((8,)), []
    for _ in range(iters):
        fut = _plan(x, c_op, policy=policy).compute_async(executor=ex)
        futs.append(fut)
        c_op = fut.map(_ratio)
    final = np.asarray(resolve_deferred(c_op))
    results = [f.result() for f in futs]
    return [np.asarray(_ratio(r.value)) for r in results], final, results


EXECUTORS = [
    ("local", LocalExecutor, False),
    ("threaded", ThreadedExecutor, True),
    ("mesh", MeshExecutor, False),
    ("stream", StreamExecutor, True),
    ("cluster", ClusterExecutor, True),
]


class TestBitIdentity:
    @pytest.mark.parametrize(
        "name,factory,pipelines", EXECUTORS, ids=[e[0] for e in EXECUTORS]
    )
    def test_pipelined_matches_barriered(self, name, factory, pipelines):
        x = _data()
        ex = factory()
        try:
            assert ex.capabilities.pipelined is pipelines
            ref = _barriered(x, ex, 4)
            got, final, results = _pipelined(x, ex, 4)
        finally:
            ex.close()
        assert all((a == b).all() for a, b in zip(ref, got))
        assert (final == ref[-1]).all()
        overlapped = [r.report.overlapped_launches for r in results]
        if pipelines:
            # Submit-time frozen: iteration 0 has no predecessor; every
            # later submit finds one in flight, so the whole unit count
            # overlaps.  A pure function of call order — not host speed.
            assert overlapped[0] == 0
            assert all(n == overlapped[1] > 0 for n in overlapped[1:])
        else:
            assert overlapped == [0, 0, 0, 0]
            # Non-pipelined backends degrade to sync futures: done at return.
            ex2 = factory()
            try:
                fut = _plan(x, jnp.ones((8,))).compute_async(executor=ex2)
                assert fut.done()
            finally:
                ex2.close()

    def test_per_execute_reports_stay_exact(self):
        # Overlap must not blur per-iteration attribution: each future's
        # report carries its own execute's dispatch/merge counts, equal to
        # the barriered run's.
        x = _data()
        ex = ThreadedExecutor()
        try:
            c, sync_reports = jnp.ones((8,)), []
            for _ in range(3):
                res = _plan(x, c).compute(executor=ex)
                c = _ratio(res.value)
                sync_reports.append(res.report)
            _, _, results = _pipelined(x, ex, 3)
        finally:
            ex.close()
        for sync, r in zip(sync_reports, results):
            assert r.report.dispatches == sync.dispatches
            assert r.report.merges == sync.merges


class TestLoweringEdges:
    def test_cross_iteration_edges_match_partitions(self):
        # Two lowerings of the same spec: every task of the next graph is
        # gated on exactly the same-partition task(s) of the previous one.
        from repro.api.lowering import lower

        ex = LocalExecutor()
        spec = _plan(_data(), jnp.ones((8,))).plan().spec
        policy, _ = ex._resolve_policy(spec)
        prepared = ex._prepare(spec.inputs, policy, ex.engine.new_report("t"))

        g1 = lower(spec, prepared.arrays, prepared.groups, ex.capabilities)
        g2 = lower(spec, prepared.arrays, prepared.groups, ex.capabilities)
        edges = cross_iteration_edges(g1, g2)
        assert edges  # same partitioning -> every task matched
        for idx, deps in edges.items():
            key = partition_key(g2.tasks[idx])
            assert all(partition_key(g1.tasks[d]) == key for d in deps)
        ex.close()

    def test_partition_versions_increment_across_submits(self):
        # The versioned-key counter: consecutive in-flight submissions
        # stamp monotonically increasing versions per partition key.
        x = _data()
        ex = ThreadedExecutor()
        try:
            f1 = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            f2 = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            entries = list(ex._pipeline)
            versions = [dict(e.state.partition_versions) for e in entries]
            f1.result(), f2.result()
        finally:
            ex.close()
        assert len(versions) == 2
        assert set(versions[0]) == set(versions[1])
        for key, v in versions[0].items():
            assert versions[1][key] == v + 1 == 2


class TestProbeGuard:
    def test_probe_iterations_run_barriered(self):
        # An "auto" policy's probe window feeds measured walls into the
        # cost model — overlapping probes would record contended walls and
        # mistune every later iteration.  The guard forces depth 1: each
        # probe's future is already resolved at submit return.
        x = _data()
        auto = SplIter(partitions_per_location="auto")
        ex = ThreadedExecutor()
        try:
            c_op, futs = jnp.ones((8,)), []
            for _ in range(3):  # the deterministic probe ladder (seed 0)
                fut = _plan(x, c_op, policy=auto).compute_async(executor=ex)
                futs.append(fut)
                c_op = fut.map(_ratio)
            for fut in futs:
                assert fut.done()  # sync future: resolved before return
                assert fut.result().report.overlapped_launches == 0
        finally:
            ex.close()


class TestFailureSemantics:
    def test_failure_fails_own_future_and_poisons_next(self):
        x = _data()
        ex = ThreadedExecutor()
        try:
            f0 = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            f1 = _plan(x, f0.map(_ratio), fn=_boom).compute_async(executor=ex)
            f2 = _plan(x, f1.map(_ratio)).compute_async(executor=ex)

            assert f0.result() is not None  # iteration 0 unaffected
            with pytest.raises(ValueError, match="injected unit failure"):
                f1.result()  # the originating iteration: original error
            with pytest.raises(PipelineBrokenError) as exc:
                f2.result()  # the dependent iteration: typed poison
            assert exc.value.iteration == f1.iteration
            assert str(f1.iteration) in str(exc.value)
        finally:
            ex.close()

    def test_deferred_against_failed_future_raises_typed(self):
        x = _data()
        ex = ThreadedExecutor()
        try:
            fut = _plan(x, jnp.ones((8,)), fn=_boom).compute_async(executor=ex)
            d = fut.map(_ratio)
            with pytest.raises(PipelineBrokenError) as exc:
                d.resolve()
            assert exc.value.iteration == fut.iteration
        finally:
            ex.close()

    def test_close_with_inflight_futures_drains_cleanly(self):
        x = _data()
        ex = ThreadedExecutor()
        ref = _barriered(x, LocalExecutor(), 3)
        c_op, futs = jnp.ones((8,)), []
        for _ in range(3):
            fut = _plan(x, c_op).compute_async(executor=ex)
            futs.append(fut)
            c_op = fut.map(_ratio)
        ex.close()  # nothing resolved yet: close must drain, not wedge
        got = [np.asarray(_ratio(f.result().value)) for f in futs]
        assert all((a == b).all() for a, b in zip(ref, got))

    def test_close_after_failure_is_clean(self):
        x = _data()
        ex = ThreadedExecutor()
        f0 = _plan(x, jnp.ones((8,)), fn=_boom).compute_async(executor=ex)
        f1 = _plan(x, f0.map(_ratio)).compute_async(executor=ex)
        ex.close()  # errors stay on the futures; close itself must not raise
        with pytest.raises(ValueError):
            f0.result()
        with pytest.raises(PipelineBrokenError):
            f1.result()


@needs_shm
class TestClusterPipeline:
    def test_cluster_failure_poisons_and_leaks_nothing(self):
        x = _data()
        ex = ClusterExecutor()
        prefix = ex._shm.prefix
        try:
            f0 = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            f1 = _plan(x, f0.map(_ratio), fn=_boom).compute_async(executor=ex)
            f2 = _plan(x, f1.map(_ratio)).compute_async(executor=ex)
            assert f0.result() is not None
            with pytest.raises(Exception) as exc:
                f1.result()
            assert "injected unit failure" in str(exc.value)
            with pytest.raises(PipelineBrokenError) as exc2:
                f2.result()
            assert exc2.value.iteration == f1.iteration
        finally:
            ex.close()
        assert leaked_segments(prefix) == []

    def test_cluster_close_with_inflight_leaks_no_segments(self):
        x = _data()
        ref = _barriered(x, LocalExecutor(), 3)
        ex = ClusterExecutor()
        prefix = ex._shm.prefix
        c_op, futs = jnp.ones((8,)), []
        for _ in range(3):
            fut = _plan(x, c_op).compute_async(executor=ex)
            futs.append(fut)
            c_op = fut.map(_ratio)
        ex.close()
        got = [np.asarray(_ratio(f.result().value)) for f in futs]
        assert all((a == b).all() for a, b in zip(ref, got))
        assert leaked_segments(prefix) == []


class TestStreamPipeline:
    def test_prefetch_crosses_iteration_boundary(self):
        # Out-of-core pipelining: with the dataset spilled to disk, the
        # next execute's first partitions prefetch while the current one
        # still computes — bit-identical values, warm prefetch pipeline.
        x = _data()
        ref = _barriered(x, LocalExecutor(), 3)
        store = DiskStore(x.nbytes // 2)
        ex = StreamExecutor(close_stores=False)
        try:
            xd = Collection.from_array(
                x, block_rows=64, num_locations=2, store=store
            )
            c_op, futs = jnp.ones((8,)), []
            for _ in range(3):
                fut = (
                    xd.split(POL)
                    .map_blocks(_partial, extra_args=(c_op,))
                    .reduce(_combine)
                    .compute_async(executor=ex)
                )
                futs.append(fut)
                c_op = fut.map(_ratio)
            final = np.asarray(resolve_deferred(c_op))
            results = [f.result() for f in futs]
        finally:
            ex.close()
            store.close()
        got = [np.asarray(_ratio(r.value)) for r in results]
        assert all((a == b).all() for a, b in zip(ref, got))
        assert (final == ref[-1]).all()
        assert sum(r.report.overlapped_launches for r in results) > 0
        assert sum(r.report.prefetch_hits for r in results) > 0


class TestBarrierRule:
    def test_sync_execute_drains_pipeline_first(self):
        x = _data()
        ex = ThreadedExecutor()
        try:
            f0 = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            f1 = _plan(x, f0.map(_ratio)).compute_async(executor=ex)
            res = _plan(x, f1.map(_ratio)).compute(executor=ex)
            # The synchronous execute never overlaps: both async futures
            # resolved before it ran.
            assert f0.done() and f1.done()
            ref = _barriered(x, LocalExecutor(), 3)
            assert (np.asarray(_ratio(res.value)) == ref[-1]).all()
        finally:
            ex.close()

    def test_window_caps_inflight_entries(self):
        x = _data()
        ex = ThreadedExecutor()
        try:
            c_op = jnp.ones((8,))
            for _ in range(5):
                fut = _plan(x, c_op).compute_async(executor=ex)
                c_op = fut.map(_ratio)
                assert len(ex._pipeline) <= ex.pipeline_depth
        finally:
            ex.close()


class TestFutureSurface:
    def test_map_chains_and_caches(self):
        x = _data()
        ex = LocalExecutor()
        try:
            fut = _plan(x, jnp.ones((8,))).compute_async(executor=ex)
            d = fut.map(_ratio).map(lambda c: c * 2.0)
            assert isinstance(d, Deferred)
            v1, v2 = d.resolve(), d.resolve()
            assert v1 is v2  # single-flight cached
            assert (np.asarray(v1) == np.asarray(_ratio(fut.result().value)) * 2.0).all()
        finally:
            ex.close()
