"""Hypothesis property tests on the one-hot MoE dispatch invariants."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models.moe import _moe_onehot, _route, init_moe


def _cfg(e, k, cf, vs=1, group=1024):
    return ModelConfig(
        name="moe-prop", family="moe", source="[test]",
        num_layers=1, d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
        vocab_size=64, moe_experts=e, moe_top_k=k, moe_d_ff=32,
        moe_capacity_factor=cf, moe_virtual_split=vs, moe_group=group,
        dtype="float32",
    )


@settings(max_examples=20, deadline=None)
@given(
    e=st.sampled_from([4, 8]),
    k=st.integers(1, 3),
    b=st.integers(1, 3),
    l=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_route_gates_normalized(e, k, b, l, seed):
    cfg = _cfg(e, min(k, e), 1.25)
    p = init_moe(jax.random.key(seed % 997), cfg)
    x = jax.random.normal(jax.random.key(seed), (b * l, cfg.d_model))
    gates, idx = _route(p, cfg, x)
    g = np.asarray(gates)
    assert np.allclose(g.sum(-1), 1.0, atol=1e-5)   # renormalized
    assert (g >= 0).all()
    i = np.asarray(idx)
    assert ((0 <= i) & (i < e)).all()
    # top-k indices are distinct per token
    for row in i.reshape(-1, i.shape[-1]):
        assert len(set(row.tolist())) == len(row)


@settings(max_examples=15, deadline=None)
@given(
    e=st.sampled_from([4, 8]),
    k=st.integers(1, 2),
    cf=st.sampled_from([0.5, 1.0, 1.25]),
    seed=st.integers(0, 2**31 - 1),
)
def test_onehot_output_finite_and_bounded(e, k, cf, seed):
    """Any capacity factor: finite outputs, dropped tokens → zero rows
    (identity through the residual), kept rows bounded by gate-convexity."""
    cfg = _cfg(e, k, cf)
    key = jax.random.key(seed % 9973)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(seed), (2, 32, cfg.d_model))
    y = _moe_onehot(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), vs=st.sampled_from([1, 2]))
def test_virtual_split_conserves_token_mass(seed, vs):
    """With no drops, every token's gates contribute exactly once per
    (real) expert choice regardless of the virtual split."""
    cfg = _cfg(4, 2, 2.0, vs=vs)  # cf = E/k → dropless
    p = init_moe(jax.random.key(seed % 7919), cfg)
    x = jax.random.normal(jax.random.key(seed), (1, 16, cfg.d_model))

    # linearity probe: moe(2x) with identity-ish experts keeps scaling —
    # cheap structural check that combine weights aren't double-counted
    y1 = _moe_onehot(p, cfg, x)
    # identical routing for scaled input is NOT guaranteed (router logits
    # scale), so compare against an exact vs=1 reference instead
    if vs == 2:
        e, d, f = 4, cfg.d_model, cfg.moe_d_ff
        p1 = {
            "router": p["router"],
            "experts_gate": p["experts_gate"].reshape(e, 2, d, f // 2)
            .transpose(0, 2, 1, 3).reshape(e, d, f),
            "experts_up": p["experts_up"].reshape(e, 2, d, f // 2)
            .transpose(0, 2, 1, 3).reshape(e, d, f),
            "experts_down": p["experts_down"].reshape(e, 2, f // 2, d)
            .reshape(e, f, d),
        }
        cfg1 = _cfg(4, 2, 2.0, vs=1)
        y_ref = _moe_onehot(p1, cfg1, x)
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y_ref), rtol=2e-5, atol=2e-5
        )
