"""Documentation-site integrity, enforceable without mkdocs installed.

CI's ``docs`` job runs ``mkdocs build --strict``; this tier-1 module
checks the same failure classes locally — nav entries that point at
missing pages, and relative markdown links whose targets do not exist —
so a broken docs tree fails fast even on hosts without mkdocs.
"""

from __future__ import annotations

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")


def _md_files():
    out = []
    for root, _dirs, files in os.walk(DOCS):
        out.extend(os.path.join(root, f) for f in files if f.endswith(".md"))
    return sorted(out)


def test_mkdocs_yml_nav_targets_exist():
    """Every page referenced from mkdocs.yml nav exists under docs/."""
    with open(os.path.join(REPO, "mkdocs.yml")) as f:
        text = f.read()
    # nav entries are "Title: path.md" lines; grab the .md paths
    targets = re.findall(r":\s*([\w\-/]+\.md)\s*$", text, re.MULTILINE)
    assert targets, "mkdocs.yml declares no nav pages"
    missing = [t for t in targets if not os.path.isfile(os.path.join(DOCS, t))]
    assert not missing, f"mkdocs.yml nav points at missing pages: {missing}"


def test_mkdocs_yml_parses():
    yaml = pytest.importorskip("yaml")
    with open(os.path.join(REPO, "mkdocs.yml")) as f:
        cfg = yaml.safe_load(f)
    assert cfg["site_name"]
    assert cfg["nav"], "mkdocs.yml has no nav"


def test_every_docs_page_is_reachable_from_nav():
    with open(os.path.join(REPO, "mkdocs.yml")) as f:
        nav = set(re.findall(r":\s*([\w\-/]+\.md)\s*$", f.read(), re.MULTILINE))
    pages = {os.path.relpath(p, DOCS).replace(os.sep, "/") for p in _md_files()}
    orphans = pages - nav
    assert not orphans, f"docs pages missing from mkdocs.yml nav: {sorted(orphans)}"


@pytest.mark.parametrize(
    "page", [os.path.relpath(p, REPO) for p in _md_files()], ids=lambda p: p
)
def test_docs_internal_links_resolve(page):
    """Relative links inside docs/ pages point at existing files."""
    path = os.path.join(REPO, page)
    with open(path) as f:
        text = f.read()
    base = os.path.dirname(path)
    broken = []
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            broken.append(target)
    assert not broken, f"{page}: broken relative links {broken}"


def test_readme_links_resolve():
    """The root README's repo-relative links (docs/, DESIGN.md, ...) exist."""
    with open(os.path.join(REPO, "README.md")) as f:
        text = f.read()
    broken = []
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if "://" in target:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(REPO, target))):
            broken.append(target)
    assert not broken, f"README.md: broken relative links {broken}"


def test_readme_has_required_sections():
    """The satellite contract: pitch, install, quickstart, architecture,
    and links into docs/ + DESIGN.md."""
    with open(os.path.join(REPO, "README.md")) as f:
        text = f.read()
    for needle in (
        "## Install",
        "## Quickstart",
        "## Architecture",
        "docs/index.md",
        "DESIGN.md",
        "benchmarks/README.md",
    ):
        assert needle in text, f"README.md missing {needle!r}"
