"""Ragged-tail blocking: dataset sizes that are not multiples of the block
size (normal for Dask/dislib arrays) must work under every policy."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Baseline, Collection, Rechunk, SplIter
from repro.core.apps.histogram import histogram
from repro.core.blocked import BlockedArray, round_robin_placement


@pytest.mark.parametrize(
    "policy",
    [Baseline(), SplIter(), SplIter(materialize=True), Rechunk()],
    ids=lambda p: p.mode_name,
)
@pytest.mark.parametrize("rows,block_rows", [(1000, 96), (341, 100), (97, 96)])
def test_ragged_histogram_all_policies(policy, rows, block_rows):
    rng = np.random.default_rng(0)
    pts = rng.random((rows, 3)).astype(np.float32)
    x = BlockedArray.from_array(
        jnp.asarray(pts), block_rows, num_locations=3,
        policy=round_robin_placement,
    )
    assert not x.uniform or rows % block_rows == 0
    h, rep = histogram(x, bins=4, policy=policy)
    ref = np.histogramdd(pts, bins=4, range=[(0, 1)] * 3)[0]
    np.testing.assert_array_equal(np.asarray(h), ref)


def test_ragged_spliter_dispatch_accounting():
    """A partition with a ragged tail costs at most one extra dispatch."""
    rng = np.random.default_rng(1)
    pts = rng.random((1000, 2)).astype(np.float32)  # 11 blocks of 96 + tail 40
    x = BlockedArray.from_array(
        jnp.asarray(pts), 96, num_locations=2, policy=round_robin_placement,
    )
    result, rep = (
        Collection.from_blocked(x)
        .split(SplIter())
        .map_blocks(lambda b: b.sum(0))
        .reduce(lambda a, b: a + b)
        .compute()
    )
    np.testing.assert_allclose(np.asarray(result), pts.sum(0), rtol=1e-5)
    # 2 locations; the tail block adds ≤1 dispatch per location + 1 merge
    assert rep.dispatches <= 2 * 2 + 1
