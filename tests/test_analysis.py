"""HLO collective parser + roofline arithmetic unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import parse_collectives
from repro.analysis.roofline import (
    analyze,
    model_flops,
)

SYNTH = """
HloModule test

ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128,2048]{1,0} all-reduce(%ag), to_apply=add
  %rs = bf16[64,256]{1,0} reduce-scatter(%p0), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (f32[128,2048]{1,0}) tuple(%ar)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(SYNTH)
    assert st.counts == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1,
    }
    p0 = 128 * 256 * 4
    ag = 128 * 2048 * 4
    assert st.operand_bytes["all-gather"] == p0
    assert st.operand_bytes["all-reduce"] == ag
    assert st.operand_bytes["reduce-scatter"] == p0
    assert st.operand_bytes["collective-permute"] == p0
    assert st.result_bytes["reduce-scatter"] == 64 * 256 * 2  # bf16


def test_parse_collectives_on_real_lowering():
    """Parser finds the all-reduce GSPMD inserts for a 2-device psum."""
    if jax.device_count() != 1:  # spec: main process keeps 1 device
        return
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    lowered = jax.jit(
        lambda a: a @ a, in_shardings=NamedSharding(mesh, P("data")),
    ).lower(x)
    txt = lowered.compile().as_text()
    st = parse_collectives(txt)  # 1-device: no collectives, parser is robust
    assert st.total_operand_bytes >= 0


def _mk(arch="deepseek-7b", shape="train_4k", mesh="single_pod", **kw):
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh, "devices": 256,
        "status": "OK",
        "memory": {"peak_live_bytes": int(10e9)},
        "cost": {"flops": 1e12, "bytes_accessed": 1e11},
        "collectives": {},
    }
    rec.update(kw)
    return rec


def _probe(flops, bytes_, coll, **kw):
    rec = {
        "arch": kw.get("arch", "deepseek-7b"),
        "shape": kw.get("shape", "train_4k"),
        "mesh": kw.get("mesh", "single_pod"),
        "status": "OK",
        "extrapolated": {
            "flops": flops, "bytes_accessed": bytes_,
            "collective_bytes": coll, "collective_by_kind": {},
        },
    }
    return rec


def test_roofline_terms_and_dominance():
    rows = analyze([_mk()], [_probe(1.97e14, 8.19e11, 5e10)])
    r = rows[0]
    np.testing.assert_allclose(r["compute_s"], 1.0)
    np.testing.assert_allclose(r["memory_s"], 1.0)
    np.testing.assert_allclose(r["collective_s"], 1.0)
    assert r["dominant"] in ("compute", "memory", "collective")

    rows = analyze([_mk()], [_probe(1e12, 8.19e13, 5e10)])
    assert rows[0]["dominant"] == "memory"
    rows = analyze([_mk()], [_probe(1e12, 1e9, 5e13)])
    assert rows[0]["dominant"] == "collective"


def test_roofline_skip_rows_pass_through():
    skip = {"arch": "qwen2-72b", "shape": "long_500k", "mesh": "single_pod",
            "status": "SKIP", "reason": "pure full-attention stack"}
    rows = analyze([skip], [])
    assert rows[0]["status"] == "SKIP"


def test_model_flops_train_vs_decode():
    tr = model_flops("deepseek-7b", "train_4k")
    de = model_flops("deepseek-7b", "decode_32k")
    # train: 6·N·(256·4096) vs decode: 2·N·128 → ratio = 3·4096·256/128
    np.testing.assert_allclose(tr / de, 3 * 4096 * 256 / 128, rtol=1e-6)


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config

    mixtral = get_config("mixtral-8x7b")
    counts = mixtral.param_counts()
    assert counts["active"] < 0.35 * counts["total"]  # 2-of-8 experts
    mf = model_flops("mixtral-8x7b", "train_4k")
    n_eff = counts["active"] - mixtral.padded_vocab * mixtral.d_model
    np.testing.assert_allclose(mf, 6 * n_eff * 256 * 4096, rtol=1e-6)
