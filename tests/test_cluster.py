"""ClusterExecutor — multi-process scheduling, locality, fault tolerance.

The acceptance contract of DESIGN.md §11:

* bit-identical results to LocalExecutor on all four apps (histogram,
  kmeans, knn, svm) — including with injected worker kills mid-run
  (``EngineReport.retries >= 1``);
* chunk-backed plans resolve blocks worker-side from the handed-off
  DiskStore (bytes never transit the control channel), and a kill releases
  the dead dispatch's pins on requeue;
* two sequential kills of the same unit exhaust ``max_retries`` and raise
  a typed :class:`ClusterFailedError` naming the poisoned task key;
* every executor's ``close()`` is idempotent (the shared base-class sweep).

The CI ``cluster-fault-lane`` job runs exactly this module with
``REPRO_CLUSTER_LOG_DIR`` set, uploading per-worker logs as artifacts on
failure.
"""

from __future__ import annotations

import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Baseline,
    ClusterExecutor,
    ClusterFailedError,
    Collection,
    DiskStore,
    Executor,
    FaultPlan,
    LocalExecutor,
    MeshExecutor,
    SplIter,
    StreamExecutor,
    ThreadedExecutor,
    decode_fn,
    encode_fn,
)
from repro.api import shm_available
from repro.api.executors import _SchedulerState, _Unit
from repro.api.shm import leaked_segments
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.apps.knn import knn
from repro.core.blocked import BlockedArray, round_robin_placement

LOG_DIR = os.environ.get("REPRO_CLUSTER_LOG_DIR")  # CI fault lane artifacts
POL = SplIter(partitions_per_location=2)
needs_shm = pytest.mark.skipif(
    not shm_available(), reason="host has no POSIX shared memory"
)


def _cluster(**kw) -> ClusterExecutor:
    kw.setdefault("log_dir", LOG_DIR)
    return ClusterExecutor(**kw)


def _blocked(a, block_rows=256, locs=2) -> BlockedArray:
    return BlockedArray.from_array(
        jnp.asarray(a), block_rows, num_locations=locs, policy=round_robin_placement
    )


@pytest.fixture(scope="module")
def points() -> BlockedArray:
    rng = np.random.default_rng(0)
    return _blocked(rng.random((2048, 4)).astype(np.float32))


@pytest.fixture(scope="module")
def cluster():
    """One shared pool for the fault-free tests (spawn paid once)."""
    ex = _cluster()
    yield ex
    ex.close()


def identical(a, b) -> bool:
    return bool(jnp.all(jnp.equal(a, b)))


# ---------------------------------------------------------------------------
# bit-identity vs LocalExecutor — all four apps
# ---------------------------------------------------------------------------


class TestBitIdentity:
    def test_histogram(self, points, cluster):
        ref, ref_rep = histogram(points, bins=8, policy=POL)
        h, rep = histogram(points, bins=8, policy=POL, executor=cluster)
        assert identical(h, ref)
        assert rep.dispatches == ref_rep.dispatches  # C1 parity over IPC
        assert rep.remote_dispatches == ref_rep.dispatches - ref_rep.merges
        assert rep.ipc_bytes > 0 and rep.retries == 0

    def test_histogram_pallas_fusion(self, points, cluster):
        pol = SplIter(partitions_per_location=2, fusion="pallas")
        ref, _ = histogram(points, bins=8, policy=pol)
        h, rep = histogram(points, bins=8, policy=pol, executor=cluster)
        assert identical(h, ref)
        assert rep.remote_dispatches >= 1  # kernel rehydrated by name remotely

    def test_kmeans(self, points, cluster):
        ref = kmeans(points, k=4, iters=3, policy=POL)
        res = kmeans(points, k=4, iters=3, policy=POL, executor=cluster)
        assert identical(res.centers, ref.centers)
        assert sum(r.remote_dispatches for r in res.reports) >= 3 * 4

    def test_knn(self, points, cluster):
        rng = np.random.default_rng(1)
        qry = _blocked(rng.random((256, 4)).astype(np.float32), 128)
        ref = knn(points, qry, k=4, policy=POL)
        res = knn(points, qry, k=4, policy=POL, executor=cluster)
        assert identical(res.indices, ref.indices)
        assert identical(res.distances, ref.distances)
        # fit builds + lookup/merge loops are driver RPCs on the cluster
        assert res.report.remote_dispatches >= 1

    def test_svm(self, points, cluster):
        rng = np.random.default_rng(2)
        y = _blocked(np.where(rng.random(2048) > 0.5, 1.0, -1.0).astype(np.float32))
        ref = cascade_svm(points, y, num_sv=16, steps=30, iterations=1, policy=POL)
        res = cascade_svm(
            points, y, num_sv=16, steps=30, iterations=1, policy=POL, executor=cluster
        )
        assert identical(res.sv_x, ref.sv_x)
        assert identical(res.sv_y, ref.sv_y)
        assert res.report.remote_dispatches >= 1

    def test_unreduced_map_partials_order(self, points, cluster):
        plan = Collection.from_blocked(points).split(Baseline()).map_blocks(
            lambda b: jnp.sum(b, axis=0)
        )
        ref = plan.compute(executor=LocalExecutor())
        got = plan.compute(executor=cluster)
        assert len(got.value) == len(ref.value) == points.num_blocks
        for g, r in zip(got.value, ref.value):
            assert identical(g, r)


def test_large_payloads_do_not_deadlock_pipes():
    """Regression: both control-channel directions are blocking writes over
    ~64KB OS pipes.  In-memory plans ship operands inline, so a single
    command or result above the buffer used to let the parent block in
    ``send_bytes`` while the worker blocked writing its reply — a mutual
    hang.  The one-un-replied-command-per-worker window must keep every
    send aimed at a worker that is parked in ``recv``."""
    rng = np.random.default_rng(3)
    big = _blocked(rng.random((2048, 128)).astype(np.float32), 256)  # 128KB/block
    plan = Collection.from_blocked(big).split(Baseline()).map_blocks(
        lambda b: b * 2.0
    )
    ref = plan.compute(executor=LocalExecutor())
    ex = _cluster(shm=False)  # force inline payloads: this test IS the pipe path
    box: dict = {}

    def run():
        box["got"] = plan.compute(executor=ex)

    t = threading.Thread(target=run, daemon=True)  # watchdog: hang -> fail, not CI stall
    t.start()
    t.join(timeout=180)
    try:
        if t.is_alive():
            pytest.fail("cluster run deadlocked on >64KB pipe payloads")
    finally:
        if not t.is_alive():
            ex.close()
    got = box["got"]
    assert got.report.remote_dispatches >= 1
    # operands AND results crossed the wire: ipc dwarfs the dataset
    assert got.report.ipc_bytes > 1.9 * big.nbytes
    for g, r in zip(got.value, ref.value):
        assert identical(g, r)


# ---------------------------------------------------------------------------
# chunk-backed plans: bytes stay off the control channel
# ---------------------------------------------------------------------------


def test_chunk_handles_keep_bytes_off_the_wire(points):
    """shm=False — the PR 5 spill-file path, unchanged by the data plane."""
    ref, _ = histogram(points, bins=8, policy=POL)
    ex_mem = _cluster(shm=False)
    _, rep_mem = histogram(points, bins=8, policy=POL, executor=ex_mem)
    ex_mem.close()

    store = DiskStore(residency_bytes=1 << 20)
    chunked = points.to_store(store)
    ex = _cluster(shm=False)
    h, rep = histogram(chunked, bins=8, policy=POL, executor=ex)
    ex.close()
    assert identical(h, ref)
    # operands travel as ChunkHandles resolved worker-side from the
    # manifested spill files: vs the in-memory run, (at least) the whole
    # dataset's bytes disappear from the control channel and reappear as
    # worker-side spill reads (bytes_loaded).
    assert rep_mem.ipc_bytes - rep.ipc_bytes > 0.9 * points.nbytes
    assert rep.bytes_loaded >= points.nbytes
    assert all(not store.is_pinned(r) for r in chunked.blocks)
    store.close()


@needs_shm
def test_chunk_manifest_hands_off_via_shm_without_spilling(points):
    """shm on — resident chunks manifest as segments: no spill, no loads."""
    ref, _ = histogram(points, bins=8, policy=POL)
    store = DiskStore(residency_bytes=64 << 20)  # everything stays resident
    chunked = points.to_store(store)
    ex = _cluster(shm=True)
    h, rep = histogram(chunked, bins=8, policy=POL, executor=ex)
    assert identical(h, ref)
    # The old handoff force-spilled EVERY chunk; shm-first writes nothing
    # to disk and workers read segments, not files.  (Asserted before
    # close(): the close-time trim legitimately spills the residency
    # cache, which is release bookkeeping, not handoff traffic.)
    assert store.stats.spills == 0 and store.stats.bytes_spilled == 0
    ex.close()
    assert rep.bytes_spilled == 0 and rep.bytes_loaded == 0
    assert rep.shm_bytes >= points.nbytes  # each chunk copied exactly once
    assert rep.ipc_bytes < points.nbytes  # descriptors, not block bytes
    assert all(not store.is_pinned(r) for r in chunked.blocks)
    store.close()


# ---------------------------------------------------------------------------
# the shared-memory data plane — the PR 7 acceptance numbers
# ---------------------------------------------------------------------------


@needs_shm
class TestShmDataPlane:
    """Block payloads move through /dev/shm; the pipes carry descriptors.

    The acceptance bar: ≥10× less control-channel traffic on the two
    payload-heavy apps (knn ships fit structures into every lookup RPC,
    cascade_svm ships group matrices into every cascade level), with
    results bit-identical to both LocalExecutor and the shm-off cluster.
    """

    def _run_both(self, app):
        out = {}
        for shm in (False, True):
            ex = _cluster(shm=shm)
            try:
                for _ in range(2):  # 2nd call: steady-state, export cache warm
                    res = app(ex)
            finally:
                ex.close()
            out[shm] = res
        return out[False], out[True]

    def test_knn_ipc_bytes_drop_10x(self):
        rng = np.random.default_rng(0)
        fit = _blocked(rng.random((2048, 3)).astype(np.float32))
        qry = _blocked(rng.random((512, 3)).astype(np.float32), 256)
        ref = knn(fit, qry, k=4, policy=POL)
        off, on = self._run_both(lambda ex: knn(fit, qry, k=4, policy=POL, executor=ex))
        for res in (off, on):
            assert identical(res.indices, ref.indices)
            assert identical(res.distances, ref.distances)
        assert off.report.ipc_bytes >= 10 * on.report.ipc_bytes
        assert on.report.shm_bytes > 0 and off.report.shm_bytes == 0

    def test_svm_ipc_bytes_drop_10x(self):
        rng = np.random.default_rng(0)
        pts = rng.standard_normal((4096, 16)).astype(np.float32)
        w = rng.standard_normal(16).astype(np.float32)
        labels = np.sign(pts @ w + 0.05 * rng.standard_normal(4096)).astype(np.float32)
        x, y = _blocked(pts, 512), _blocked(labels, 512)

        def app(ex):
            return cascade_svm(
                x, y, num_sv=32, steps=30, iterations=1, policy=POL, executor=ex
            )

        ref = cascade_svm(x, y, num_sv=32, steps=30, iterations=1, policy=POL)
        off, on = self._run_both(app)
        for res in (off, on):
            assert identical(res.sv_x, ref.sv_x)
            assert identical(res.sv_y, ref.sv_y)
        assert off.report.ipc_bytes >= 10 * on.report.ipc_bytes
        assert on.report.shm_bytes > 0

    def test_grown_store_reattaches_as_a_delta(self, points):
        # A second dataset lands in an ALREADY handed-off store: workers
        # hold an attach from run 1, so run 2 must ship only the new
        # chunks' descriptors (manifest delta, merged in place) — not
        # re-manifest, re-spill, or re-send the world.
        store = DiskStore(residency_bytes=64 << 20)
        chunked = points.to_store(store)
        ref, _ = histogram(points, bins=8, policy=POL)
        ex = _cluster(shm=True)
        h1, _ = histogram(chunked, bins=8, policy=POL, executor=ex)
        assert identical(h1, ref)
        rng = np.random.default_rng(7)
        pts2 = _blocked(rng.random((1024, 4)).astype(np.float32))
        ref2, _ = histogram(pts2, bins=8, policy=POL)
        chunked2 = pts2.to_store(store)  # the SAME store, grown mid-session
        h2, rep2 = histogram(chunked2, bins=8, policy=POL, executor=ex)
        assert identical(h2, ref2)
        assert store.stats.spills == 0  # delta handed off via shm too
        assert rep2.ipc_bytes < pts2.nbytes  # descriptors, not block bytes
        ex.close()
        store.close()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class TestFaultTolerance:
    def test_kill_midrun_replays_bit_identical(self, points):
        ref, _ = histogram(points, bins=8, policy=SplIter(partitions_per_location=4))
        ex = _cluster(fault_plan=FaultPlan(kill_after=((0, 2),)))
        h, rep = histogram(
            points, bins=8, policy=SplIter(partitions_per_location=4), executor=ex
        )
        assert identical(h, ref)
        assert rep.retries >= 1
        # the pool healed onto survivors: a follow-up run still works
        h2, rep2 = histogram(
            points, bins=8, policy=SplIter(partitions_per_location=4), executor=ex
        )
        assert identical(h2, ref) and rep2.retries == 0
        ex.close()

    def test_kill_during_merge_dependency_wait(self, points):
        # Worker 0 dies on its LAST queued unit: by then every task unit
        # is dispatched and the parent is parked waiting for the merge
        # unit's dependencies — the requeue must un-stick that wait.
        pol = SplIter(partitions_per_location=4)
        ref, ref_rep = histogram(points, bins=8, policy=pol)
        ex = _cluster(fault_plan=FaultPlan(kill_after=((0, 4),)))
        h, rep = histogram(points, bins=8, policy=pol, executor=ex)
        ex.close()
        assert identical(h, ref)
        assert rep.retries >= 1
        assert rep.merges == ref_rep.merges  # the merge still ran, once

    def test_kill_worker_owning_pinned_chunk_releases_pins(self, points):
        store = DiskStore(residency_bytes=1 << 20)
        chunked = points.to_store(store)
        pol = SplIter(partitions_per_location=4)
        ref, _ = histogram(points, bins=8, policy=pol)
        ex = _cluster(fault_plan=FaultPlan(kill_after=((1, 1),)))
        h, rep = histogram(chunked, bins=8, policy=pol, executor=ex)
        ex.close()
        assert identical(h, ref)
        assert rep.retries >= 1
        # release-on-requeue: no pin outlives the dead dispatch
        assert all(not store.is_pinned(r) for r in chunked.blocks)
        store.close()

    def test_two_kills_exhaust_max_retries(self, points):
        # worker 0 dies on first receipt; the replay lands on surviving
        # worker 1, which dies on any retried unit → attempts exceed
        # max_retries=1 → typed failure naming the poisoned task.
        ex = _cluster(
            max_retries=1,
            fault_plan=FaultPlan(kill_after=((0, 1),), kill_on_retry=(1,)),
        )
        with pytest.raises(ClusterFailedError, match="poisoned") as ei:
            histogram(points, bins=8, policy=POL, executor=ex)
        assert ei.value.task_key is not None
        assert "histogramdd_block" in ei.value.task_key
        # the error carries the full attempt history: both deaths, with
        # worker ids and a per-attempt cause summary
        assert len(ei.value.attempts) >= 2
        assert len({a["worker"] for a in ei.value.attempts}) >= 2
        assert all(a["error"] for a in ei.value.attempts)
        assert "attempt history" in str(ei.value)
        if LOG_DIR:
            # with worker logging on, the error points at the log files
            assert ei.value.log_paths
            assert all(p.startswith(LOG_DIR) for p in ei.value.log_paths)
        # the executor survives the failure: fresh workers, clean run
        ref, _ = histogram(points, bins=8, policy=POL)
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        assert identical(h, ref)
        ex.close()

    def test_send_boundary_death_requeues_unit(self, points):
        # A worker that passes the liveness check but whose command pipe
        # is already torn raises OSError inside the send itself.  The unit
        # is assigned before the transport is touched, so the death
        # sweep's requeue must replay it — not silently lose it.
        ref, _ = histogram(points, bins=8, policy=POL)
        ex = _cluster()
        h0, _ = histogram(points, bins=8, policy=POL, executor=ex)  # warm pool
        assert identical(h0, ref)
        ex._workers[0]._conn.close()  # torn transport, process still alive
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        ex.close()
        assert identical(h, ref)
        assert rep.retries >= 1

    def test_driver_rpc_retries_on_worker_death(self, points):
        rng = np.random.default_rng(1)
        qry = _blocked(rng.random((256, 4)).astype(np.float32), 128)
        ref = knn(points, qry, k=4, policy=POL)
        ex = _cluster(fault_plan=FaultPlan(kill_after=((0, 3),)))
        res = knn(points, qry, k=4, policy=POL, executor=ex)
        ex.close()
        assert identical(res.indices, ref.indices)
        assert res.report.retries >= 1

    @needs_shm
    def test_kill_midrun_leaks_no_shm_segments(self, points):
        # The dead worker's in-flight reply segment (and every operand
        # segment pinned for its units) must be swept: close() leaves
        # /dev/shm with zero entries under this executor's prefix.
        pol = SplIter(partitions_per_location=4)
        ref, _ = histogram(points, bins=8, policy=pol)
        ex = _cluster(fault_plan=FaultPlan(kill_after=((0, 2),)))
        prefix = ex._shm.prefix
        h, rep = histogram(points, bins=8, policy=pol, executor=ex)
        assert identical(h, ref)
        assert rep.retries >= 1
        ex.close()
        assert leaked_segments(prefix) == []

    @needs_shm
    def test_poisoned_run_leaks_no_shm_segments(self, points):
        # Even the failure path — two kills, typed ClusterFailedError,
        # partial results discarded — must unwind every segment.
        ex = _cluster(
            max_retries=1,
            fault_plan=FaultPlan(kill_after=((0, 1),), kill_on_retry=(1,)),
        )
        prefix = ex._shm.prefix
        with pytest.raises(ClusterFailedError):
            histogram(points, bins=8, policy=POL, executor=ex)
        ex.close()
        assert leaked_segments(prefix) == []

    def test_hung_worker_detected_by_heartbeat_timeout(self, points):
        # mute: the worker process stays alive but stops heartbeating and
        # never replies — only the staleness detector can reclaim it.
        ex = _cluster(
            fault_plan=FaultPlan(mute_after=((0, 2),)),
            heartbeat_s=0.1,
            heartbeat_timeout_s=1.5,
        )
        ref, _ = histogram(points, bins=8, policy=POL)
        h, rep = histogram(points, bins=8, policy=POL, executor=ex)
        ex.close()
        assert identical(h, ref)
        assert rep.retries >= 1


# ---------------------------------------------------------------------------
# scheduler-state ownership hooks (the requeue substrate)
# ---------------------------------------------------------------------------


def test_scheduler_state_requeue_hooks():
    units = [
        _Unit(index=i, location=0, tasks=(), run=lambda: i, kind="task")
        for i in range(3)
    ]
    state = _SchedulerState(units)
    state.assign(units[0], "w0")
    state.assign(units[1], "w0")
    state.assign(units[2], "w1")
    state.complete(units[1], "done-1")
    lost = state.requeue("w0")
    assert [u.index for u in lost] == [0]  # completed unit 1 is not lost
    assert state.attempts[0] == 1
    state.assign(units[0], "w1")
    assert state.attempts[0] == 2
    # duplicate completion (late reply from a presumed-dead worker) is a no-op
    assert state.complete(units[1], "dup") == []
    assert state.results[1] == "done-1"
    assert state.is_done(1) and not state.is_done(0)


def test_fnref_roundtrip():
    import functools

    from repro.core.apps.kmeans import _combine, partial_sum_block

    # importable module-level fn
    ref = encode_fn(_combine)
    assert ref[0] == "import"
    assert decode_fn(ref) is _combine
    # partial with picklable statics
    p = functools.partial(partial_sum_block)
    assert decode_fn(encode_fn(p)).func is partial_sum_block
    # closure lambda → code ref that computes the same thing
    k = 3
    f = lambda x: x * k  # noqa: E731 — the shape under test
    g = decode_fn(encode_fn(f))
    assert g(7) == 21
    # unpicklable closure cell → not remotable
    lock = threading.Lock()
    assert encode_fn(lambda x: (lock, x)) is None


def test_cluster_executor_satisfies_protocol():
    ex = ClusterExecutor()
    assert isinstance(ex, Executor)
    ex.close()


# ---------------------------------------------------------------------------
# close() idempotence — the shared base-class sweep (all five backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "make",
    [LocalExecutor, ThreadedExecutor, MeshExecutor, StreamExecutor, ClusterExecutor],
    ids=lambda c: c.__name__,
)
def test_close_is_idempotent(make, points):
    ex = make()
    _, _ = histogram(points, bins=8, policy=POL, executor=ex)
    ex.close()
    ex.close()  # second close must be a clean no-op
    # close → reuse → close: pools/workers respawn transparently
    h, _ = histogram(points, bins=8, policy=POL, executor=ex)
    ref, _ = histogram(points, bins=8, policy=POL)
    assert identical(h, ref)
    ex.close()
    ex.close()


def test_stream_close_twice_with_disk_store(points):
    """The close-idempotence satellite's regression: double close must not
    re-enter the (already closed) store's teardown."""
    store = DiskStore(residency_bytes=1 << 14)
    chunked = points.to_store(store)
    ex = StreamExecutor()
    _, _ = histogram(chunked, bins=8, policy=POL, executor=ex)
    ex.close()
    assert store.closed
    ex.close()  # second close: store already gone, must not raise
    assert store.closed
