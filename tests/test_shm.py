"""The shared-memory data plane (repro.api.shm) and its billing contracts.

What PR 7's tentpole must guarantee, independent of any cluster run:

* ``ShmBlockRef`` descriptors pickle to ~100 bytes and round-trip exactly;
* the arena (``ShmStore``) caches exports by identity (one copy per block,
  ever), declines over-budget exports instead of erroring, evicts only
  unpinned/unlocked segments, and ``close()`` leaves ``/dev/shm`` clean;
* the ChunkStore contract holds (put/get bit-identity, budget errors);
* reply transport (``pack_tree``/``unpack_tree``/``discard_tree``) has a
  strict send→consume→unlink lifecycle — no segment outlives its reply;
* ``DiskStore.manifest`` is shm-first and incremental, and bills
  ``spills``/``bytes_spilled`` only for genuinely new spill writes;
* ``EngineReport`` aggregation/serialization is field-registry driven, so
  ``shm_bytes`` (and any future counter) sums and round-trips untouched.
"""

from __future__ import annotations

import dataclasses
import pickle

import numpy as np
import pytest

from repro.api import ChunkRef, DiskStore
from repro.api.chunkstore import ChunkStoreError
from repro.api.shm import (
    ShmAttachments,
    ShmBlockRef,
    ShmStore,
    discard_tree,
    leaked_segments,
    pack_tree,
    shm_available,
    sweep_segments,
    unpack_tree,
)
from repro.core.engine import _FIELD_RULES, EngineReport

pytestmark = pytest.mark.skipif(
    not shm_available(), reason="host has no POSIX shared memory"
)


def _arr(n=1024, seed=0, shape=None):
    rng = np.random.default_rng(seed)
    return rng.random(shape or (n,)).astype(np.float64)


# ---------------------------------------------------------------------------
# descriptors
# ---------------------------------------------------------------------------


def test_block_ref_pickles_small_and_exact():
    ref = ShmBlockRef("rshm1x1a1", 256, (128, 4), "<f4")
    blob = pickle.dumps(ref)
    assert len(blob) < 200  # the whole point: descriptors, not payloads
    assert pickle.loads(blob) == ref
    assert ref.nbytes == 128 * 4 * 4


# ---------------------------------------------------------------------------
# the arena
# ---------------------------------------------------------------------------


class TestShmStore:
    def test_export_round_trip(self):
        a = _arr()
        with ShmStore() as store:
            ref, wrote = store.export(a)
            assert ref is not None and wrote == a.nbytes
            att = ShmAttachments()
            view = att.view(ref)
            assert not view.flags.writeable
            np.testing.assert_array_equal(view, a)
            att.close()

    def test_export_caches_by_identity(self):
        a = _arr()
        with ShmStore() as store:
            ref1, wrote1 = store.export(a)
            ref2, wrote2 = store.export(a)
            assert ref1 == ref2
            assert wrote1 == a.nbytes and wrote2 == 0  # one copy, ever
            assert store.bytes_exported == a.nbytes

    def test_small_blocks_decline(self):
        with ShmStore(min_bytes=1024) as store:
            ref, wrote = store.export(np.zeros(4))
            assert ref is None and wrote == 0

    def test_budget_exhaustion_declines_not_raises(self):
        with ShmStore(budget_bytes=1 << 16, segment_bytes=1 << 16) as store:
            pinned, _ = store.export(_arr(4096, seed=1))  # 32KB
            store.pin_refs([pinned])
            b, c = _arr(4096, seed=2), _arr(8192, seed=3)
            refb, _ = store.export(b)
            store.pin_refs([refb])
            # nothing evictable is left and c does not fit: decline
            refc, wrote = store.export(c)
            assert refc is None and wrote == 0
            store.unpin_refs([pinned])
            store.unpin_refs([refb])

    def test_lru_eviction_spares_pinned_segments(self):
        # 32KB segments, 96KB budget: each 32KB export fills one segment.
        with ShmStore(budget_bytes=3 << 15, segment_bytes=1 << 15) as store:
            a, b, c = (_arr(4096, seed=i) for i in (1, 2, 3))
            ra, _ = store.export(a)
            rb, _ = store.export(b)
            store.pin_refs([ra])
            rc, _ = store.export(c)  # budget now fully allocated
            rd, _ = store.export(_arr(4096, seed=4))  # must evict one segment
            assert rd is not None
            live = store.live_segments()
            assert ra.segment in live  # pinned survived
            assert rc.segment in live  # recently used survived
            assert rb.segment not in live  # LRU unpinned victim
            store.unpin_refs([ra])

    def test_close_unlinks_everything_and_is_reusable(self):
        store = ShmStore()
        store.export(_arr(seed=4))
        prefix = store.prefix
        assert leaked_segments(prefix)
        store.close()
        assert leaked_segments(prefix) == []
        ref, wrote = store.export(_arr(seed=5))  # reusable after close
        assert ref is not None and wrote > 0
        store.close()
        assert leaked_segments(prefix) == []


class TestShmChunkStore:
    def test_put_get_bit_identical(self):
        a = _arr(seed=6).astype(np.float32)  # jnp round-trips f32 untouched
        with ShmStore() as store:
            ref = store.put(a)
            assert isinstance(ref, ChunkRef)
            np.testing.assert_array_equal(np.asarray(store.get(ref)), a)
            assert store.handle(ref) is not None  # picklable descriptor

    def test_put_ignores_min_bytes_floor(self):
        with ShmStore(min_bytes=1 << 20) as store:
            ref = store.put(np.arange(8.0))
            np.testing.assert_array_equal(np.asarray(store.get(ref)), np.arange(8.0))

    def test_put_raises_when_budget_exhausted(self):
        with ShmStore(budget_bytes=1 << 14, segment_bytes=1 << 14) as store:
            store.put(_arr(1024, seed=7))  # 8KB, locked by put
            with pytest.raises(ChunkStoreError):
                store.put(_arr(4096, seed=8))  # 32KB can never fit


# ---------------------------------------------------------------------------
# reply transport
# ---------------------------------------------------------------------------


class TestReplyTransport:
    def test_pack_unpack_round_trip_unlinks(self):
        tree = {"big": _arr(1024, seed=9), "small": np.float64(3.5)}
        packed, seg, wrote = pack_tree(tree, threshold=1024, name="rshmtestp1")
        assert seg == "rshmtestp1" and wrote == tree["big"].nbytes
        assert isinstance(packed["big"], ShmBlockRef)
        assert packed["small"] == tree["small"]  # under threshold: inline
        out, segs = unpack_tree(packed)
        np.testing.assert_array_equal(out["big"], tree["big"])
        assert segs == ["rshmtestp1"]
        assert leaked_segments("rshmtestp1") == []  # consume == unlink

    def test_pack_without_big_leaves_is_a_no_op(self):
        tree = (np.arange(4.0), 7)
        packed, seg, wrote = pack_tree(tree, threshold=1024, name="rshmtestp2")
        assert seg is None and wrote == 0 and packed is tree
        assert leaked_segments("rshmtestp2") == []

    def test_discard_tree_unlinks_unconsumed_replies(self):
        packed, seg, _ = pack_tree(
            [_arr(1024, seed=10)], threshold=1024, name="rshmtestp3"
        )
        assert leaked_segments("rshmtestp3") == [seg]
        discard_tree(packed)  # the stale-reply path
        assert leaked_segments("rshmtestp3") == []

    def test_sweep_reaps_orphans(self):
        pack_tree([_arr(1024, seed=11)], threshold=1024, name="rshmtestp4x1")
        pack_tree([_arr(1024, seed=12)], threshold=1024, name="rshmtestp4x2")
        assert sweep_segments("rshmtestp4") == 2
        assert leaked_segments("rshmtestp4") == []


# ---------------------------------------------------------------------------
# DiskStore.manifest — shm-first, incremental, honest billing
# ---------------------------------------------------------------------------


class TestManifestHandoff:
    def _store_with_chunks(self, n=4):
        store = DiskStore(residency_bytes=64 << 20)
        refs = [store.put(_arr(512, seed=20 + i)) for i in range(n)]
        return store, refs

    def test_shm_first_writes_no_files(self):
        store, refs = self._store_with_chunks()
        with ShmStore() as arena:

            def export(cid, arr):
                ref, _ = arena.export(arr, key=cid, min_bytes=0, lock=True)
                return ref

            m = store.manifest(export=export)
            assert {tag for tag, *_ in m.chunks.values()} == {"shm"}
            assert store.stats.spills == 0 and store.stats.bytes_spilled == 0
            assert len(m.chunks) == len(refs)
        store.close()

    def test_fallback_spill_billed_once(self):
        store, refs = self._store_with_chunks()
        m1 = store.manifest()  # no exporter: force-spill path
        assert {tag for tag, *_ in m1.chunks.values()} == {"file"}
        first = (store.stats.spills, store.stats.bytes_spilled)
        assert first[0] == len(refs) and first[1] > 0
        # regression (the PR 5 bug): a second manifest re-spilled and
        # re-billed the world; now chunks with files reuse them for free.
        m2 = store.manifest()
        assert (store.stats.spills, store.stats.bytes_spilled) == first
        assert m2.chunks.keys() == m1.chunks.keys()
        store.close()

    def test_known_yields_only_the_delta(self):
        store, _ = self._store_with_chunks(n=2)
        m1 = store.manifest()
        grown = store.put(_arr(512, seed=99))
        delta = store.manifest(known=m1.chunks.keys())
        assert set(delta.chunks) == {grown.chunk_id}
        store.close()

    def test_manifested_resident_chunks_get_handles(self):
        store, refs = self._store_with_chunks(n=2)
        assert store.handle(refs[0]) is None  # resident, never handed off
        with ShmStore() as arena:

            def export(cid, arr):
                ref, _ = arena.export(arr, key=cid, min_bytes=0, lock=True)
                return ref

            store.manifest(export=export)
            h = store.handle(refs[0])  # no spill file, but manifested
            assert h is not None and h.chunk_id == refs[0].chunk_id
        store.close()


# ---------------------------------------------------------------------------
# EngineReport — the single field registry drives every aggregation path
# ---------------------------------------------------------------------------


class TestReportFieldRegistry:
    def test_registry_covers_every_non_sum_field(self):
        names = {f.name for f in dataclasses.fields(EngineReport)}
        assert set(_FIELD_RULES) <= names
        assert _FIELD_RULES["mode"] == "label"

    def test_every_field_round_trips_and_sums_generically(self):
        # Fill EVERY field with a distinct value — a future counter that
        # misses to_json/from_json/__iadd__ fails here without a hand edit.
        kw = {
            f.name: ("m" if f.name == "mode" else i + 1)
            for i, f in enumerate(dataclasses.fields(EngineReport))
        }
        rep = EngineReport(**kw)
        assert EngineReport.from_json(rep.to_json()) == rep
        summed = EngineReport.from_json(rep.to_json())
        summed += rep
        for f in dataclasses.fields(EngineReport):
            rule = _FIELD_RULES.get(f.name, "sum")
            want = {
                "sum": kw[f.name] * 2,
                "latest": kw[f.name],
                "label": kw[f.name],
            }[rule]
            assert getattr(summed, f.name) == want, f.name

    def test_shm_bytes_is_a_summed_counter(self):
        a = EngineReport(mode="x", shm_bytes=100, ipc_bytes=5)
        b = EngineReport(mode="x", shm_bytes=40, ipc_bytes=2)
        out = a.merge(b)
        assert (out.shm_bytes, out.ipc_bytes) == (140, 7)
        assert EngineReport.from_json(out.to_json()).shm_bytes == 140
