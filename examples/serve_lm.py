"""Batched serving example (deliverable b) — serve a smoke-sized model with
batched requests: one prefill dispatch, then a fused decode loop.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if not any(a.startswith("--arch") for a in sys.argv[1:]):
        sys.argv += ["--arch", "qwen3-32b"]
    main()
