"""Quickstart: the SplIter in 60 lines.

A blocked dataset is distributed across locations; the baseline dispatches
one task per block, the SplIter dispatches one task per *locality
partition* and iterates the local blocks inside it — zero data movement.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.blocked import BlockedArray, round_robin_placement
from repro.core.engine import run_map_reduce
from repro.core.spliter import spliter

# -- 1. a blocked, distributed dataset --------------------------------------
# 64 blocks of 128 five-dimensional points, scattered round-robin over
# 8 logical locations (nodes/workers/devices).
rng = np.random.default_rng(0)
data = rng.random((64 * 128, 5)).astype(np.float32)
x = BlockedArray.from_array(
    jnp.asarray(data), block_rows=128, num_locations=8,
    policy=round_robin_placement,
)
print(f"dataset: {x.num_rows} rows, {x.num_blocks} blocks, "
      f"{x.num_locations} locations")

# -- 2. split(): locality partitions, zero movement --------------------------
parts = spliter(x)
for p in parts[:3]:
    print(f"partition@loc{p.location}: blocks {p.get_indexes()[:4]}..., "
          f"{p.num_rows} rows")
print(f"... {len(parts)} partitions total (1 per location)")

# -- 3. iterate: the same map-reduce, three execution strategies -------------
def block_mean_sum(block):          # per-block work
    return block.sum(axis=0)

combine = lambda a, b: a + b        # associative merge

for mode in ("baseline", "spliter", "rechunk"):
    result, report = run_map_reduce([x], block_mean_sum, combine, mode=mode)
    mean = result / x.num_rows
    print(f"{mode:10s} dispatches={report.dispatches:3d} "
          f"bytes_moved={report.bytes_moved:10d}  mean[0]={float(mean[0]):.6f}")

# baseline: 64 block tasks + merge;  spliter: 8 partition tasks + merge,
# 0 bytes moved;  rechunk: 8 tasks but Θ(dataset) bytes shuffled first.

# -- 4. order restoration (paper §4.1) ---------------------------------------
p0 = parts[0]
print("get_indexes()      ->", p0.get_indexes()[:8])
print("get_item_indexes() ->", p0.get_item_indexes()[:8], "...")
