"""Quickstart: the SplIter in 60 lines, on the lazy Collection API.

A blocked dataset is distributed across locations; the ``Baseline`` policy
dispatches one task per block, the ``SplIter`` policy dispatches one task
per *locality partition* and iterates the local blocks inside it — zero
data movement.  A fluent chain builds a lazy plan; nothing runs until
``.compute(executor=...)``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import (
    Baseline,
    Collection,
    DiskStore,
    EngineConfig,
    FaultPlan,
    JobClient,
    Rechunk,
    SplIter,
    engine,
)
from repro.core.blocked import BlockedArray, round_robin_placement
from repro.core.spliter import spliter


# NOTE: the script body lives under a __main__ guard because §11 spawns
# worker PROCESSES — like any multiprocessing program, the entry point
# must be import-safe or spawned children would re-execute the script.
def main():
    # -- 1. a blocked, distributed dataset --------------------------------------
    # 64 blocks of 128 five-dimensional points, scattered round-robin over
    # 8 logical locations (nodes/workers/devices).
    rng = np.random.default_rng(0)
    data = rng.random((64 * 128, 5)).astype(np.float32)
    x = BlockedArray.from_array(
        jnp.asarray(data), block_rows=128, num_locations=8,
        policy=round_robin_placement,
    )
    print(f"dataset: {x.num_rows} rows, {x.num_blocks} blocks, "
          f"{x.num_locations} locations")

    # -- 2. split(): locality partitions, zero movement --------------------------
    parts = spliter(x)
    for p in parts[:3]:
        print(f"partition@loc{p.location}: blocks {p.get_indexes()[:4]}..., "
              f"{p.num_rows} rows")
    print(f"... {len(parts)} partitions total (1 per location)")

    # -- 3. one lazy plan, three execution policies ------------------------------
    def block_sum(block):               # per-block work
        return block.sum(axis=0)

    combine = lambda a, b: a + b        # associative merge

    col = Collection.from_blocked(x)
    for policy in (Baseline(), SplIter(), Rechunk()):
        plan = col.split(policy).map_blocks(block_sum).reduce(combine)
        result, report = plan.compute(executor=engine("local"))
        mean = result / x.num_rows
        print(f"{policy.mode_name:10s} dispatches={report.dispatches:3d} "
              f"bytes_moved={report.bytes_moved:10d}  mean[0]={float(mean[0]):.6f}")

    # baseline: 64 block tasks + merge;  spliter: 8 partition tasks + merge,
    # 0 bytes moved;  rechunk: 8 tasks but Θ(dataset) bytes shuffled first.

    # -- 4. the plan is inspectable before it runs --------------------------------
    print(col.split(SplIter()).map_blocks(block_sum).reduce(combine).plan().describe())

    # -- 5. ThreadedExecutor: one worker thread per location, identical result ----
    seq = col.split(SplIter()).map_blocks(block_sum).reduce(combine).compute(
        executor=engine("local"))
    thr = col.split(SplIter()).map_blocks(block_sum).reduce(combine).compute(
        executor=engine("threaded"))
    print("threaded identical:", bool(jnp.array_equal(seq.value, thr.value)))

    # -- 6. lowering is inspectable too: the placed, keyed TaskGraph --------------
    ex = engine("local")
    graph = ex.lower(col.split(SplIter()).map_blocks(block_sum).reduce(combine).plan())
    print(graph.describe().splitlines()[0], f"... ({len(graph.tasks)} tasks)")

    # -- 7. MeshExecutor: location groups as ONE sharded dispatch -----------------
    # The 8 uniform partitions stack into a single shard_map call over the
    # device mesh; partials merge with a psum-style collective.  On a 1-device
    # host this still runs (mesh of 1); under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 each location gets a
    # device and bytes_moved bills the collective traffic.
    mesh = col.split(SplIter()).map_blocks(block_sum).reduce(combine).compute(
        executor=engine("mesh"))
    print(f"mesh: dispatches={mesh.report.dispatches} "
          f"bytes_moved={mesh.report.bytes_moved} "
          f"matches={bool(jnp.allclose(mesh.value, seq.value, rtol=2e-4))}")

    # -- 8. order restoration (paper §4.1) ---------------------------------------
    p0 = parts[0]
    print("get_indexes()      ->", p0.get_indexes()[:8])
    print("get_item_indexes() ->", p0.get_item_indexes()[:8], "...")

    # -- 9. adaptive granularity: no knob at all ----------------------------------
    # SplIter(partitions_per_location="auto") hands the last tuning knob to the
    # executor's cost-model autotuner: early iterations probe a deterministic
    # granularity ladder, a Tiny-Tasks cost model picks the winner (≤3 retunes),
    # and every retune is a LOGICAL regroup of the already-split blocks — the
    # prepare cache never re-splits and never moves a byte.
    ex = engine("local")
    auto_plan = col.split(SplIter(partitions_per_location="auto")) \
                   .map_blocks(block_sum).reduce(combine)
    for i in range(5):
        r = auto_plan.compute(executor=ex)
        print(f"iter {i}: ppl={r.report.granularity} retunes={r.report.retunes} "
              f"bytes_moved={r.report.bytes_moved}")
    print(f"prepare stats: {ex.prepare_stats}  (splits stays 1: regroup-without-resplit)")
    print("profile:", [(p.kind, p.calls, round(p.mean_dispatch_s * 1e3, 3))
                       for p in ex.profile.snapshot()[:3]], "(kind, calls, mean dispatch ms)")

    # -- 10. out of core: blocks behind a chunk store ------------------------------
    # The same dataset, but the blocks live in a DiskStore whose residency
    # budget is a QUARTER of the dataset: only ~budget bytes are ever resident;
    # evicted blocks spill to .npy files and the StreamExecutor prefetches
    # partition k+1 while partition k computes.  Same policy, same TaskGraph,
    # same merge order — the streamed result is bit-identical to the in-memory
    # one (bit-identity holds per policy; different granularities reassociate).
    fine = SplIter(partitions_per_location=8)        # fine partitions: bounded RSS
    ref = col.split(fine).map_blocks(block_sum).reduce(combine).compute(
        executor=engine("local"))
    store = DiskStore(residency_bytes=x.nbytes // 4)
    sx = x.to_store(store)                           # same blocking, chunk refs now
    sex = engine("stream")
    stream = (
        Collection.from_blocked(sx)
        .split(fine)
        .map_blocks(block_sum)
        .reduce(combine)
        .compute(executor=sex)
    )
    print(f"stream: dispatches={stream.report.dispatches} "
          f"loaded={stream.report.bytes_loaded}B spilled={stream.report.bytes_spilled}B "
          f"prefetch_hits={stream.report.prefetch_hits} "
          f"peak_resident={store.stats.peak_resident_bytes}B "
          f"(budget {store.residency_bytes}B) "
          f"bit_identical={bool(jnp.all(stream.value == ref.value))}")
    sex.close()                                      # spill files removed here

    # -- 11. a real cluster: worker processes, locality, fault tolerance ----------
    # The same plan again, but each location is owned by a spawn-based WORKER
    # PROCESS: task descriptors (code reference + operand payloads) cross a
    # real pickle/IPC boundary, partials come back over a reply queue, and the
    # report bills the transport (ipc_bytes, remote_dispatches).  Kill a
    # worker mid-run and its in-flight tasks replay on a survivor — task
    # descriptors are pure, so the result stays bit-identical (retries > 0
    # would say a replay happened; here, none is injected).
    cex = engine("cluster")
    clus = (
        Collection.from_blocked(x)
        .split(SplIter(partitions_per_location=2))
        .map_blocks(block_sum)
        .reduce(combine)
        .compute(executor=cex)
    )
    ref2 = col.split(SplIter(partitions_per_location=2)).map_blocks(
        block_sum).reduce(combine).compute(executor=engine("local"))
    print(f"cluster: dispatches={clus.report.dispatches} "
          f"remote={clus.report.remote_dispatches} "
          f"ipc={clus.report.ipc_bytes}B retries={clus.report.retries} "
          f"bit_identical={bool(jnp.all(clus.value == ref2.value))}")
    cex.close()                                      # worker pool joins here

    # -- 12. engine as a service: many tenants, one pool, durable jobs ------------
    # A JobServer turns the executor into a long-lived service.  JobClient
    # satisfies the Executor protocol, so the same plans run unchanged —
    # but now two tenants submit CONCURRENTLY and the server interleaves
    # their units on one shared pool under weighted-fair scheduling (bob's
    # weight=2 buys twice the unit slots).  Pass root= and the write-ahead
    # journal + snapshots let a killed server restart and resume mid-job,
    # recomputing only units that never finished.
    server = engine("server")
    alice = JobClient(server, tenant="alice")
    bob = JobClient(server, tenant="bob", weight=2)
    plan = col.split(SplIter()).map_blocks(block_sum).reduce(combine).plan()
    ja, jb = alice.submit(plan), bob.submit(plan)     # both in flight at once
    ra, rb = alice.wait(ja), bob.wait(jb)
    print(f"jobserver: tenants=2 events={len(server.event_log)} "
          f"alice_dispatches={ra.report.dispatches} "
          f"bit_identical={bool(jnp.all(ra.value == seq.value))}")
    for ev in jb.events[:3]:
        print("  bob:", ev)
    server.close()                                   # drains, then stops

    # -- 13. pipelined iteration: kill the per-execute barrier --------------------
    # An iterative loop migrates in two lines: ``.compute(...)`` becomes
    # ``.compute_async(...)``, and the loop-carried value becomes
    # ``fut.map(...)`` — a lazy Deferred the next iteration consumes as an
    # operand.  Consecutive executes now OVERLAP: iteration k+1's units
    # launch the moment their same-partition k predecessors (and k's merge
    # fold) finish, no global drain — while results stay bit-identical and
    # every future's report stays exact for its own execute.
    def weighted_sum(block, w):          # w is the loop-carried operand
        return (block * w).sum(axis=0)

    scale = lambda v: v / x.num_rows

    tex = engine("threaded")
    w = jnp.ones((5,))                                        # barriered loop
    for _ in range(3):
        res = (col.split(SplIter()).map_blocks(weighted_sum, extra_args=(w,))
               .reduce(combine).compute(executor=tex))
        w = scale(res.value)

    w_op, futs = jnp.ones((5,)), []                           # pipelined loop
    for _ in range(3):
        fut = (col.split(SplIter()).map_blocks(weighted_sum, extra_args=(w_op,))
               .reduce(combine).compute_async(executor=tex))  # changed line 1
        futs.append(fut)
        w_op = fut.map(scale)                                 # changed line 2
    reports = [f.result().report for f in futs]
    print(f"pipelined: bit_identical={bool(jnp.all(w_op.resolve() == w))} "
          f"overlapped_launches={[r.overlapped_launches for r in reports]}")
    tex.close()

    # -- 14. elasticity: work stealing rescues a straggler -------------------------
    # Same cluster plan, but worker 0 is artificially slowed 30ms per unit
    # (FaultPlan.slow — a deterministic straggler).  With steal=True an idle
    # sibling takes worker 0's queued units whenever the cost gate predicts
    # fetch < wait — per-worker service-time EMAs make the gate asymmetric,
    # so the straggler never steals the work back.  Steals move shm
    # descriptors, not bytes; attempts are refunded (retries stays 0); and
    # the result is still bit-identical.  grow()/shrink() scale the pool the
    # same way: shrink drains through the kill-replay path, as preemption.
    eex = engine("cluster", fault_plan=FaultPlan(slow=((0, 0.03),)), steal=True)
    elas = (
        Collection.from_blocked(x)
        .split(SplIter(partitions_per_location=2))
        .map_blocks(block_sum)
        .reduce(combine)
        .compute(executor=eex)
    )
    print(f"elastic: steals={elas.report.steals} "
          f"retries={elas.report.retries} "
          f"steal_log={[e['kind'] for e in eex.steal_log]} "
          f"bit_identical={bool(jnp.all(elas.value == ref2.value))}")
    eex.close()

    # -- 15. one construction path: engine() + peer-exchanged merge folds ----------
    # Every executor above came out of engine(backend, ...) — the blessed
    # construction path.  A frozen EngineConfig carries EVERY backend's
    # knobs (each backend reads only its own section), so one config can
    # drive an A/B pair across backends.  Here it also turns on the
    # cluster's peer exchange (p2p): member units publish their partials
    # into /dev/shm, a sibling fold unit reduces each location's chain
    # worker-side, and the driver receives ONE merged partial per
    # location — driver_merge_bytes collapses from N·S to L·S while the
    # member bytes reappear as p2p_bytes.  Bit-identical either way: the
    # fold tree (lowering's fold_plan) is the same association in the
    # same order on every route.
    cfg = EngineConfig(p2p=True)         # forced on; p2p="auto" cost-gates
    plan2 = (col.split(SplIter(partitions_per_location=2))
             .map_blocks(block_sum).reduce(combine))
    with engine("local", config=cfg) as lex:
        pin = plan2.compute(executor=lex)
    with engine("cluster", config=cfg) as pex:
        p2p = plan2.compute(executor=pex)
    print(f"p2p: driver_merge_bytes {pin.report.driver_merge_bytes}B -> "
          f"{p2p.report.driver_merge_bytes}B  p2p_bytes={p2p.report.p2p_bytes}B "
          f"bit_identical={bool(jnp.all(p2p.value == pin.value))}")


if __name__ == "__main__":
    main()
