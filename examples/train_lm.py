"""End-to-end LM training driver (deliverable b) — thin wrapper over
``repro.launch.train`` with the ~100M-parameter preset.

The global batch is a *blocked collection* of microbatches; the train step
is ONE dispatch that scans the local blocks with an in-scan gradient
accumulator (the SplIter at trainer level, DESIGN.md L2).  Checkpointing is
preemption-safe: Ctrl-C triggers a final checkpoint, re-running resumes
bit-identically.

Run (fast, ~20M params, a few hundred steps on CPU):

    PYTHONPATH=src python examples/train_lm.py

Run the full ~100M deliverable configuration:

    PYTHONPATH=src python examples/train_lm.py --preset lm100m --steps 300

Compare the paper's execution strategies on identical math:

    PYTHONPATH=src python examples/train_lm.py --accum-mode per_block
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    defaults = ["--preset", "lm20m", "--steps", "200", "--global-batch", "16",
                "--num-blocks", "4", "--seq-len", "128",
                "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "50"]
    # user-supplied flags win; defaults fill the rest
    user = sys.argv[1:]

    def has(flag: str) -> bool:
        return any(a == flag or a.startswith(flag + "=") for a in user)

    merged = list(user)
    i = 0
    while i < len(defaults):
        flag = defaults[i]
        if not has(flag) and not (flag == "--preset" and has("--arch")):
            merged += [defaults[i], defaults[i + 1]]
        i += 2
    sys.argv = [sys.argv[0]] + merged
    main()
