"""The paper's four applications (§5) end-to-end, in all execution modes.

Histogram (§5.1, memory-bound) · k-means (§5.2, iterative) ·
Cascade SVM (§5.3, compute-bound, order-sensitive) · k-NN (§5.4,
consolidated lookup structures).

All apps run through the typed repro.api policies (Baseline / SplIter /
Rechunk) — no mode strings.

Run:  PYTHONPATH=src python examples/paper_apps.py [--blocks-per-loc 8]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import Baseline, Rechunk, SplIter
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.apps.knn import knn
from repro.core.blocked import BlockedArray, round_robin_placement


def blocked(arr, block_rows, locs):
    return BlockedArray.from_array(
        jnp.asarray(arr), block_rows, num_locations=locs,
        policy=round_robin_placement,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--locations", type=int, default=4)
    ap.add_argument("--blocks-per-loc", type=int, default=8)
    args = ap.parse_args()
    locs, bpl = args.locations, args.blocks_per_loc
    rng = np.random.default_rng(0)

    # ---------------- Histogram ------------------------------------------
    print("== Histogram (memory-bound, single pass) ==")
    pts = rng.random((locs * bpl * 256, 3)).astype(np.float32)
    x = blocked(pts, 256, locs)
    ref = np.histogramdd(pts, bins=4, range=[(0, 1)] * 3)[0]
    for pol in (Baseline(), SplIter(), Rechunk()):
        h, rep = histogram(x, bins=4, policy=pol)
        ok = np.array_equal(np.asarray(h), ref)
        print(f"  {pol.mode_name:10s} dispatches={rep.dispatches:3d} "
              f"moved={rep.bytes_moved:9d}B correct={ok}")

    # ---------------- k-means --------------------------------------------
    print("== k-means (iterative, memory-bound) ==")
    centers_true = rng.random((4, 2))  # in the unit square (kmeans init range)
    pts = (centers_true[rng.integers(0, 4, locs * bpl * 128)]
           + 0.02 * rng.standard_normal((locs * bpl * 128, 2))).astype(np.float32)
    x = blocked(pts, 128, locs)
    for pol in (Baseline(), SplIter(), Rechunk()):
        res = kmeans(x, k=4, iters=5, seed=1, policy=pol)
        print(f"  {pol.mode_name:10s} dispatches={res.total_dispatches:3d} "
              f"moved={res.total_bytes_moved:9d}B "
              f"centers[0]={np.asarray(res.centers)[0].round(2).tolist()}")

    # ---------------- Cascade SVM ----------------------------------------
    print("== Cascade SVM (compute-bound, order-sensitive) ==")
    n = locs * bpl * 64
    pts = rng.standard_normal((n, 4)).astype(np.float32)
    w_true = np.array([1.5, -2.0, 0.7, 1.1], np.float32)
    labels = np.sign(pts @ w_true + 0.1 * rng.standard_normal(n)).astype(np.float32)
    x, y = blocked(pts, 64, locs), blocked(labels, 64, locs)
    for pol in (Baseline(), SplIter(), SplIter(materialize=True)):
        res = cascade_svm(x, y, num_sv=64, iterations=1, policy=pol)
        pred = jnp.sign(res.decision(jnp.asarray(pts)))
        acc = float(jnp.mean(pred == jnp.asarray(labels)))
        print(f"  {pol.mode_name:12s} dispatches={res.report.dispatches:3d} "
              f"#SV={res.sv_x.shape[0]:4d} train_acc={acc:.3f}")

    # ---------------- k-NN ------------------------------------------------
    print("== k-NN (consolidated lookup structures) ==")
    fit_pts = rng.random((locs * bpl * 128, 3)).astype(np.float32)
    qry_pts = rng.random((locs * 2 * 64, 3)).astype(np.float32)
    xf = blocked(fit_pts, 128, locs)
    xq = blocked(qry_pts, 64, locs)
    ref = np.argsort(((qry_pts[:, None] - fit_pts[None]) ** 2).sum(-1), 1)[:, :5]
    for pol in (Baseline(), SplIter()):
        res = knn(xf, xq, k=5, policy=pol)
        ok = np.array_equal(np.sort(np.asarray(res.indices), 1), np.sort(ref, 1))
        print(f"  {pol.mode_name:10s} dispatches={res.report.dispatches:3d} "
              f"merges={res.report.merges:4d} correct={ok}")


if __name__ == "__main__":
    main()
