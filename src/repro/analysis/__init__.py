"""Analysis: HLO collective parsing + three-term roofline (DESIGN.md §6)."""
