"""HLO text analysis: collective-op operand bytes, op census.

``cost_analysis()`` does not expose collective traffic, so we parse the
SPMD-partitioned module text: every ``all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute`` instruction's *operand*
bytes are summed (the spec's definition of collective_bytes).  The
partitioned module is per-device, so the sum is per-chip wire traffic.

Caveat handled upstream (roofline.py): instructions inside a ``while`` body
execute trip-count times but appear once in the text — the roofline uses
unrolled probe compiles, and this parser is also used to *verify* the probe
fit against trip-count-scaled scanned modules.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = f32[8,128]{1,0} op-name(...)` (also matches tuple-free defs)
_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*\(?([a-z0-9_]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(.+?)\s+(" + "|".join(COLLECTIVES) + r")(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind operand/result bytes of collectives in one HLO module."""

    operand_bytes: dict[str, int]
    result_bytes: dict[str, int]
    counts: dict[str, int]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    def as_dict(self) -> dict:
        return {
            "operand_bytes": dict(self.operand_bytes),
            "result_bytes": dict(self.result_bytes),
            "counts": dict(self.counts),
            "total_operand_bytes": self.total_operand_bytes,
            "total_result_bytes": self.total_result_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # first pass: instruction name -> byte size of its (first) result shape
    sizes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, dtype, dims = m.groups()
        if dtype in _DTYPE_BYTES:
            sizes[name] = _shape_bytes(dtype, dims)

    operand_bytes: dict[str, int] = defaultdict(int)
    result_bytes: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)

    for line in hlo_text.splitlines():
        line = line.strip()
        mm = None
        kind = None
        for c in COLLECTIVES:
            # match ` <kind>(` or `<kind>-start(` as the op of this line
            m2 = re.search(r"\s(" + c + r")(?:-start)?\(", line)
            if m2 and "=" in line.split(m2.group(0))[0]:
                mm, kind = m2, c
                break
        if not mm:
            continue
        counts[kind] += 1
        # result bytes: shape(s) on the LHS
        lhs = line.split("=", 1)[0]
        rhs_from_op = line[mm.end():]
        head = line.split("=", 1)[1]
        for ms in re.finditer(r"([a-z0-9_]+)\[([\d,]*)\]", head.split(mm.group(0))[0]):
            dt, dims = ms.groups()
            if dt in _DTYPE_BYTES:
                result_bytes[kind] += _shape_bytes(dt, dims)
        # operand bytes: resolve %refs inside the call parens
        depth = 0
        args = ""
        for ch in rhs_from_op:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            args += ch
        for ref in re.finditer(r"%?([\w.\-]+)", args):
            nm = ref.group(1)
            if nm in sizes:
                operand_bytes[kind] += sizes[nm]

    return CollectiveStats(
        operand_bytes=dict(operand_bytes),
        result_bytes=dict(result_bytes),
        counts=dict(counts),
    )
