"""Three-term roofline from the dry-run artifacts (DESIGN.md §6).

Reads the scanned-compile matrix (memory proof + collective schedule) and
the probe matrix (depth-extrapolated per-chip FLOPs / bytes / collective
bytes) and derives, per (arch × shape × mesh):

    compute_term    = HLO_FLOPs_per_chip  / PEAK_FLOPS
    memory_term     = HLO_bytes_per_chip  / HBM_BW
    collective_term = coll_bytes_per_chip / ICI_BW

plus the dominant bottleneck, MODEL_FLOPS = 6·N·D (train) or 2·N·D
(fwd-only), the MODEL/HLO FLOP ratio (remat + dispatch + attention
overhead), and a roofline fraction:

* compute-dominant cells: ``model_flops_time / dominant`` (MFU-style);
* memory-dominant cells:  ``min_bytes_time / dominant`` (BWU-style), where
  min bytes = one bf16 read of active params + decode cache per chip.

CLI::

    PYTHONPATH=src python -m repro.analysis.roofline \
        --dryrun results/dryrun/single_pod.json \
        --probe  results/dryrun/probe_single_pod.json \
        --out results/roofline_single_pod.json --md results/roofline_single_pod.md
"""

from __future__ import annotations

import argparse
import json
from typing import Any

from repro.configs import SHAPES, get_config

# TPU v5e-class hardware constants (per chip) — the assignment's targets.
PEAK_FLOPS = 197e12   # bf16
HBM_BW = 819e9        # B/s
ICI_BW = 50e9         # B/s per link


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D (train) / 2·N_active·D (fwd), D = processed tokens.

    N excludes the input-side embedding table (a gather, not a matmul);
    the LM head matmul keeps its table counted.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_counts()["active"]
    if not cfg.tie_embeddings:
        n -= cfg.padded_vocab * cfg.d_model  # input embedding gather
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def _cache_bytes(cfg, shape) -> float:
    """Exact decode-cache footprint (the minimum bytes a decode step reads)."""
    b = shape.global_batch
    s = shape.seq_len
    total = 0.0
    for seg in cfg.segments():
        for spec in seg.period:
            if spec.mixer in ("attn", "enc_attn"):
                sl = min(s, cfg.sliding_window) if cfg.sliding_window else s
                total += seg.repeats * 2 * b * sl * cfg.num_kv_heads * cfg.resolved_head_dim * 2.0
            elif spec.mixer == "cross_attn":
                m = cfg.encoder_seq if cfg.family == "audio" else cfg.image_tokens
                total += seg.repeats * 2 * b * m * cfg.num_kv_heads * cfg.resolved_head_dim * 2.0
            elif spec.mixer == "mla":
                total += seg.repeats * b * s * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2.0
            elif spec.mixer == "mamba2":
                din = cfg.ssm_expand * cfg.d_model
                nh = din // cfg.ssm_head_dim
                total += seg.repeats * b * (
                    nh * cfg.ssm_head_dim * cfg.ssm_state * 4.0  # fp32 state
                    + (cfg.ssm_conv_width - 1) * (din + 2 * cfg.ssm_state) * 2.0
                )
    return total


def analyze(dryrun: list[dict], probe: list[dict]) -> list[dict[str, Any]]:
    probes = {(r["arch"], r["shape"], r["mesh"]): r for r in probe}
    rows: list[dict[str, Any]] = []
    for rec in dryrun:
        key = (rec["arch"], rec["shape"], rec["mesh"])
        row: dict[str, Any] = {
            "arch": rec["arch"],
            "shape": rec["shape"],
            "mesh": rec["mesh"],
            "status": rec["status"],
        }
        if rec["status"] == "SKIP":
            row["reason"] = rec.get("reason", "")
            rows.append(row)
            continue
        p = probes.get(key)
        if rec["status"] != "OK" or p is None or p.get("status") != "OK":
            row["status"] = "NO-PROBE" if rec["status"] == "OK" else rec["status"]
            rows.append(row)
            continue
        dev = rec["devices"]
        ex = p["extrapolated"]
        compute_t = ex["flops"] / PEAK_FLOPS
        memory_t = ex["bytes_accessed"] / HBM_BW
        coll_t = ex["collective_bytes"] / ICI_BW
        terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
        dominant = max(terms, key=terms.get)
        mf = model_flops(rec["arch"], rec["shape"])
        mf_pc = mf / dev
        ratio = mf_pc / ex["flops"] if ex["flops"] else 0.0
        # roofline fraction: MFU-style for compute-shaped steps; BWU-style
        # (achievable-bytes / modeled-bytes) for decode, whose useful FLOPs
        # are negligible by construction.
        if SHAPES[rec["shape"]].kind == "decode" and dominant == "memory":
            mb = _min_bytes_model(rec["arch"], rec["shape"], dev)
            frac = (mb / HBM_BW) / terms[dominant]
        else:
            frac = (mf_pc / PEAK_FLOPS) / terms[dominant]
        row.update(
            devices=dev,
            compute_s=compute_t,
            memory_s=memory_t,
            collective_s=coll_t,
            dominant=dominant,
            model_flops=mf,
            model_over_hlo=ratio,
            roofline_fraction=frac,
            peak_gb_per_dev=rec["memory"]["peak_live_bytes"] / 1e9,
            note=_note(dominant, ratio, rec["shape"]),
        )
        rows.append(row)
    return rows


def _min_bytes_model(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    total = cfg.param_counts()["active"] * 2.0
    if shape.kind == "decode":
        total += _cache_bytes(cfg, shape)
    return total / devices


def _note(dominant: str, ratio: float, shape: str) -> str:
    if dominant == "compute":
        if ratio < 0.55:
            return ("compute waste (remat/dispatch): relax the remat policy or "
                    "shrink MoE one-hot dispatch groups")
        return "near compute roofline; next win is overlapping the DP reduction"
    if dominant == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("cache-read bound: keep donation aliasing, avoid f32 upcast "
                    "of KV, consider int8 KV")
        return ("activation traffic: sequence-parallel residual stream + smaller "
                "microbatch blocks (SplIter re-split)")
    return ("collective bound: hierarchical pod-aware reduction, int8 gradient "
            "compression, overlap with backward")


# ---------------------------------------------------------------------------


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | dev | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO | roofline | peak GB/dev | note |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        if r["status"] == "SKIP":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | — | "
                f"SKIP: {r.get('reason', '')[:60]}… |"
            )
            continue
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | — | {r['status']} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['devices']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['dominant']}** | {r['model_over_hlo']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['peak_gb_per_dev']:.1f} "
            f"| {r['note']} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dryrun", required=True)
    ap.add_argument("--probe", required=True)
    ap.add_argument("--out", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    with open(args.dryrun) as f:
        dryrun = json.load(f)
    with open(args.probe) as f:
        probe = json.load(f)
    rows = analyze(dryrun, probe)
    md = to_markdown(rows)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
