"""mixtral-8x7b [moe] — 8 experts top-2, SWA window 4096. [arXiv:2401.04088; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    source="[arXiv:2401.04088; hf]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,          # per-expert hidden
    vocab_size=32000,
    sliding_window=4096,
    moe_experts=8,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_virtual_split=2,  # 8 experts -> 16 virtual half-width experts (exact
                          # F-split) so the expert dim shards over 16-way TP
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        sliding_window=32,
        moe_experts=4,
        moe_top_k=2,
        moe_d_ff=96,
        moe_virtual_split=1,
        moe_capacity_factor=2.0,  # = E/k: no drops -> exact at smoke scale
        vocab_pad_multiple=32,
    )
