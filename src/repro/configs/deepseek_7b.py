"""deepseek-7b [dense] — llama-arch, GQA kv=32 (i.e. MHA). [arXiv:2401.02954; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    source="[arXiv:2401.02954; hf]",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_multiple=32,
    )
