"""qwen3-32b [dense] — GQA kv=8, qk_norm (per-head RMSNorm on q,k).
[hf:Qwen/Qwen3-8B; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    source="[hf:Qwen/Qwen3-8B; hf]",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,  # explicit (not d_model//heads), per Qwen3 HF config
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_multiple=32,
    )
