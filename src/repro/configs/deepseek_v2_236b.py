"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6,
first layer dense. [arXiv:2405.04434; hf]

Spec gives the per-expert hidden (d_ff=1536); the leading dense layer uses
the model's dense intermediate size (12288 per the HF config) — noted in
DESIGN.md as a config-completion beyond the assigned line.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="[arXiv:2405.04434; hf]",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: kv heads == q heads after decompression
    head_dim=128,
    d_ff=1536,          # per-expert hidden
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    moe_experts=160,
    moe_top_k=6,
    moe_shared_experts=2,
    moe_d_ff=1536,
    moe_first_dense=1,
    dense_d_ff=12288,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        kv_lora_rank=32,
        q_lora_rank=48,
        rope_head_dim=8,
        moe_experts=8,
        moe_top_k=2,
        moe_capacity_factor=4.0,  # = E/k: no drops -> exact at smoke scale
        moe_shared_experts=1,
        moe_d_ff=64,
        moe_first_dense=1,
        dense_d_ff=128,
        vocab_pad_multiple=32,
    )
