"""mamba2-1.3b [ssm] — SSD (state-space duality), attn-free.
[arXiv:2405.21060; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="[arXiv:2405.21060; unverified]",
    num_layers=48,
    d_model=2048,
    num_heads=0,          # attn-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,               # no MLP; Mamba block carries the capacity
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
        vocab_pad_multiple=32,
    )
