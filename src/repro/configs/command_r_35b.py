"""command-r-35b [dense] — GQA kv=8, no bias, parallel attn+FFN block,
LayerNorm. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    parallel_block=True,
    norm="layernorm",
    rope_theta=8e6,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        vocab_pad_multiple=32,
    )
