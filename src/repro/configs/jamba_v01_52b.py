"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave (period 8, attn at
index 4), MoE 16e top-2 on every other sublayer. [arXiv:2403.19887; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="[arXiv:2403.19887; hf]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_period=2,         # MoE on odd sublayers within the period
    attn_period=8,        # 1 attention layer per 8 (1:7)
    attn_index=4,
    ssm_state=16,         # jamba uses Mamba-1-style state 16
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=8,     # one full period
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        moe_experts=4,
        moe_top_k=2,
        moe_d_ff=96,
        moe_capacity_factor=2.0,  # = E/k: no drops -> exact at smoke scale
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=16,
        vocab_pad_multiple=32,
    )
