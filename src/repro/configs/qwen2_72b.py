"""qwen2-72b [dense] — GQA kv=8, QKV bias. [arXiv:2407.10671; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    source="[arXiv:2407.10671; hf]",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_multiple=32,
    )
