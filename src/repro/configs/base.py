"""Model/run configuration system.

A :class:`ModelConfig` fully describes an architecture as a sequence of
*segments*; each segment is a repeated *period* of :class:`LayerSpec`s.
Homogeneous stacks (most LMs) are one segment with a 1-layer period scanned
``num_layers`` times; heterogeneous stacks (jamba's 1:7 attn:mamba periods,
deepseek-v2's first dense layer, llama-vision's cross-attn interleave) use
multi-layer periods and/or multiple segments.  The scanned-period design
keeps full-size HLO small (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mla", "mamba2", "cross_attn", "enc_attn"]
Mlp = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One sublayer: a sequence mixer followed by an MLP (either optional)."""

    mixer: Mixer = "attn"
    mlp: Mlp = "dense"


@dataclasses.dataclass(frozen=True)
class Segment:
    """``repeats`` × ``period`` layers, scanned over ``repeats``."""

    period: tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.period) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity ------------------------------------------------------------
    name: str
    family: str  # dense | moe | audio | hybrid | ssm | vlm
    source: str  # provenance note "[arXiv:...; tier]"

    # -- trunk ---------------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // num_heads

    # -- attention flags -------------------------------------------------------
    qkv_bias: bool = False       # qwen2
    qk_norm: bool = False        # qwen3
    parallel_block: bool = False # command-r: attn and FFN in parallel
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    rope_theta: float = 1e6
    sliding_window: int = 0      # mixtral SWA; 0 = full attention

    # -- MoE -------------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert hidden
    moe_period: int = 1          # MoE every k-th layer (jamba: 2)
    moe_first_dense: int = 0     # deepseek-v2: first k layers use dense MLP
    dense_d_ff: int = 0          # hidden of those dense layers (0 -> d_ff)
    moe_impl: str = "onehot"     # "onehot" (GSPMD-partitionable, capacity) |
                                 # "ragged" (sort-based dropless; 1-device ref)
    moe_capacity_factor: float = 1.25  # onehot: per-expert buffer slack
    moe_group: int = 1024        # onehot: tokens per dispatch group
    moe_virtual_split: int = 1   # split each expert into n half-width virtual
                                 # experts (exact) so E·n divides the TP axis
                                 # (mixtral: 8 experts × 2 = 16)

    # -- MLA (deepseek-v2) -------------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0       # decoupled RoPE dims (shared across heads)

    # -- SSM (mamba2 / jamba) -----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256         # SSD chunk length
    attn_period: int = 0         # hybrid: 1 attn layer every k layers (jamba: 8)
    attn_index: int = 4          # position of the attn layer inside the period

    # -- encoder-decoder (whisper) -------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0         # stubbed frame-embedding count (whisper: 1500)

    # -- VLM (llama-3.2-vision) ------------------------------------------------------
    cross_attn_period: int = 0   # 1 cross-attn layer every k layers (5)
    image_tokens: int = 0        # stubbed patch-embedding count
    image_embed_dim: int = 0

    # -- training / numerics ----------------------------------------------------------
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256  # Megatron-style padded vocab for TP
    remat: str = "full"         # "none" | "dots" | "full" — per-layer checkpoint policy
    attn_impl: str = "ref"       # "ref" (XLA einsum) | "flash" (Pallas kernel)
    unroll_layers: bool = False  # roofline probes: unroll instead of scan
                                 # (cost_analysis counts scan bodies once)

    # -- derived ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def is_seq_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state or bounded-window attn."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def segments(self) -> tuple[Segment, ...]:
        """Decoder-trunk segment list (encoder handled separately)."""
        segs = self._segments_impl()
        if self.unroll_layers:  # flatten: one period of all layers, no scan
            segs = tuple(Segment(s.period * s.repeats, 1) for s in segs)
        return segs

    def _segments_impl(self) -> tuple[Segment, ...]:
        if self.family == "audio":
            # whisper decoder block: self-attn, cross-attn to encoder, MLP
            period = (LayerSpec("attn", "none"), LayerSpec("cross_attn", "dense"))
            return (Segment(period, self.num_layers),)

        if self.family == "ssm":
            spec = LayerSpec(mixer="mamba2", mlp="none")
            return (Segment((spec,), self.num_layers),)

        if self.family == "hybrid":  # jamba: period of attn_period sublayers
            period = []
            for i in range(self.attn_period):
                mixer = "attn" if i == self.attn_index else "mamba2"
                mlp = "moe" if (self.moe_experts and i % self.moe_period == 1) else "dense"
                period.append(LayerSpec(mixer=mixer, mlp=mlp))
            reps = self.num_layers // self.attn_period
            return (Segment(tuple(period), reps),)

        if self.family == "vlm":  # 4 self-attn + 1 cross-attn per period
            p = self.cross_attn_period
            period = [LayerSpec("attn", "dense")] * (p - 1) + [
                LayerSpec("cross_attn", "dense")
            ]
            return (Segment(tuple(period), self.num_layers // p),)

        mlp: Mlp = "moe" if self.moe_experts else "dense"
        if self.moe_first_dense:  # deepseek-v2: leading dense layers
            mixer: Mixer = "mla" if self.mla else "attn"
            return (
                Segment((LayerSpec(mixer, "dense"),), self.moe_first_dense),
                Segment(
                    (LayerSpec(mixer, "moe"),),
                    self.num_layers - self.moe_first_dense,
                ),
            )
        mixer = "mla" if self.mla else "attn"
        return (Segment((LayerSpec(mixer, mlp),), self.num_layers),)

    def encoder_segments(self) -> tuple[Segment, ...]:
        if not self.encoder_layers:
            return ()
        seg = Segment((LayerSpec("enc_attn", "dense"),), self.encoder_layers)
        if self.unroll_layers:
            seg = Segment(seg.period * seg.repeats, 1)
        return (seg,)

    # -- parameter counting (for roofline MODEL_FLOPS) ------------------------------------
    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and *active* (MoE top-k only)."""
        d, dh = self.d_model, self.resolved_head_dim
        h, hkv = self.num_heads, self.num_kv_heads

        def attn_params() -> float:
            if self.mla:
                q = (
                    d * self.q_lora_rank
                    + self.q_lora_rank * h * (dh + self.rope_head_dim)
                    if self.q_lora_rank
                    else d * h * (dh + self.rope_head_dim)
                )
                kv = d * (self.kv_lora_rank + self.rope_head_dim)
                up = self.kv_lora_rank * h * (dh + dh)  # k_nope + v
                o = h * dh * d
                return q + kv + up + o
            qkv = d * (h + 2 * hkv) * dh
            if self.qkv_bias:
                qkv += (h + 2 * hkv) * dh
            return qkv + h * dh * d

        def dense_mlp(ff: int) -> float:
            return 3 * d * ff  # gate/up/down

        def moe_mlp() -> tuple[float, float]:
            total = self.moe_experts * 3 * d * self.moe_d_ff + d * self.moe_experts
            total += self.moe_shared_experts * 3 * d * self.moe_d_ff
            active = (self.moe_top_k + self.moe_shared_experts) * 3 * d * self.moe_d_ff
            active += d * self.moe_experts
            return total, active

        def mamba_params() -> float:
            din = self.ssm_expand * d
            nh = din // self.ssm_head_dim
            in_proj = d * (2 * din + 2 * self.ssm_state + nh)  # z,x,B,C,dt
            conv = self.ssm_conv_width * (din + 2 * self.ssm_state)
            return in_proj + conv + 3 * nh + din + din * d  # A,D,dt_bias,norm,out

        total = active = 0.0
        for seg in self.segments():
            for spec in seg.period:
                t = a = 0.0
                if spec.mixer in ("attn", "cross_attn", "enc_attn"):
                    t = a = attn_params()
                elif spec.mixer == "mla":
                    t = a = attn_params()
                elif spec.mixer == "mamba2":
                    t = a = mamba_params()
                if spec.mlp == "dense":
                    ff = self.dense_d_ff or self.d_ff
                    t += dense_mlp(ff)
                    a += dense_mlp(ff)
                elif spec.mlp == "moe":
                    mt, ma = moe_mlp()
                    t += mt
                    a += ma
                total += t * seg.repeats
                active += a * seg.repeats
        for seg in self.encoder_segments():
            n = seg.num_layers
            total += n * (attn_params() + dense_mlp(self.d_ff))
            active += n * (attn_params() + dense_mlp(self.d_ff))
        emb = self.padded_vocab * d
        total += emb * (1 if self.tie_embeddings else 2)
        active += emb * (1 if self.tie_embeddings else 2)
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}
