"""whisper-tiny [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed (B, 1500, 384) frame embeddings). [arXiv:2212.04356; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="[arXiv:2212.04356; unverified]",
    num_layers=4,            # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    encoder_layers=4,
    encoder_seq=1500,
    norm="layernorm",
    rope_theta=1e4,          # whisper uses learned/sinusoidal; RoPE stands in
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_layers=2,
        encoder_seq=24,
        vocab_pad_multiple=32,
    )
