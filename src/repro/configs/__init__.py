"""Architecture registry: ``--arch <id>`` → ModelConfig.

``get_config(id)`` returns the full assigned config; ``get_smoke_config(id)``
the reduced same-family config used by CPU smoke tests.  IDs use dashes
(CLI-style); module names use underscores.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, LayerSpec, ModelConfig, Segment, ShapeCell

_MODULES: dict[str, str] = {
    "qwen2-72b": "repro.configs.qwen2_72b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "llama-3.2-vision-11b": "repro.configs.llama_32_vision_11b",
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).smoke()


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "LayerSpec",
    "ModelConfig",
    "Segment",
    "ShapeCell",
    "get_config",
    "get_smoke_config",
]
