"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer;
patch-embedding frontend STUB (input_specs provides (B, 1600, 4096) image
embeddings). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_period=5,   # 8 cross-attn layers in 40
    image_tokens=1600,
    image_embed_dim=4096,
    rope_theta=5e5,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=5,      # one full period
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        image_tokens=12,
        image_embed_dim=48,
        vocab_pad_multiple=32,
    )
