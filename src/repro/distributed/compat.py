"""jax version compatibility shims for the distribution substrate.

``jax.shard_map`` (with ``check_vma``) only exists on newer jax; older
releases ship it as ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` spelling.  Every shard_map in this repo goes through
:func:`shard_map` so the call sites stay on the modern API.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "axis_size"]


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` with a fallback for older jax.

    ``psum(1, axis)`` is the historical idiom: it is special-cased to fold
    to a concrete integer, which is exactly what the newer helper returns.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)

try:  # jax >= 0.6: public API
    from jax import shard_map as _new_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _new_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )

except ImportError:  # older jax: experimental module, check_rep spelling
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )
