"""Pod-aware collectives: hierarchical reductions and compressed cross-pod
hops, expressed with ``shard_map`` so the schedule is explicit.

On a 2×16×16 mesh the ``pod`` axis is the slow (DCN) dimension.  A flat
all-reduce over (pod, data) pays the slow link for the full gradient;
the hierarchical schedule reduce-scatters within the pod rows first, sends
only 1/16th of the bytes across pods, then all-gathers back — the classic
two-level schedule, here as a reusable primitive the trainer and the §Perf
iterations build on.

``compressed_psum_pod`` additionally int8-quantizes the shard before the
cross-pod hop (4× fewer DCN bytes); error feedback lives in the optimizer
(``repro.optim.compression``) because it is stateful.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import axis_size, shard_map
from repro.optim.compression import int8_compress

__all__ = [
    "hierarchical_psum",
    "psum_pod_hierarchical",
    "compressed_psum_pod",
]


def hierarchical_psum(x: jax.Array, *, fast_axis: str, slow_axis: str) -> jax.Array:
    """Two-level all-reduce for use INSIDE shard_map: RS(fast) → AR(slow) →
    AG(fast).  Equivalent to ``psum(x, (fast, slow))`` with 2/W of the flat
    schedule's slow-link bytes (W = fast-axis size)."""
    w = axis_size(fast_axis)
    n = x.shape[0]
    if n % w:  # ragged leading dim: fall back to the flat schedule
        return jax.lax.psum(x, (fast_axis, slow_axis))
    # reduce-scatter along the leading dim within the fast axis
    shard = jax.lax.psum_scatter(
        x.reshape(w, n // w, *x.shape[1:]), fast_axis, scatter_dimension=0, tiled=False
    )
    # slow-link hop carries only the 1/w shard
    shard = jax.lax.psum(shard, slow_axis)
    # all-gather back within the fast axis
    return jax.lax.all_gather(shard, fast_axis, axis=0, tiled=False).reshape(x.shape)


def psum_pod_hierarchical(tree: Any, mesh: Mesh) -> Any:
    """jit-level helper: hierarchically all-reduce a pytree over (pod, data).

    Leaves enter replicated over (pod, data) per-shard values (e.g. local
    gradient contributions) and exit fully reduced.
    """
    axes = mesh.axis_names
    assert "pod" in axes and "data" in axes, axes
    others = tuple(a for a in axes if a not in ("pod", "data"))

    def inner(t):
        return jax.tree.map(
            lambda x: hierarchical_psum(x, fast_axis="data", slow_axis="pod"), t
        )

    specs = jax.tree.map(lambda _: P(), tree)
    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(specs,),
        out_specs=specs,
        check_vma=False,
    )(tree)


def compressed_psum_pod(x: jax.Array, *, fast_axis: str, slow_axis: str) -> jax.Array:
    """Hierarchical psum whose cross-pod hop is int8-quantized.

    For use INSIDE shard_map.  The within-pod reduction stays exact; the
    slow link carries each pod's shard as (int8 values, fp32 per-row
    scales) — ~4× fewer DCN bytes than bf16/fp32 — and the sum of the
    dequantized shards is exact *given the quantization* (each pod keeps
    its own scale; pair with error feedback in the optimizer for the
    quantization residual).
    """
    w = axis_size(fast_axis)
    n = x.shape[0]
    if n % w:
        return jax.lax.psum(x, (fast_axis, slow_axis))
    shard = jax.lax.psum_scatter(
        x.reshape(w, n // w, *x.shape[1:]), fast_axis, scatter_dimension=0, tiled=False
    )
    flat = shard.reshape(max(shard.shape[0], 1), -1)
    q, s = int8_compress(flat)
    # slow-link hop: gather every pod's (q, s); int8 dominates the volume
    qg = jax.lax.all_gather(q, slow_axis, axis=0, tiled=False)   # (P, r, c) int8
    sg = jax.lax.all_gather(s, slow_axis, axis=0, tiled=False)   # (P, r, 1) fp32
    deq = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)           # exact Σ pods
    shard = deq.reshape(shard.shape).astype(shard.dtype)
    return jax.lax.all_gather(shard, fast_axis, axis=0, tiled=False).reshape(x.shape)
