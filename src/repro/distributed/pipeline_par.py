"""Pipeline parallelism: a GPipe-style stage executor over a ``pipe`` mesh
axis, built on ``shard_map`` + ``ppermute``.

For ≥1k-chip scale-out the (data, model) mesh gains a third factor: layers
split into S stages, each stage owned by one pipe rank.  Microbatches
stream through; stage s computes microbatch m at tick t = s + m, and
activations hop s→s+1 via ``collective_permute``.  Fill/drain bubbles cost
(S−1)/(T+S−1) of the ticks — amortized by the SplIter-shaped microbatch
blocking (many small blocks per step), the same granularity lever as L2.

This module is the *executor primitive*: stage-stacked params in, outputs
at the last stage.  It is exercised by a subprocess test on an 8-device
host mesh and composes with the dry-run mesh by factoring ``pipe`` out of
``model`` (see tests/test_distributed.py).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map

__all__ = ["gpipe"]


def gpipe(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # pytree; leaves (S, ...) — one slice per stage
    x_micro: jax.Array,           # (T, mb, ...) microbatch blocks
    *,
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run T microbatches through S pipeline stages; returns (T, mb, ...).

    ``stage_fn(params_s, x) -> y`` must be shape-preserving (a trunk
    segment).  Stage s's params live on pipe rank s (leading dim sharded
    over ``axis``); microbatches stream via ppermute with a fill/drain
    schedule of T + S − 1 ticks.
    """
    s_count = mesh.shape[axis]
    t_count = x_micro.shape[0]

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(p_specs, P(None)),  # every rank sees the full block stream
        out_specs=P(None),
        check_vma=False,
    )
    def run(params, xs):
        my = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda p: p[0], params)  # (1, ...) → (...)
        mb_shape = xs.shape[1:]
        n_ticks = t_count + s_count - 1
        fwd_perm = [(i, i + 1) for i in range(s_count - 1)]

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (when in range); others use the
            # activation that arrived from the previous stage
            inject = jnp.where(t < t_count, t, 0)
            x_in = jnp.where(my == 0, xs[inject], state)
            y = stage_fn(params, x_in)
            # last stage records its result at tick t - (S-1) → microbatch id
            out_idx = t - (s_count - 1)
            write = jnp.logical_and(my == s_count - 1, out_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # hop s → s+1 for the next tick
            state = jax.lax.ppermute(y, axis, fwd_perm)
            return (state, outs), None

        state0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((t_count,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(
            tick, (state0, outs0), jnp.arange(t_count + s_count - 1)
        )
        # every rank returns outs; only the last stage wrote into its copy
        # (the rest are zeros), so a psum broadcasts it — making
        # out_specs=P(None) truthful
        return jax.lax.psum(outs, axis)

    return run(stage_params, x_micro)
