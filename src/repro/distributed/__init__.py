"""Distribution substrate: logical-axis sharding rules, collectives, PP."""
