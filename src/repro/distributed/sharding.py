"""Logical-axis sharding: rules map logical dims → mesh axes.

Models annotate activations with *logical* axis names (``shard(x, "batch",
"seq", "embed")``); a :class:`ShardingRules` context maps those to mesh axes
and inserts ``with_sharding_constraint``.  Outside a rules context the calls
are identity — so smoke tests and single-device benches run unannotated
(1 device, per the dry-run spec), while the launcher activates the
production rules.

Parameter placement is name-based: :func:`param_pspec` pattern-matches the
parameter path (e.g. ``.../wq`` → heads over "model").  Leading stacked-layer
dims (from scanned segments) are never sharded.

Rule presets (DESIGN.md §5):

* ``train_rules``   — DP over (pod, data); TP heads/ffn/experts/vocab over model.
* ``train_rules_sp``— + sequence-parallel residual stream (seq over model
                      between blocks; cuts the activation memory term).
* ``decode_rules``  — batch over (pod, data); heads/vocab over model.
* ``long_decode_rules`` — batch unshardable (B=1): KV/state sequence over
                      data (context parallelism), heads over model.
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "use_rules",
    "shard",
    "param_pspec",
    "params_shardings",
    "cache_shardings",
    "train_rules",
    "train_rules_sp",
    "decode_rules",
    "long_decode_rules",
]

_ACTIVE: list["ShardingRules"] = []


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    logical: dict[str, Any]  # logical axis name -> mesh axis (str/tuple/None)
    cache_impl: str = "masked"  # decode cache write: "masked" | "sharded_dus"

    def spec(self, *names: str | None) -> P:
        return P(*(self.logical.get(n) if n else None for n in names))


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    if rules is None:
        yield
        return
    _ACTIVE.append(rules)
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_rules() -> ShardingRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Constrain ``x`` to the active rules' mapping of logical ``names``.

    Axes whose mesh extent does not divide the dim are dropped (replicated)
    — e.g. whisper's 6 heads on a 16-way model axis.
    """
    r = active_rules()
    if r is None:
        return x
    assert x.ndim == len(names), (x.shape, names)
    spec = []
    for dim, name in zip(x.shape, names):
        ax = r.logical.get(name) if name else None
        spec.append(ax if ax and dim % _axis_size(r.mesh, ax) == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec))
    )


# ---------------------------------------------------------------------------
# Rule presets.  `dp` = the data-parallel submesh (("pod","data") or ("data",)).
# ---------------------------------------------------------------------------

def _dp(mesh: Mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def train_rules(mesh: Mesh) -> ShardingRules:
    dp = _dp(mesh)
    return ShardingRules(
        mesh,
        {
            "batch": dp,
            "seq": None,
            "seq_res": None,   # residual stream between blocks (SP shards it)
            "embed": None,
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "expert": "model",
            "vocab": "model",
            "kv_seq": None,
            "state": None,
        },
    )


def train_rules_sp(mesh: Mesh) -> ShardingRules:
    """Sequence-parallel residual stream: seq sharded over model between
    blocks (beyond-paper §Perf optimization — cuts activation bytes)."""
    r = train_rules(mesh)
    logical = dict(r.logical)
    logical["seq_res"] = "model"  # Megatron-style sequence parallelism
    return ShardingRules(mesh, logical)


def decode_rules(mesh: Mesh) -> ShardingRules:
    """Decode: context parallelism.  The KV cache sequence shards over
    `model` (GQA kv-heads rarely divide a 16-way TP axis), so attention
    heads must stay UNSHARDED — q replicates over model, each model rank
    attends to its S/16 keys and the softmax reduces across them.  MLP/vocab
    stay tensor-parallel."""
    dp = _dp(mesh)
    return ShardingRules(
        mesh,
        {
            "batch": dp,
            "seq": None,
            "seq_res": None,   # residual stream between blocks (SP shards it)
            "embed": None,
            "heads": None,
            "kv_heads": None,
            "head_dim": None,
            "mlp": "model",
            "expert": "model",
            "vocab": "model",
            "kv_seq": "model",
            "state": None,
        },
    )


def decode_rules_headsharded(mesh: Mesh) -> ShardingRules:
    """Decode for archs whose kv-head count divides the model axis
    (deepseek-7b: 32 kv heads on 16-way TP): shard heads, keep the cache
    sequence dim UNSHARDED so the per-token cache update is a true
    dynamic-update-slice (offset on an unsharded dim → GSPMD partitions it
    in place; no full-cache rewrite).  §Perf cell-B optimization."""
    dp = _dp(mesh)
    return ShardingRules(
        mesh,
        {
            "batch": dp,
            "seq": None,
            "seq_res": None,
            "embed": None,
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "expert": "model",
            "vocab": "model",
            "kv_seq": None,
            "state": None,
        },
        cache_impl="heads_dus",
    )


def long_decode_rules(mesh: Mesh) -> ShardingRules:
    """B=1 long-context decode: context parallelism — the KV/conv/SSM state
    sequence dim shards over data; batch replicates."""
    return ShardingRules(
        mesh,
        {
            "batch": None,
            "seq": None,
            "seq_res": None,   # residual stream between blocks (SP shards it)
            "embed": None,
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "expert": "model",
            "vocab": "model",
            "kv_seq": "data",
            "state": "data",
        },
    )


# ---------------------------------------------------------------------------
# Parameter placement (name-based rules, MaxText-style).
# ---------------------------------------------------------------------------

# (regex on the joined param path, per-dim sharding) — "model" is tensor
# parallelism, "fsdp" is the ZeRO-3 dimension (resolved to the data axis):
# weights too large to replicate per DP rank are sharded over data and
# GSPMD inserts the FSDP all-gather (fwd) / reduce-scatter (bwd) pattern.
# Leaves inside scanned segments carry a leading layer-stack dim.
_PARAM_RULES: list[tuple[str, tuple[Any, ...]]] = [
    # attention projections: (D, H, Dh) -> heads over model, D over fsdp
    (r"/(wq|wk|wv|wk_mem|wv_mem)$", ("fsdp", "model", None)),
    (r"/(wq_b|wk_b|wv_b)$", ("fsdp", "model", None)),
    (r"/(bq|bk|bv)$", ("model", None)),
    # output projection: (H, Dh, D) -> heads over model, D over fsdp
    (r"/wo$", ("model", None, "fsdp")),
    # MLA low-rank downs
    (r"/(wq_a|wkv_a)$", ("fsdp", None)),
    # dense mlp: (D, F) / (F, D)
    (r"/(w_gate|w_up)$", ("fsdp", "model")),
    (r"/w_down$", ("model", "fsdp")),
    # moe experts: (E, D, F) / (E, F, D) -> expert-parallel over model
    (r"/(experts_gate|experts_up)$", ("model", "fsdp", None)),
    (r"/experts_down$", ("model", None, "fsdp")),
    (r"/router$", (None, None)),
    # mamba: shard the inner (head) dim over model, D over fsdp
    (r"/(w_in_z|w_in_x)$", ("fsdp", "model")),
    (r"/(w_in_b|w_in_c)$", ("fsdp", None)),
    (r"/w_in_dt$", ("fsdp", "model")),
    (r"/w_out$", ("model", "fsdp")),
    (r"/(conv_x)$", (None, "model")),
    (r"/(conv_b|conv_c)$", (None, None)),
    (r"/(A_log|ssm_D|dt_bias)$", ("model",)),
    (r"/ssm_norm$", ("model",)),
    # embeddings / head: vocab over model, embed over fsdp
    (r"/embed$", ("model", "fsdp")),
    (r"/lm_head$", ("fsdp", "model")),
    # norms, gates, scalars: replicated
    (r"/(ln1|ln2|ln1_b|ln2_b|final_norm|final_norm_b|enc_final_norm|enc_final_norm_b|q_norm|k_norm|q_norm_a|kv_norm_a|gate)$", ()),
]


def param_pspec(
    path: str,
    shape: tuple[int, ...],
    mesh: Mesh,
    *,
    fsdp_axis: Any = "data",
) -> P:
    """PartitionSpec for a parameter leaf by path name.

    A leaf under a scanned segment carries a leading layer-stack dim (never
    sharded); it is detected *by rank*: every non-empty rule's spec length
    equals the parameter's base rank, so ``ndim == len(rule)+1`` ⇔ stacked.
    (Path heuristics break for repeats==1 segments and unrolled probe
    configs, which have no stack dim.)  Dims not divisible by their axis
    extent are replicated.  ``fsdp_axis=None`` disables ZeRO sharding.
    """
    chosen: tuple[Any, ...] | None = None
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            chosen = spec
            break
    if chosen is None or len(chosen) == 0:
        return P(*((None,) * len(shape)))  # unmatched or norms/scalars: replicate
    if len(shape) == len(chosen) + 1:
        stacked = True
    elif len(shape) == len(chosen):
        stacked = False
    else:  # rank mismatch (e.g. scalar variants): replicate, never crash
        return P(*((None,) * len(shape)))
    base_shape = shape[1:] if stacked else shape
    out = []
    for i, dim in enumerate(base_shape):
        ax = chosen[i]
        if ax == "fsdp":
            ax = fsdp_axis
        if ax is None or dim % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*(((None,) if stacked else ()) + tuple(out)))


def params_shardings(params: Any, mesh: Mesh, *, fsdp_axis: Any = "data") -> Any:
    """Map a params pytree to NamedShardings (path-name rules)."""

    def one(path, leaf):
        pstr = "/" + "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        return NamedSharding(
            mesh,
            param_pspec(pstr, tuple(leaf.shape), mesh, fsdp_axis=fsdp_axis),
        )

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# decode-cache placement
# ---------------------------------------------------------------------------


# decode-cache leaf base ranks (unstacked); a leading layer-stack dim is
# detected exactly as ndim == base+1 (repeats==1 segments and unrolled
# probe configs have none).
_CACHE_BASE_RANK = {
    "k": 4, "v": 4,            # (B, S, Hkv, Dh)
    "k_mem": 4, "v_mem": 4,    # (B, M, Hkv, Dh)
    "ckv": 3, "krope": 3,      # (B, S, R)
    "conv": 3,                 # (B, W-1, C)
    "h": 4,                    # (B, NH, P, N)
}


def cache_shardings(
    cache: Any,
    mesh: Mesh,
    *,
    long_context: bool = False,
    layout: str = "seq",
) -> Any:
    """NamedShardings for a decode cache pytree.

    ``layout="seq"`` (default): batch over (pod, data); the KV sequence dim
    over model (context parallel inside attention) — kv heads are usually
    not divisible by the model axis (GQA kv=8 on 16-way TP), the sequence
    always is.  Long-context (B=1): the sequence dim shards over data
    instead, batch replicates.

    ``layout="heads"``: shard the head (k/v) or latent (MLA) dim over model
    and leave the sequence dim whole, enabling the in-place DUS cache
    update (``decode_rules_headsharded``).
    """
    dp = _dp(mesh)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        base = _CACHE_BASE_RANK.get(name)
        stacked = base is not None and leaf.ndim == base + 1
        nb = 1 if stacked else 0  # leading layer-stack dim
        dims = [None] * leaf.ndim
        seq_ax = "data" if long_context else "model"
        batch_ax = None if long_context else dp
        heads = layout == "heads" and not long_context
        if name in ("k", "v"):          # (.., B, S, Hkv, Dh)
            dims[nb + 0] = batch_ax
            if heads:
                dims[nb + 2] = "model"
            else:
                dims[nb + 1] = seq_ax
        elif name in ("k_mem", "v_mem"):  # (.., B, M, Hkv, Dh)
            dims[nb + 0] = batch_ax
        elif name in ("ckv", "krope"):    # (.., B, S, R)
            dims[nb + 0] = batch_ax
            if heads:
                dims[nb + 2] = "model"
            else:
                dims[nb + 1] = seq_ax
        elif name == "conv":              # (.., B, W-1, C)
            dims[nb + 0] = batch_ax
            dims[nb + 2] = "model"
        elif name == "h":                 # (.., B, NH, P, N)
            dims[nb + 0] = batch_ax
            dims[nb + 1] = "model"
        # drop non-divisible axes
        for i, (dim, ax) in enumerate(zip(leaf.shape, dims)):
            if ax is not None and dim % _axis_size(mesh, ax) != 0:
                dims[i] = None
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache)
