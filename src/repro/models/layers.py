"""Transformer building blocks: norms, RoPE, GQA attention, SwiGLU MLP.

Everything is a pure function over an explicit params dict.  Activations are
annotated with logical axes (``repro.distributed.sharding.shard``) so the
same code runs unannotated on one device and fully sharded under the
production mesh rules.

Attention supports the assigned archs' flags: GQA (grouped einsum — the
repeated KV heads are never materialized), QKV bias (qwen2), per-head
qk_norm (qwen3), sliding window (mixtral), bidirectional (whisper encoder),
cross-attention with static memory (whisper decoder / llama-vision), and a
ring-buffer KV cache for decode.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (((x - mu) * jax.lax.rsqrt(var + eps)) * (1.0 + w) + b).astype(dt)


def apply_norm(x: jax.Array, p: Params, name: str, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p[name], p[name + "_b"])
    return rms_norm(x, p[name])


def init_norm(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    out = {"w": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        out["b"] = jnp.zeros((d,), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (..., L) -> cos/sin (..., L, dim/2) in fp32."""
    freq = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, L, H, Dh); cos/sin (B, L, Dh/2) — rotate-half convention."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


def cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write ``new`` (B, 1, ...) into ``cache`` (B, S, ...) at row ``pos``.

    Baseline path: one-hot masked select instead of dynamic_update_slice —
    GSPMD cannot partition a DUS whose *traced* offset lands on a sharded
    dim (it replicates the whole cache — measured: 16× decode peak).  The
    masked write stays sharded, but it reads AND rewrites the full cache
    (≈4 extra cache passes per decode step on top of attention's 2 reads).

    Optimized path (§Perf): when the active rules shard the cache's
    sequence dim, ``_cache_write_sharded`` runs a shard_map in which only
    the rank owning slot ``pos`` performs a 1-row local DUS — in-place
    under donation, ~zero extra traffic.  Enabled per-config via
    ``decode_cache_impl="sharded_dus"``.
    """
    from repro.distributed.sharding import active_rules

    r = active_rules()
    impl = getattr(r, "cache_impl", "") if r is not None else ""
    if "sharded_dus" in impl:
        out = _cache_write_sharded(cache, new, pos, r)
        if out is not None:
            return out
    if "heads_dus" in impl:
        # head-sharded cache layout: the seq dim is whole on every rank, so
        # a traced-offset DUS partitions in place (no full-cache rewrite)
        start = (jnp.zeros((), pos.dtype), pos) + (jnp.zeros((), pos.dtype),) * (
            cache.ndim - 2
        )
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), start)
    s = cache.shape[1]
    mask = (jnp.arange(s) == pos).reshape((1, s) + (1,) * (cache.ndim - 2))
    return jnp.where(mask, new.astype(cache.dtype), cache)


def _cache_write_sharded(cache, new, pos, rules):
    """1-row local DUS on the rank owning the slot (see cache_write).

    Returns None when the layout doesn't qualify (seq axis unsharded or
    non-divisible) — caller falls back to the masked write.
    """
    from jax.sharding import PartitionSpec as P

    seq_ax = rules.logical.get("kv_seq")
    if not seq_ax:
        return None
    mesh = rules.mesh
    n_seq = mesh.shape[seq_ax] if not isinstance(seq_ax, tuple) else int(
        np.prod([mesh.shape[a] for a in seq_ax])
    )
    if n_seq <= 1 or cache.shape[1] % n_seq:
        return None
    batch_ax = rules.logical.get("batch")
    if batch_ax and cache.shape[0] % (
        int(np.prod([mesh.shape[a] for a in batch_ax]))
        if isinstance(batch_ax, tuple)
        else mesh.shape[batch_ax]
    ):
        batch_ax = None
    trail = (None,) * (cache.ndim - 2)
    c_spec = P(batch_ax, seq_ax, *trail)
    n_spec = P(batch_ax, None, *trail)

    def body(c, n, s):
        idx = jax.lax.axis_index(seq_ax)
        s_local = c.shape[1]
        local = s - idx * s_local
        in_range = (local >= 0) & (local < s_local)
        li = jnp.clip(local, 0, s_local - 1)
        row = jax.lax.dynamic_slice_in_dim(c, li, 1, axis=1)
        row = jnp.where(in_range, n.astype(c.dtype), row)
        return jax.lax.dynamic_update_slice_in_dim(c, row, li, axis=1)

    from repro.distributed.compat import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(c_spec, n_spec, P()),
        out_specs=c_spec,
        check_vma=False,
    )(cache, new.astype(cache.dtype), pos)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig, *, cross: bool = False) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    k = iter(jax.random.split(key, 8))
    sd = 1.0 / np.sqrt(d)
    p: Params = {
        "wq": jax.random.normal(next(k), (d, h, dh), jnp.float32) * sd,
        "wo": jax.random.normal(next(k), (h, dh, d), jnp.float32) / np.sqrt(h * dh),
    }
    mem_d = cfg.image_embed_dim if (cross and cfg.family == "vlm") else d
    kname, vname = ("wk_mem", "wv_mem") if cross else ("wk", "wv")
    p[kname] = jax.random.normal(next(k), (mem_d, hkv, dh), jnp.float32) / np.sqrt(mem_d)
    p[vname] = jax.random.normal(next(k), (mem_d, hkv, dh), jnp.float32) / np.sqrt(mem_d)
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), jnp.float32)
        p["bk"] = jnp.zeros((hkv, dh), jnp.float32)
        p["bv"] = jnp.zeros((hkv, dh), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    if cross:
        p["gate"] = jnp.zeros((), jnp.float32)  # llama-vision tanh gate
    return p


def _sdpa(
    q: jax.Array,  # (B, Lq, H, Dh)
    k: jax.Array,  # (B, Lk, Hkv, Dh)
    v: jax.Array,  # (B, Lk, Hkv, Dh)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    window: int = 0,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Grouped-query scaled dot-product attention (reference path).

    Never materializes repeated KV heads: q is reshaped to (B, Lq, Hkv, G,
    Dh) and scores are computed per kv-head group.  ``q_offset`` is the
    absolute position of q's first row (decode: current position).
    ``kv_len`` masks cache tails beyond the valid length.
    """
    b, lq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, lq, hkv, g, dh)
    scale = 1.0 / np.sqrt(dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale

    qpos = jnp.arange(lq)[:, None] + q_offset          # (Lq, 1) absolute
    kpos = jnp.arange(k.shape[1])[None, :]             # (1, Lk)
    mask = jnp.ones((lq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, lq, h, dh)


def _sdpa_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    kv_len: jax.Array | None = None,
    q_chunk: int = 512,
) -> jax.Array:
    """Query-chunked attention: O(bq·Lk) live scores instead of O(Lq·Lk).

    The XLA-level analogue of the flash kernel's memory behavior — this is
    what the dry-run compiles, so the roofline memory term reflects
    kernel-like (not materialized-S²) attention.  ``lax.scan`` over query
    chunks; K/V stay resident.
    """
    b, lq, h, dh = q.shape
    c = min(q_chunk, lq)
    if lq % c:
        return _sdpa(q, k, v, causal=causal, window=window, kv_len=kv_len)
    nc = lq // c
    qs = jnp.moveaxis(q.reshape(b, nc, c, h, dh), 1, 0)  # (nc, B, c, h, dh)

    def body(_, inp):
        qc, iq = inp
        o = _sdpa(
            qc, k, v, causal=causal, q_offset=iq * c, window=window, kv_len=kv_len
        )
        return None, o

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, lq, h, dh)


_CHUNK_THRESHOLD = 2048


def _sdpa_auto(q, k, v, *, causal, window=0, kv_len=None):
    if q.shape[1] >= _CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, causal=causal, window=window, kv_len=kv_len)
    return _sdpa(q, k, v, causal=causal, window=window, kv_len=kv_len)


def _sdpa_decode_decomposed(
    q: jax.Array,       # (B, 1, H, Dh)
    kc: jax.Array,      # (B, S, Hkv, Dh) cache BEFORE this token's write
    vc: jax.Array,
    kn: jax.Array,      # (B, 1, Hkv, Dh) this token's k/v
    vn: jax.Array,
    *,
    valid_len: jax.Array,   # number of valid cache rows (= pos, or window fill)
    slot: jax.Array,        # ring slot this token will occupy (masked out)
) -> jax.Array:
    """Decode attention over (old cache ⊕ new token) with a joint softmax.

    Mathematically identical to write-then-attend, but nothing reads the
    *updated* cache: the old cache is an attention operand and the 1-row
    write can alias in place (§Perf cell B).  The ring ``slot`` is masked
    from the old cache (it holds the evicted token once the window wraps).
    """
    b, lq, h, dh = q.shape
    hkv = kc.shape[2]
    g = h // hkv
    qg = q.reshape(b, lq, hkv, g, dh)
    scale = 1.0 / np.sqrt(dh)
    s_old = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32) * scale
    kpos = jnp.arange(kc.shape[1])
    mask = (kpos < valid_len) & (kpos != slot)
    s_old = jnp.where(mask[None, None, None, None], s_old, -1e30)
    s_new = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kn).astype(jnp.float32) * scale
    probs = jax.nn.softmax(jnp.concatenate([s_old, s_new], -1), axis=-1)
    p_old = probs[..., :-1].astype(vc.dtype)
    p_new = probs[..., -1:].astype(vn.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p_old, vc) + jnp.einsum(
        "bhgqk,bkhd->bqhgd", p_new, vn
    )
    return out.reshape(b, lq, h, dh)


def attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, L, D)
    *,
    positions: jax.Array,          # (B, L) absolute positions
    causal: bool = True,
    cache: Params | None = None,   # {"k","v"} (B, S, Hkv, Dh) ring/linear
    cache_pos: jax.Array | None = None,  # scalar: #tokens already cached
    memory: jax.Array | None = None,     # (B, M, Dm) for cross-attention
) -> tuple[jax.Array, Params | None]:
    """Self/cross attention.  Returns (out (B,L,D), updated cache or None).

    Modes:
      * train/prefill: ``cache is None`` → full self-attention (optionally
        returns no cache; prefill-with-cache uses ``cache`` with
        ``cache_pos=0`` and L ≤ S).
      * decode: L == 1, ``cache_pos`` = current length; KV written at
        ``cache_pos`` (ring position for SWA).
      * cross: ``memory`` supplies K/V (no cache mechanics needed beyond
        one-time projection, passed as ``cache``).
    """
    dh = cfg.resolved_head_dim
    dt = x.dtype
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)

    is_cross = memory is not None or (cache is not None and "k_mem" in cache)
    if is_cross:  # cross-attention path
        if memory is not None:  # train / prefill: project (and cache) memory
            kk = jnp.einsum("bmd,dhk->bmhk", memory, p["wk_mem"].astype(dt))
            vv = jnp.einsum("bmd,dhk->bmhk", memory, p["wv_mem"].astype(dt))
            new_cache = (
                {"k_mem": kk.astype(cache["k_mem"].dtype), "v_mem": vv.astype(cache["v_mem"].dtype)}
                if cache is not None
                else None
            )
        else:  # decode: reuse the projected memory from prefill
            kk, vv = cache["k_mem"].astype(dt), cache["v_mem"].astype(dt)
            new_cache = cache
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            kk = rms_norm(kk, p["k_norm"])
        out = _sdpa(q, kk, vv, causal=False)
        out = jnp.einsum("blhk,hkd->bld", out, p["wo"].astype(dt))
        if "gate" in p:
            out = jnp.tanh(p["gate"].astype(dt)) * out
        return out, new_cache

    k = jnp.einsum("bld,dhk->blhk", x, p["wk"].astype(dt))
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")

    if cache is None:
        out = _sdpa_auto(q, k, v, causal=causal, window=cfg.sliding_window)
        new_cache = None
    else:
        s_max = cache["k"].shape[1]
        if q.shape[1] == 1:  # -------- decode step --------
            from repro.distributed.sharding import active_rules

            r = active_rules()
            impl = getattr(r, "cache_impl", "") if r is not None else ""
            # ring index under SWA, linear otherwise
            slot = cache_pos % s_max if cfg.sliding_window else cache_pos
            if "decomposed" in impl:
                # attend (old cache ⊕ new token); the updated cache is only
                # written, never read — in-place under donation (§Perf)
                valid = (
                    jnp.minimum(cache_pos, s_max)
                    if cfg.sliding_window
                    else cache_pos
                )
                out = _sdpa_decode_decomposed(
                    q, cache["k"], cache["v"], k, v, valid_len=valid, slot=slot
                )
                ck = cache_write(cache["k"], k, slot)
                cv = cache_write(cache["v"], v, slot)
            else:
                ck = cache_write(cache["k"], k, slot)
                cv = cache_write(cache["v"], v, slot)
                if cfg.sliding_window:
                    # every live slot is in-window; mask only unwritten rows
                    valid = jnp.minimum(cache_pos + 1, s_max)
                    out = _sdpa(q, ck, cv, causal=False, kv_len=valid)
                else:
                    out = _sdpa(q, ck, cv, causal=False, kv_len=cache_pos + 1)
            new_cache = {"k": ck, "v": cv}
        else:  # -------- prefill into cache --------
            lq = q.shape[1]
            if cfg.sliding_window and lq > s_max:
                # Only the last window survives; place token t at slot
                # t % s_max so later decode writes stay consistent.
                tail_k = jnp.roll(k[:, -s_max:], lq % s_max, axis=1)
                tail_v = jnp.roll(v[:, -s_max:], lq % s_max, axis=1)
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], tail_k.astype(cache["k"].dtype), (0, 0, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], tail_v.astype(cache["v"].dtype), (0, 0, 0, 0)
                )
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
                )
            out = _sdpa_auto(q, k, v, causal=causal, window=cfg.sliding_window)
            new_cache = {"k": ck, "v": cv}

    out = jnp.einsum("blhk,hkd->bld", out, p["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int) -> Params:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), jnp.float32) / np.sqrt(d),
        "w_up": jax.random.normal(k2, (d, d_ff), jnp.float32) / np.sqrt(d),
        "w_down": jax.random.normal(k3, (d_ff, d), jnp.float32) / np.sqrt(d_ff),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    h = shard(h, "batch", "seq", "mlp")
    return h @ p["w_down"].astype(dt)
