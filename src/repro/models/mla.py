"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a rank-``kv_lora_rank`` latent c_kv plus a small
decoupled-RoPE key shared across heads; per-head K/V are up-projected from
the latent.  The decode cache stores ONLY (c_kv, k_rope) — the point of MLA
— and the decode path uses the *absorbed* formulation (W^UK folded into q,
W^UV folded into W^O) so per-step cost is O(S·(kv_lora+rope)) per head
rather than O(S·Dh·H) of decompress-then-attend.

Shapes:  q_nope (B,L,H,Dh), q_rope (B,L,H,Rh), c_kv (B,L,Kr), k_rope (B,L,Rh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import Params, apply_rope, rms_norm, rope_cos_sin


def init_mla(key: jax.Array, cfg: ModelConfig) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, kr, rh, qr = cfg.num_heads, cfg.kv_lora_rank, cfg.rope_head_dim, cfg.q_lora_rank
    k = iter(jax.random.split(key, 8))
    p: Params = {}
    if qr:
        p["wq_a"] = jax.random.normal(next(k), (d, qr), jnp.float32) / np.sqrt(d)
        p["q_norm_a"] = jnp.zeros((qr,), jnp.float32)
        p["wq_b"] = jax.random.normal(next(k), (qr, h, dh + rh), jnp.float32) / np.sqrt(qr)
    else:
        p["wq_b"] = jax.random.normal(next(k), (d, h, dh + rh), jnp.float32) / np.sqrt(d)
    p["wkv_a"] = jax.random.normal(next(k), (d, kr + rh), jnp.float32) / np.sqrt(d)
    p["kv_norm_a"] = jnp.zeros((kr,), jnp.float32)
    p["wk_b"] = jax.random.normal(next(k), (kr, h, dh), jnp.float32) / np.sqrt(kr)
    p["wv_b"] = jax.random.normal(next(k), (kr, h, dh), jnp.float32) / np.sqrt(kr)
    p["wo"] = jax.random.normal(next(k), (h, dh, d), jnp.float32) / np.sqrt(h * dh)
    return p


def _project_q(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    dt = x.dtype
    dh, rh = cfg.resolved_head_dim, cfg.rope_head_dim
    if "wq_a" in p:
        qa = x @ p["wq_a"].astype(dt)
        qa = rms_norm(qa, p["q_norm_a"])
        q = jnp.einsum("blr,rhk->blhk", qa, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bld,dhk->blhk", x, p["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    cos, sin = rope_cos_sin(positions, rh, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _project_kv_latent(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    dt = x.dtype
    kr, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    kv = x @ p["wkv_a"].astype(dt)                        # (B, L, Kr+Rh)
    c_kv = rms_norm(kv[..., :kr], p["kv_norm_a"])
    k_rope = kv[..., kr:][:, :, None, :]                  # (B, L, 1, Rh)
    cos, sin = rope_cos_sin(positions, rh, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]        # shared across heads
    return c_kv, k_rope


def mla_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Params | None = None,   # {"ckv": (B,S,Kr), "krope": (B,S,Rh)}
    cache_pos: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    dt = x.dtype
    dh, kr = cfg.resolved_head_dim, cfg.kv_lora_rank
    scale = 1.0 / np.sqrt(dh + cfg.rope_head_dim)

    q_nope, q_rope = _project_q(p, cfg, x, positions)

    if cache is not None and x.shape[1] == 1:
        # ---------------- absorbed decode ----------------
        from repro.models.layers import cache_write

        c_new, kr_new = _project_kv_latent(p, cfg, x, positions)
        ckv = cache_write(cache["ckv"], c_new, cache_pos)
        krope = cache_write(cache["krope"], kr_new, cache_pos)
        # absorb W^UK into q:  q_lat (B,1,H,Kr)
        q_lat = jnp.einsum("blhk,rhk->blhr", q_nope, p["wk_b"].astype(dt))
        # context-parallel decode: q replicates over model, ckv stays S-sharded
        q_lat = shard(q_lat, "batch", "seq", "heads", "head_dim")
        q_rope = shard(q_rope, "batch", "seq", "heads", "head_dim")
        s_nope = jnp.einsum("blhr,bsr->bhls", q_lat, ckv.astype(dt))
        s_rope = jnp.einsum("blhk,bsk->bhls", q_rope, krope.astype(dt))
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        kpos = jnp.arange(ckv.shape[1])[None, None, None]
        scores = jnp.where(kpos <= cache_pos, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        # attend in latent space, then absorb W^UV on the way out
        o_lat = jnp.einsum("bhls,bsr->blhr", probs, ckv.astype(dt))
        o = jnp.einsum("blhr,rhk->blhk", o_lat, p["wv_b"].astype(dt))
        out = jnp.einsum("blhk,hkd->bld", o, p["wo"].astype(dt))
        return out, {"ckv": ckv, "krope": krope}

    # ---------------- train / prefill (decompressed) ----------------
    c_kv, k_rope = _project_kv_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("blr,rhk->blhk", c_kv, p["wk_b"].astype(dt))
    v = jnp.einsum("blr,rhk->blhk", c_kv, p["wv_b"].astype(dt))
    k_nope = shard(k_nope, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")

    b, l, h, _ = q_nope.shape

    def q_chunk_attn(qn, qr, q_off):
        """One query chunk vs. full K/V (keeps live scores O(c·L))."""
        s_nope = jnp.einsum("blhk,bshk->bhls", qn, k_nope)
        s_rope = jnp.einsum("blhk,bsk->bhls", qr, k_rope)
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        qpos = jnp.arange(qn.shape[1])[:, None] + q_off
        kpos = jnp.arange(l)[None, :]
        scores = jnp.where((kpos <= qpos)[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        return jnp.einsum("bhls,bshk->blhk", probs, v)

    chunk = 512
    if l >= 2048 and l % chunk == 0:
        nc = l // chunk
        qn = jnp.moveaxis(q_nope.reshape(b, nc, chunk, h, -1), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, chunk, h, -1), 1, 0)

        def body(_, inp):
            qn_c, qr_c, ic = inp
            return None, q_chunk_attn(qn_c, qr_c, ic * chunk)

        _, outs = jax.lax.scan(body, None, (qn, qr, jnp.arange(nc)))
        o = jnp.moveaxis(outs, 0, 1).reshape(b, l, h, -1)
    else:
        o = q_chunk_attn(q_nope, q_rope, 0)
    out = jnp.einsum("blhk,hkd->bld", o, p["wo"].astype(dt))

    new_cache = None
    if cache is not None:  # prefill into the compressed cache
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0)
        )
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0)
        )
        new_cache = {"ckv": ckv, "krope": krope}
    return out, new_cache
