"""Model zoo: pure-function models (pytree params) assembled from LayerSpecs."""

from repro.models.lm import Model, build_model

__all__ = ["Model", "build_model"]
