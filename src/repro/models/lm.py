"""Model assembly: LayerSpec segments → init / loss / prefill / decode_step.

One assembly path serves all ten architectures.  Each config's
``segments()`` yields repeated periods; repeated periods are executed with
``lax.scan`` over stacked parameters so the full-size HLO stays small and
`cost_analysis` probes stay linear in depth (DESIGN.md §6).

Entry points (all pure functions of explicit params):

* ``model.init(key)``                 → params pytree (works under eval_shape)
* ``model.loss(params, batch)``       → scalar CE loss (training forward)
* ``model.prefill(params, batch, cache)`` → (last_logits, cache)
* ``model.decode_step(params, cache, token, pos)`` → (logits, cache)
* ``model.init_cache(batch, max_len)``→ cache pytree (decode state)
* ``model.input_specs(shape)``        → ShapeDtypeStructs for the dry-run
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig, Segment, ShapeCell
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.mla import init_mla, mla_attention
from repro.models.moe import init_moe, moe_mlp
from repro.models.ssm import init_mamba, mamba_block

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, spec: LayerSpec, cfg: ModelConfig) -> Params:
    km, kf, _ = jax.random.split(key, 3)
    p: Params = {}
    norm = L.init_norm(cfg)
    p["ln1"] = norm["w"]
    if "b" in norm:
        p["ln1_b"] = norm["b"]
    if spec.mixer == "attn" or spec.mixer == "enc_attn":
        p["mixer"] = L.init_attention(km, cfg)
    elif spec.mixer == "cross_attn":
        p["mixer"] = L.init_attention(km, cfg, cross=True)
    elif spec.mixer == "mla":
        p["mixer"] = init_mla(km, cfg)
    elif spec.mixer == "mamba2":
        p["mixer"] = init_mamba(km, cfg)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    if spec.mlp != "none" and not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg)["w"]
        if cfg.norm == "layernorm":
            p["ln2_b"] = L.init_norm(cfg)["b"]
    if spec.mlp == "dense":
        ff = cfg.dense_d_ff or cfg.d_ff
        p["mlp"] = L.init_mlp(kf, cfg, ff)
    elif spec.mlp == "moe":
        p["mlp"] = init_moe(kf, cfg)
    return p


def _apply_layer(
    p: Params,
    spec: LayerSpec,
    cfg: ModelConfig,
    x: jax.Array,
    ctx: dict[str, Any],
    cache: Params | None,
) -> tuple[jax.Array, Params | None]:
    """Pre-norm residual block; command-r runs attn ∥ mlp off one norm."""
    h = L.apply_norm(x, p, "ln1", cfg)
    new_cache = None
    if spec.mixer in ("attn", "enc_attn"):
        mix, new_cache = L.attention(
            p["mixer"],
            cfg,
            h,
            positions=ctx["positions"],
            causal=spec.mixer == "attn",
            cache=cache,
            cache_pos=ctx.get("cache_pos"),
        )
    elif spec.mixer == "cross_attn":
        mix, new_cache = L.attention(
            p["mixer"],
            cfg,
            h,
            positions=ctx["positions"],
            cache=cache,
            memory=ctx.get("memory"),
        )
    elif spec.mixer == "mla":
        mix, new_cache = mla_attention(
            p["mixer"],
            cfg,
            h,
            positions=ctx["positions"],
            cache=cache,
            cache_pos=ctx.get("cache_pos"),
        )
    elif spec.mixer == "mamba2":
        mix, new_cache = mamba_block(p["mixer"], cfg, h, cache=cache)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)

    if cfg.parallel_block and spec.mlp != "none":
        # command-r: x + attn(norm(x)) + mlp(norm(x))
        ff = L.mlp(p["mlp"], h) if spec.mlp == "dense" else moe_mlp(p["mlp"], cfg, h)
        x = x + mix + ff
        return shard(x, "batch", "seq_res", "embed"), new_cache

    x = x + mix
    if spec.mlp != "none":
        h2 = L.apply_norm(x, p, "ln2", cfg)
        ff = L.mlp(p["mlp"], h2) if spec.mlp == "dense" else moe_mlp(p["mlp"], cfg, h2)
        x = x + ff
    return shard(x, "batch", "seq_res", "embed"), new_cache


# ---------------------------------------------------------------------------
# per-layer cache construction
# ---------------------------------------------------------------------------


def _init_layer_cache(
    spec: LayerSpec, cfg: ModelConfig, batch: int, max_len: int, dtype
) -> Params | None:
    if spec.mixer in ("attn", "enc_attn"):
        dh = cfg.resolved_head_dim
        s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        shp = (batch, s, cfg.num_kv_heads, dh)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if spec.mixer == "cross_attn":
        dh = cfg.resolved_head_dim
        m = cfg.encoder_seq if cfg.family == "audio" else cfg.image_tokens
        shp = (batch, m, cfg.num_kv_heads, dh)
        return {"k_mem": jnp.zeros(shp, dtype), "v_mem": jnp.zeros(shp, dtype)}
    if spec.mixer == "mla":
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        }
    if spec.mixer == "mamba2":
        din = cfg.ssm_expand * cfg.d_model
        nh = din // cfg.ssm_head_dim
        conv_c = din + 2 * cfg.ssm_state
        return {
            "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_c), dtype),
            # SSM state accumulates across the whole context: keep fp32
            "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        }
    return None


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Params = {
            "embed": jax.random.normal(
                keys[0], (cfg.padded_vocab, cfg.d_model), jnp.float32
            )
            / np.sqrt(cfg.d_model)
        }
        for si, seg in enumerate(cfg.segments()):
            params[f"seg{si}"] = self._init_segment(keys[1 + si], seg)
        if cfg.encoder_layers:
            params["enc_seg0"] = self._init_segment(
                keys[5], cfg.encoder_segments()[0], enc=True
            )
            params["enc_final_norm"] = L.init_norm(cfg)["w"]
            if cfg.norm == "layernorm":
                params["enc_final_norm_b"] = L.init_norm(cfg)["b"]
        params["final_norm"] = L.init_norm(cfg)["w"]
        if cfg.norm == "layernorm":
            params["final_norm_b"] = L.init_norm(cfg)["b"]
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(
                keys[6], (cfg.d_model, cfg.padded_vocab), jnp.float32
            ) / np.sqrt(cfg.d_model)
        return params

    def _init_segment(self, key: jax.Array, seg: Segment, enc: bool = False) -> Any:
        def init_period(k):
            ks = jax.random.split(k, len(seg.period))
            return tuple(
                _init_layer(ks[i], spec, self.cfg)
                for i, spec in enumerate(seg.period)
            )

        if seg.repeats == 1:
            return init_period(key)
        keys = jax.random.split(key, seg.repeats)
        return jax.vmap(init_period)(keys)  # leaves: (repeats, ...)

    # ---------------- trunk executors ----------------

    def _run_segment(
        self,
        seg_params: Any,
        seg: Segment,
        x: jax.Array,
        ctx: dict[str, Any],
        caches: Any | None,
        *,
        remat: bool,
    ) -> tuple[jax.Array, Any | None]:
        cfg = self.cfg

        def period_body(x, period_params, period_caches):
            new_caches = []
            for i, spec in enumerate(seg.period):
                c = None if period_caches is None else period_caches[i]
                x, nc = _apply_layer(period_params[i], spec, cfg, x, ctx, c)
                new_caches.append(nc)
            return x, tuple(new_caches)

        if remat and cfg.remat != "none":
            policy = (
                jax.checkpoint_policies.nothing_saveable
                if cfg.remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
            period_body = jax.checkpoint(
                period_body, policy=policy, static_argnums=()
            )

        if seg.repeats == 1:
            return period_body(x, seg_params, caches)

        if caches is None:

            def scan_no_cache(x, pp):
                y, _ = period_body(x, pp, None)
                return y, None

            x, _ = jax.lax.scan(scan_no_cache, x, seg_params)
            return x, None

        def scan_with_cache(x, pc):
            pp, cc = pc
            y, nc = period_body(x, pp, cc)
            return y, nc

        x, new_caches = jax.lax.scan(scan_with_cache, x, (seg_params, caches))
        return x, new_caches

    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over stubbed frame embeddings (B, M, D)."""
        cfg = self.cfg
        b, m, _ = frames.shape
        ctx = {"positions": jnp.broadcast_to(jnp.arange(m), (b, m))}
        seg = cfg.encoder_segments()[0]
        x, _ = self._run_segment(
            params["enc_seg0"], seg, frames.astype(cfg.dtype), ctx, None, remat=False
        )
        if cfg.norm == "layernorm":
            return L.layer_norm(x, params["enc_final_norm"], params["enc_final_norm_b"])
        return L.rms_norm(x, params["enc_final_norm"])

    def _trunk(
        self,
        params: Params,
        x: jax.Array,
        ctx: dict[str, Any],
        caches: Any | None,
        *,
        remat: bool,
    ) -> tuple[jax.Array, Any | None]:
        new_caches = {}
        for si, seg in enumerate(self.cfg.segments()):
            c = None if caches is None else caches[f"seg{si}"]
            x, nc = self._run_segment(
                params[f"seg{si}"], seg, x, ctx, c, remat=remat
            )
            if caches is not None:
                new_caches[f"seg{si}"] = nc
        return x, (new_caches if caches is not None else None)

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.norm == "layernorm":
            x = L.layer_norm(x, params["final_norm"], params["final_norm_b"])
        else:
            x = L.rms_norm(x, params["final_norm"])
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ).astype(x.dtype)
        logits = x @ head
        logits = shard(logits, "batch", "seq", "vocab")
        # mask Megatron-style vocab padding
        if cfg.padded_vocab != cfg.vocab_size:
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        return logits

    def _memory(self, params: Params, batch: dict[str, jax.Array]) -> jax.Array | None:
        cfg = self.cfg
        if cfg.family == "audio":
            return self._encode(params, batch["frames"])
        if cfg.family == "vlm":
            return batch["image_embeds"].astype(cfg.dtype)
        return None

    # ---------------- entry points ----------------

    def forward(self, params: Params, batch: dict[str, jax.Array], *, remat: bool):
        """Training/scoring forward → logits (B, S, Vp)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = shard(x, "batch", "seq_res", "embed")
        ctx = {
            "positions": jnp.broadcast_to(jnp.arange(s), (b, s)),
            "memory": self._memory(params, batch),
        }
        x, _ = self._trunk(params, x, ctx, None, remat=remat)
        return self._logits(params, x)

    def loss(self, params: Params, batch: dict[str, jax.Array]) -> jax.Array:
        """Mean next-token CE over ``labels >= 0`` positions."""
        logits = self.forward(params, batch, remat=True)
        labels = batch["labels"]
        mask = labels >= 0
        lab = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)

    def init_cache(
        self, batch: int, max_len: int, dtype=jnp.bfloat16
    ) -> Params:
        caches: Params = {}
        for si, seg in enumerate(self.cfg.segments()):
            def one_period():
                return tuple(
                    _init_layer_cache(spec, self.cfg, batch, max_len, dtype)
                    for spec in seg.period
                )

            if seg.repeats == 1:
                caches[f"seg{si}"] = one_period()
            else:
                caches[f"seg{si}"] = jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (seg.repeats,) + l.shape).copy()
                    if l is not None
                    else None,
                    one_period(),
                )
        return caches

    def prefill(
        self, params: Params, batch: dict[str, jax.Array], cache: Params
    ) -> tuple[jax.Array, Params]:
        """Run the full prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        ctx = {
            "positions": jnp.broadcast_to(jnp.arange(s), (b, s)),
            "memory": self._memory(params, batch),
            "cache_pos": jnp.asarray(0, jnp.int32),
        }
        x, new_cache = self._trunk(params, x, ctx, cache, remat=False)
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, new_cache

    def decode_step(
        self,
        params: Params,
        cache: Params,
        token: jax.Array,  # (B, 1) int32
        pos: jax.Array,    # scalar int32: #tokens already in cache
        memory: jax.Array | None = None,
    ) -> tuple[jax.Array, Params]:
        cfg = self.cfg
        b = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
        ctx = {
            "positions": jnp.full((b, 1), pos, jnp.int32),
            "cache_pos": pos,
            "memory": memory,
        }
        x, new_cache = self._trunk(params, x, ctx, cache, remat=False)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    # ---------------- dry-run input specs ----------------

    def input_specs(self, shape: ShapeCell) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        Modality frontends are stubbed here per the assignment: whisper gets
        precomputed frame embeddings, the VLM gets patch embeddings.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:  # decode: one new token against a cache of length s
            specs = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "audio" and shape.kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), f)
        if cfg.family == "vlm" and shape.kind != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.image_tokens, cfg.image_embed_dim), f
            )
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
