"""Mamba2 block — SSD (state-space duality) chunked form (arXiv:2405.21060).

Recurrence (per head h, head_dim P, state N):
    H_t = exp(dt_t·A_h) · H_{t-1} + dt_t · x_t ⊗ B_t          H ∈ (P, N)
    y_t = H_t · C_t + D_h · x_t

The chunked SSD algorithm splits the sequence into chunks of length Q:
inside a chunk the contribution is an attention-like quadratic form
(C_i·B_jᵀ masked by the decay segment-sum), across chunks a small state is
passed through a scan — O(L·Q) instead of O(L²), MXU-friendly.  This file
is the pure-jnp implementation used by the model; ``repro.kernels.ssd_scan``
is the Pallas kernel version of the same algorithm and is verified against
:func:`ssd_chunked` (which is itself verified against :func:`ssd_reference`,
the naive sequential recurrence).

Single B/C group (n_groups=1), as in the assigned configs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import Params, rms_norm


# ---------------------------------------------------------------------------
# SSD core (both forms operate on per-head inputs)
#   x  (B, L, NH, P)   dt (B, L, NH)   A (NH,)  negative
#   Bm (B, L, N)       Cm (B, L, N)
# ---------------------------------------------------------------------------


def ssd_reference(x, dt, a, bm, cm):
    """Naive sequential recurrence — the oracle."""

    b, l, nh, p = x.shape
    n = bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (B,NH,P) (B,NH) (B,N) (B,N)
        decay = jnp.exp(dtt * a)[..., None, None]  # (B,NH,1,1)
        upd = (dtt[..., None, None] * xt[..., None]) * bt[:, None, None, :]
        h = h * decay + upd                        # (B,NH,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((b, nh, p, n), x.dtype)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(bm, 1, 0),
        jnp.moveaxis(cm, 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h              # (B,L,NH,P), final state


def ssd_chunked(x, dt, a, bm, cm, *, chunk: int):
    """Chunked SSD (the paper's Algorithm 1, jnp form).

    Returns (y (B,L,NH,P), final_state (B,NH,P,N)).
    Requires L % chunk == 0.
    """
    b, l, nh, p = x.shape
    n = bm.shape[-1]
    q = chunk
    nc = l // q
    assert l % q == 0, (l, q)

    xc = x.reshape(b, nc, q, nh, p)
    dtc = dt.reshape(b, nc, q, nh)
    bc = bm.reshape(b, nc, q, n)
    cc = cm.reshape(b, nc, q, n)

    da = dtc * a                                   # (B,NC,Q,NH) log-decay
    seg = jnp.cumsum(da, axis=2)                   # inclusive cumsum in-chunk

    # ---- intra-chunk (quadratic attention-like form) ----------------------
    # decay from j -> i (j<=i):  exp(seg_i - seg_j)
    li = seg[:, :, :, None, :]                     # (B,NC,Q,1,NH)
    lj = seg[:, :, None, :, :]                     # (B,NC,1,Q,NH)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask inside the exponent: exp(-inf) = 0 with a zero (not NaN) gradient
    gam = jnp.exp(jnp.where(mask, li - lj, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)     # (B,NC,Q,Q)
    w = cb[..., None] * gam                        # (B,NC,Q,Q,NH)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dtc, xc)

    # ---- chunk states ------------------------------------------------------
    # state contributed by chunk c:  sum_j exp(seg_Q - seg_j)·dt_j·x_j ⊗ B_j
    tail = jnp.exp(seg[:, :, -1:, :] - seg)        # (B,NC,Q,NH)
    st = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn", tail, dtc, xc, bc)

    # ---- inter-chunk scan over chunk boundary states -----------------------
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))     # (B,NC,NH)

    def carry_fn(h, inp):
        st_c, dec_c = inp                          # (B,NH,P,N), (B,NH)
        h_new = h * dec_c[..., None, None] + st_c
        return h_new, h                            # emit state ENTERING chunk

    h0 = jnp.zeros((b, nh, p, n), x.dtype)
    hf, h_in = jax.lax.scan(
        carry_fn,
        h0,
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                # (B,NC,NH,P,N)

    # ---- inter-chunk contribution to outputs -------------------------------
    into = jnp.exp(seg)                            # decay 0..i within chunk
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", cc, into, h_in
    )

    y = (y_intra + y_inter).reshape(b, l, nh, p)
    return y, hf


def ssd_decode_step(h, x_t, dt_t, a, b_t, c_t):
    """One-token recurrence for serving.  h (B,NH,P,N) → (y_t, h)."""
    decay = jnp.exp(dt_t * a)[..., None, None]
    h = h * decay + (dt_t[..., None, None] * x_t[..., None]) * b_t[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, c_t)
    return y, h


# ---------------------------------------------------------------------------
# the full Mamba2 block (proj → conv → SSD → gated norm → out proj)
# ---------------------------------------------------------------------------


def init_mamba(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_head_dim
    n = cfg.ssm_state
    w = cfg.ssm_conv_width
    k = iter(jax.random.split(key, 10))
    sd = 1.0 / np.sqrt(d)
    return {
        "w_in_z": jax.random.normal(next(k), (d, din), jnp.float32) * sd,
        "w_in_x": jax.random.normal(next(k), (d, din), jnp.float32) * sd,
        "w_in_b": jax.random.normal(next(k), (d, n), jnp.float32) * sd,
        "w_in_c": jax.random.normal(next(k), (d, n), jnp.float32) * sd,
        "w_in_dt": jax.random.normal(next(k), (d, nh), jnp.float32) * sd,
        "conv_x": jax.random.normal(next(k), (w, din), jnp.float32) / np.sqrt(w),
        "conv_b": jax.random.normal(next(k), (w, n), jnp.float32) / np.sqrt(w),
        "conv_c": jax.random.normal(next(k), (w, n), jnp.float32) / np.sqrt(w),
        "A_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(A_log) = -1
        "ssm_D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssm_norm": jnp.zeros((din,), jnp.float32),
        "w_out": jax.random.normal(next(k), (din, d), jnp.float32) / np.sqrt(din),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv, width W.  x (B,L,C), w (W,C).

    With ``state`` (B,W-1,C) the conv continues a stream (decode); returns
    (out, new_state) where new_state holds the last W-1 inputs.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, L+W-1, C)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, -(width - 1) :, :]
    return jax.nn.silu(out), new_state


def mamba_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                         # (B, L, D)
    *,
    cache: Params | None = None,          # {"conv": (B,W-1,Cin), "h": (B,NH,P,N)}
    use_chunked: bool = True,
) -> tuple[jax.Array, Params | None]:
    dt_ = x.dtype
    d = cfg.d_model
    din = cfg.ssm_expand * d
    ph = cfg.ssm_head_dim
    nh = din // ph
    n = cfg.ssm_state
    b, l, _ = x.shape

    z = x @ p["w_in_z"].astype(dt_)
    xin = x @ p["w_in_x"].astype(dt_)
    bm = x @ p["w_in_b"].astype(dt_)
    cm = x @ p["w_in_c"].astype(dt_)
    dt = x @ p["w_in_dt"].astype(dt_)
    xin = shard(xin, "batch", "seq", "mlp")
    z = shard(z, "batch", "seq", "mlp")

    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)
    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1
    ).astype(dt_)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, conv_w, conv_state)
    xin, bm, cm = jnp.split(conv_out, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(dt_)
    a = (-jnp.exp(p["A_log"])).astype(dt_)            # (NH,)
    xh = xin.reshape(b, l, nh, ph)

    if cache is not None and l == 1:
        y, h = ssd_decode_step(
            cache["h"].astype(dt_), xh[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0]
        )
        y = y[:, None]                                # (B,1,NH,P)
    else:
        if use_chunked and l % cfg.ssm_chunk == 0 and l > cfg.ssm_chunk:
            y, h = ssd_chunked(xh, dt, a, bm, cm, chunk=cfg.ssm_chunk)
        else:
            y, h = ssd_reference(xh, dt, a, bm, cm)

    y = y + p["ssm_D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(b, l, din)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"])
    out = y @ p["w_out"].astype(dt_)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "h": h.astype(cache["h"].dtype)}
    return out, new_cache
