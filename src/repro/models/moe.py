"""Mixture-of-Experts MLP: top-k routing, shared experts, two dispatch paths.

``moe_impl="onehot"`` (default, the production path)
    GShard/GSPMD-style capacity-bucketed dispatch: tokens are reshaped into
    fixed-size *groups*, each expert gets a ``capacity``-slot buffer per
    group, and dispatch/combine are one-hot einsums.  Every tensor has a
    static shape with a token/group dim (shards over DP) and an expert dim
    (shards over the TP/"model" axis), so the SPMD partitioner distributes
    it cleanly — this is what the multi-pod dry-run lowers.  Tokens beyond
    an expert's capacity are dropped (standard at scale; the capacity
    factor controls the slack).

``moe_impl="ragged"``
    Sort-based *dropless* dispatch (argsort tokens by expert, grouped
    matmul via ``jax.lax.ragged_dot``, unsort).  Exact — the single-device
    reference the onehot path is tested against (with a no-drop capacity) —
    but the global argsort does not partition, so it is not used under a
    mesh.

**Virtual expert splitting** (mixtral): with 8 experts on a 16-way model
axis the expert dim cannot shard.  Each expert is split into
``moe_virtual_split`` half-width experts — exact, because the MLP is
separable over the hidden dim: ``down(act(gate)·up)`` sums over F, so
splitting F into n slices and summing their outputs reproduces the full
expert bit-for-bit.  A token is dispatched to every slice of its chosen
expert with the same gate weight.

The dispatch itself is a SplIter-shaped problem (DESIGN.md §4): tokens are
*blocks*, experts are *locations*, and the grouping into per-expert
capacity buffers decouples task granularity (one grouped matmul per
expert) from block granularity (single tokens) — the same idea the paper
applies to datasets.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import Params, init_mlp, mlp


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    d, e = cfg.d_model, cfg.moe_experts
    vs = cfg.moe_virtual_split
    ev, fv = e * vs, cfg.moe_d_ff // vs
    assert cfg.moe_d_ff % vs == 0, (cfg.moe_d_ff, vs)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p: Params = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) / np.sqrt(d),
        "experts_gate": jax.random.normal(k2, (ev, d, fv), jnp.float32) / np.sqrt(d),
        "experts_up": jax.random.normal(k3, (ev, d, fv), jnp.float32) / np.sqrt(d),
        "experts_down": jax.random.normal(k4, (ev, fv, d), jnp.float32)
        / np.sqrt(cfg.moe_d_ff),
    }
    if cfg.moe_shared_experts:
        # shared experts fused into one dense MLP of width s·F
        p["shared"] = init_mlp(k5, cfg, cfg.moe_shared_experts * cfg.moe_d_ff)
    return p


def _route(p: Params, cfg: ModelConfig, xt: jax.Array):
    """Router logits → renormalized top-k gates.  xt: (..., T, D)."""
    dt = xt.dtype
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)
    gates, expert_idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe_top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    return gates.astype(dt), expert_idx


# ---------------------------------------------------------------------------
# onehot path (GSPMD-partitionable; capacity-bucketed; virtual splitting)
# ---------------------------------------------------------------------------


def _moe_onehot(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    b, l, d = x.shape
    e, k, vs = cfg.moe_experts, cfg.moe_top_k, cfg.moe_virtual_split
    ev = e * vs
    t = b * l
    g = min(cfg.moe_group, t)
    while t % g:  # groups must tile the token axis exactly
        g //= 2
    n = t // g
    cap = max(int(math.ceil(g * k / e * cfg.moe_capacity_factor)), 1)
    cap = min(cap, g)  # an expert can never hold more than the whole group

    xg = x.reshape(n, g, d)
    xg = shard(xg, "batch", None, "embed")
    gates, idx = _route(p, cfg, xg)                       # (n,g,k) ×2

    # -- virtual expansion: choice (i, j) = split j of real choice i --------
    if vs > 1:
        idx = (idx[..., None] * vs + jnp.arange(vs)).reshape(n, g, k * vs)
        gates = jnp.repeat(gates, vs, axis=-1)            # same gate per slice
        k = k * vs

    # -- choice-priority positions within each expert's capacity buffer ----
    m = jax.nn.one_hot(idx, ev, dtype=jnp.int32)          # (n,g,k,ev)
    mt = m.transpose(0, 2, 1, 3).reshape(n, k * g, ev)    # choice-major
    pos = jnp.cumsum(mt, axis=1) - mt                     # 0-based slots
    pos = pos.reshape(n, k, g, ev).transpose(0, 2, 1, 3)  # (n,g,k,ev)
    pos_of = jnp.sum(pos * m, axis=-1)                    # (n,g,k)
    keep = (pos_of < cap).astype(dt)                      # capacity drop mask

    oh_e = m.astype(dt)                                   # (n,g,k,ev)
    oh_c = jax.nn.one_hot(pos_of, cap, dtype=dt)          # (n,g,k,cap)
    disp = jnp.einsum("ngke,ngkc->ngec", oh_e, oh_c * keep[..., None])
    comb = jnp.einsum("ngke,ngkc->ngec", oh_e, oh_c * (gates * keep)[..., None])
    disp = shard(disp, "batch", None, "expert", None)
    comb = shard(comb, "batch", None, "expert", None)

    # -- expert compute (expert dim shards over "model") --------------------
    xin = jnp.einsum("ngec,ngd->necd", disp, xg)          # (n,ev,cap,d)
    xin = shard(xin, "batch", "expert", None, None)
    h = jnp.einsum("necd,edf->necf", xin, p["experts_gate"].astype(dt))
    u = jnp.einsum("necd,edf->necf", xin, p["experts_up"].astype(dt))
    y = jnp.einsum("necf,efd->necd", jax.nn.silu(h) * u,
                   p["experts_down"].astype(dt))
    y = shard(y, "batch", "expert", None, None)

    out = jnp.einsum("ngec,necd->ngd", comb, y)           # gate-weighted return
    return out.reshape(b, l, d)


# ---------------------------------------------------------------------------
# ragged path (dropless single-device reference)
# ---------------------------------------------------------------------------


def _moe_ragged(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    assert cfg.moe_virtual_split == 1, (
        "ragged dispatch is the vs=1 reference; use onehot for virtual splits"
    )
    dt = x.dtype
    b, l, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(b * l, d)
    t = xt.shape[0]

    gates, expert_idx = _route(p, cfg, xt)                # (T,k) ×2

    # ---- sort-based dropless dispatch (MegaBlocks-style) -----------------
    flat_expert = expert_idx.reshape(-1)                  # (T·k,)
    order = jnp.argsort(flat_expert)                      # stable
    token_of = order // k                                 # source token id
    xs = jnp.take(xt, token_of, axis=0)                   # (T·k, D) grouped
    group_sizes = jnp.bincount(flat_expert, length=e)

    h = jax.lax.ragged_dot(xs, p["experts_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, p["experts_up"].astype(dt), group_sizes)
    h = jax.nn.silu(h) * u                                # (T·k, F)
    y = jax.lax.ragged_dot(h, p["experts_down"].astype(dt), group_sizes)

    # ---- unsort + gate-weighted combine -----------------------------------
    gate_of = jnp.take(gates.reshape(-1), order)          # (T·k,)
    y = y * gate_of[:, None]
    out = jnp.zeros((t, d), dt).at[token_of].add(y)
    return out.reshape(b, l, d)


def moe_mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x (B, L, D) → (B, L, D).  Top-k routed experts + shared experts."""
    if cfg.moe_impl == "onehot":
        out = _moe_onehot(p, cfg, x)
    elif cfg.moe_impl == "ragged":
        out = _moe_ragged(p, cfg, x)
    else:  # pragma: no cover
        raise ValueError(cfg.moe_impl)

    if "shared" in p:
        out = out + mlp(p["shared"], x)  # shared experts: dense path (B,L,D)

    return shard(out, "batch", "seq_res", "embed")
