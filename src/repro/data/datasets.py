"""Synthetic datasets: deterministic, seekable token streams.

The "corpus" is a counter-based PRNG over (seed, document_id) — any document
is reconstructible from its id alone, so the pipeline can resume after a
restart by remembering a single cursor (no data server, no epochs of state).
A Zipf-ish marginal over the vocab plus a short induction pattern makes the
loss *learnable* (a model that trains shows loss < ln(V) quickly), which the
end-to-end tests rely on.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTextDataset:
    vocab_size: int
    seq_len: int
    seed: int = 0

    def document(self, doc_id: int) -> np.ndarray:
        """Deterministic (seq_len,) int32 token sequence for ``doc_id``."""
        rng = np.random.default_rng((self.seed << 32) ^ (doc_id & 0xFFFFFFFF))
        v = self.vocab_size
        # Zipf-ish marginal
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=self.seq_len, p=probs).astype(np.int32)
        # induction pattern: token t repeated after a fixed lag — learnable
        lag = 1 + (doc_id % 7)
        idx = np.arange(lag, self.seq_len, 2 * lag)
        toks[idx] = toks[idx - lag]
        return toks

    def batch(self, doc_ids: np.ndarray) -> dict[str, np.ndarray]:
        toks = np.stack([self.document(int(i)) for i in doc_ids])
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}


def synthetic_lm_batch(
    vocab_size: int, batch: int, seq_len: int, *, seed: int = 0, step: int = 0
) -> dict[str, np.ndarray]:
    """One deterministic batch (convenience for examples/benchmarks)."""
    ds = SyntheticTextDataset(vocab_size, seq_len + 1, seed)
    ids = np.arange(step * batch, (step + 1) * batch, dtype=np.int64)
    return ds.batch(ids)
