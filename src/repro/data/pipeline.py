"""Blocked batch pipeline — the SplIter's L2 substrate (DESIGN.md §2).

The global batch is produced as a *blocked collection*: ``num_blocks``
microbatch blocks per optimizer step, stacked ``(nb, mb, seq)``.  Placement
on the mesh follows the data-parallel sharding, so each DP shard's local
blocks form exactly one SplIter partition; the fused train step scans them
(``repro.optim.grad_accum``).

The pipeline is deterministic and *resumable*: :class:`PipelineState` is a
single cursor (step) checkpointed alongside the model, and documents are
counter-indexed (see datasets.py), so a restarted run replays bit-identical
batches — the checkpoint/restart integration test depends on this.

Background prefetch (one thread, bounded queue) overlaps host batch
assembly with device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.data.datasets import SyntheticTextDataset


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_json(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_json(cls, d: dict) -> "PipelineState":
        return cls(step=int(d["step"]))


class BlockedBatchPipeline:
    """Yields blocked batches {tokens,labels}: (num_blocks, mb, seq) int32."""

    def __init__(
        self,
        *,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        num_blocks: int,
        seed: int = 0,
        state: PipelineState | None = None,
        prefetch: int = 2,
    ):
        assert global_batch % num_blocks == 0, (global_batch, num_blocks)
        self.ds = SyntheticTextDataset(vocab_size, seq_len + 1, seed)
        self.global_batch = global_batch
        self.num_blocks = num_blocks
        self.mb = global_batch // num_blocks
        self.seq_len = seq_len
        self.state = state or PipelineState()
        self._prefetch = prefetch
        self._q: queue.Queue | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ---- deterministic batch assembly ------------------------------------

    def _assemble(self, step: int) -> dict[str, np.ndarray]:
        base = step * self.global_batch
        ids = np.arange(base, base + self.global_batch, dtype=np.int64)
        flat = self.ds.batch(ids)
        return {
            k: v.reshape(self.num_blocks, self.mb, self.seq_len)
            for k, v in flat.items()
        }

    def peek(self, step: int) -> dict[str, np.ndarray]:
        """Batch for an arbitrary step (no state change) — resume testing."""
        return self._assemble(step)

    # ---- prefetching iterator ---------------------------------------------

    def _worker(self, start_step: int, q: queue.Queue, stop: threading.Event):
        # q/stop are passed in (not read off self) so a superseded worker
        # keeps draining against ITS queue/event and can never be revived
        # by a later re-iteration swapping the attributes underneath it.
        s = start_step
        while not stop.is_set():
            item = (s, self._assemble(s))
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        # Re-iterating must not leak the previous prefetch worker: stop and
        # join it first, then start a fresh worker bound to a fresh
        # queue/event pair at the current cursor.
        self.close()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=self._prefetch)
        self._thread = threading.Thread(
            target=self._worker,
            args=(self.state.step, self._q, self._stop),
            daemon=True,
        )
        self._thread.start()
        # Bind this iteration's queue/event locally: a superseded iterator
        # must drain its own buffer and stop — never steal from (or advance
        # the cursor of) a newer iteration that rebound the attributes.
        q, stop = self._q, self._stop
        while True:
            try:
                step, batch = q.get(timeout=0.1)
            except queue.Empty:
                if stop.is_set():
                    return
                continue
            if not stop.is_set():
                self.state.step = step + 1
            yield batch

    def close(self):
        """Stop the prefetch worker.  Idempotent; safe with no worker."""
        self._stop.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=2.0)
