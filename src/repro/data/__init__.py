"""Data substrate: deterministic blocked token pipeline with resumable state."""

from repro.data.pipeline import BlockedBatchPipeline, PipelineState
from repro.data.datasets import synthetic_lm_batch, SyntheticTextDataset

__all__ = [
    "BlockedBatchPipeline",
    "PipelineState",
    "synthetic_lm_batch",
    "SyntheticTextDataset",
]
