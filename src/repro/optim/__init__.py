"""Optimizer substrate: AdamW, LR schedules, SplIter-fused accumulation,
gradient compression."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.optim.grad_accum import accumulate_gradients

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "accumulate_gradients",
]
