"""AdamW with decoupled weight decay and global-norm clipping.

Pure-pytree implementation (no optax dependency): state = (step, m, v).
Moments are fp32 regardless of param dtype; the update is computed in fp32
and cast back.  Built to be sharded identically to the params (the state
trees inherit the param PartitionSpecs — ZeRO-style sharded moments are a
rules variant, see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array      # () int32
    m: Any               # pytree like params, fp32
    v: Any               # pytree like params, fp32


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> tuple[Any, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    # explicit flatten (param trees contain tuples — no is_leaf tricks)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([r[0] for r in res])
    new_m = treedef.unflatten([r[1] for r in res])
    new_v = treedef.unflatten([r[2] for r in res])
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
