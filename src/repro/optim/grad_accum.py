"""Gradient accumulation = the SplIter applied to the training batch (L2).

The global batch arrives as a *blocked collection* of microbatches.  The
paper's three execution modes map exactly:

``per_block`` (baseline, paper Listing 4)
    one jitted dispatch per microbatch-block; the host accumulates — N
    dispatches + N host syncs per optimizer step.

``spliter`` (paper Listing 5)
    ONE dispatch per optimizer step: ``lax.scan`` over the local blocks
    carrying the gradient accumulator — the partition-local first
    reduction.  Cross-shard reduction happens once, after the scan (GSPMD
    turns it into the DP all-reduce).  Zero data movement, zero extra
    memory beyond one microbatch's activations.

``materialized`` (paper §7 / rechunk-equivalent on-device)
    concatenate the local blocks into one giant microbatch and take one
    unblocked forward/backward — fastest per-FLOP when activations fit
    (compute-bound analogue of the paper's Cascade SVM finding), at the
    cost of scan-factor× more activation memory.

All three produce identical gradients up to float reassociation
(hypothesis-tested).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

LossFn = Callable[[Any, dict[str, jax.Array]], jax.Array]


def hoist_params_bf16(params: Any, constraint: Callable[[Any], Any] | None) -> Any:
    """FSDP gather hoisting (§Perf beyond-paper optimization).

    Under ZeRO/FSDP sharding, every block of the accumulation scan re-gathers
    the fp32 weights (GSPMD places the all-gather inside the loop body).
    Casting the matmul weights to bf16 ONCE and constraining them to the
    TP-only layout (fsdp axis dropped) hoists a single half-width gather out
    of the scan: nb× fewer gathers at half the bytes.  Scalars/vectors
    (norm weights, biases) stay fp32 and replicated — the model's own
    ``astype(cfg.dtype)`` call sites become no-ops for the casted leaves.
    The gradient path is unchanged: grads accumulate in fp32 and GSPMD
    re-scatters at the optimizer update.
    """
    casted = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if (hasattr(p, "ndim") and p.ndim >= 2 and
            jnp.issubdtype(p.dtype, jnp.floating))
        else p,
        params,
    )
    return constraint(casted) if constraint is not None else casted


def accumulate_gradients(
    loss_fn: LossFn,
    params: Any,
    blocks: dict[str, jax.Array],   # leaves (nblocks, mb, ...) — stacked blocks
    *,
    mode: str = "spliter",
    hoist: bool = False,
    hoist_constraint: Callable[[Any], Any] | None = None,
) -> tuple[jax.Array, Any]:
    """Mean loss + mean gradients over the blocked batch.

    ``hoist=True`` applies :func:`hoist_params_bf16` before the loop and
    differentiates through the cast (bf16 cotangents are accumulated into
    the fp32 gradient carry).
    """
    nb = jax.tree.leaves(blocks)[0].shape[0]

    # FSDP gather hoisting: cast+gather ONCE outside the block loop and
    # differentiate w.r.t. the casted tree; cotangents convert back to the
    # fp32 carry.  d cast(p)/dp is identity up to rounding, so the update
    # math is unchanged (standard mixed precision with fp32 master weights).
    work = hoist_params_bf16(params, hoist_constraint) if hoist else params
    vg = jax.value_and_grad(loss_fn)

    if mode == "materialized":
        merged = jax.tree.map(
            lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]), blocks
        )
        loss, g = vg(work, merged)
        return loss, jax.tree.map(lambda gg: gg.astype(jnp.float32), g)

    if mode == "per_block":
        # Baseline: caller dispatches this once per block (see Trainer);
        # here we provide the single-block step for it.
        raise ValueError(
            "per_block accumulation is driven by the Trainer loop; "
            "use trainer.train_step_per_block"
        )

    if mode == "spliter_unrolled":
        # Same math as "spliter" with a Python loop instead of lax.scan —
        # used by the roofline probes, whose cost_analysis would count a
        # scan body once and hide per-block collectives (DESIGN.md §6).
        loss_sum = jnp.zeros((), jnp.float32)
        grad_sum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        for i in range(nb):
            mb = jax.tree.map(lambda x: x[i], blocks)
            loss, g = vg(work, mb)
            loss_sum = loss_sum + loss
            grad_sum = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32), grad_sum, g
            )
        inv = 1.0 / nb
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    assert mode == "spliter", mode

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, g = vg(work, mb)
        grad_acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), grad_acc, g)
        return (loss_acc + loss, grad_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), blocks
    )
    inv = 1.0 / nb
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)
