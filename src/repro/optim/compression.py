"""Gradient compression for slow (cross-pod) links, with error feedback.

Two codecs, both shape/dtype-preserving round trips:

* :func:`int8_compress` / :func:`int8_decompress` — per-chunk symmetric
  int8 quantization (chunk = trailing-dim rows, one fp32 scale per chunk):
  4× over fp32, 2× over bf16.
* :func:`topk_compress` / :func:`topk_decompress` — magnitude top-k
  sparsification (values + int32 indices).

:class:`ErrorFeedback` carries the quantization residual into the next
step (Seide et al. / EF-SGD), which keeps SGD/Adam convergence unbiased —
verified by the convergence test in tests/test_optim.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (..., d) → (q int8 (..., d), scale fp32 (..., 1))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def topk_compress(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """flat top-k by magnitude → (values (k,), indices int32 (k,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_decompress(values: jax.Array, idx: jax.Array, shape, dtype=jnp.float32):
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    return flat.at[idx].set(values).reshape(shape).astype(dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedback:
    residual: Any  # pytree like grads, fp32

    @classmethod
    def init(cls, grads: Any) -> "ErrorFeedback":
        return cls(jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compress_with_feedback(
    grads: Any, ef: ErrorFeedback
) -> tuple[Any, ErrorFeedback]:
    """int8-round-trip the gradients, carrying the residual forward.

    Models the cross-pod hop: what a remote pod would receive is the
    decompressed value; the local residual is replayed next step.
    """

    def one(g, r):
        target = g.astype(jnp.float32) + r
        if g.ndim == 0:
            return g, jnp.zeros_like(r)
        q, s = int8_compress(target)
        back = int8_decompress(q, s)
        return back.astype(g.dtype), target - back

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    res = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([t[0] for t in res])
    new_r = treedef.unflatten([t[1] for t in res])
    return new_g, ErrorFeedback(new_r)
