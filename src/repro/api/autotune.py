"""Cost-model autotuner — the *model → retune* half of adaptive granularity.

``SplIter(partitions_per_location="auto")`` removes the last hand-picked
granularity knob: instead of the user guessing how many partitions per
location fit the computing environment (the very tuning problem the paper
set out to remove for block sizes), the executor measures each iteration,
fits a granularity cost model, and proposes the ``partitions_per_location``
(*ppl*) for the next iteration.  Retuning is *logical regrouping only*:
the executors' prepare cache re-derives partition groups from the
already-split blocks (see ``repro.api.executors``), so a retune moves zero
bytes — the paper's "no transfers nor data rearrangement" claim extends to
granularity changes.

The model is the Tiny-Tasks granularity trade-off (Bora et al.,
arXiv:2202.11464) specialized to this runtime: per-iteration wall time

    w(p) ≈ c0 + c1 · n_tasks(p) + c2 · span(p)

where ``n_tasks(p) = Σ_loc min(p, blocks_loc)`` is the dispatch count
(each task pays a fixed host overhead → ``c1`` ≈ the per-task overhead
``o``), and ``span(p) = max_loc ceil(blocks_loc / p)`` is the largest
per-task block count (the straggler / pipeline-depth term: fewer, bigger
tasks stack more blocks per dispatch and serialize more compute behind one
launch).  ``c0`` absorbs the granularity-independent compute floor.

The tuning *schedule* is deterministic and seedable (Worksharing-Tasks
style: the runtime adapts, the program does not):

1. **probe** — execute the first iterations at a fixed ladder of candidate
   ppls (powers of two up to the largest per-location block count, at most
   ``probe_limit`` entries, rotation chosen by ``seed``), one iteration
   each;
2. **fit** — least-squares fit of (c0, c1, c2) on the probed samples
   (fewer than 3 distinct samples: fall back to the measured argmin);
3. **retune** — propose the predicted-argmin ppl over the *full* ladder
   (the model extrapolates to granularities never probed).

After probing, the model keeps **refitting** as steady-state evidence
arrives: a granularity's first visit recompiles (its probe wall includes
jit tracing), and revisits supersede those polluted samples, so the
incumbent's sample self-corrects.  A move away from the incumbent needs a
clear predicted win (``hysteresis``, default 5%) — noise must not bounce
the granularity around.  A *retune* is a proposal change between
consecutive iterations; at most ``max_retunes`` (default 3) ever happen —
the budget's exhaustion freezes the schedule — so convergence is
structural, not statistical.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "CostModel",
    "fit_cost_model",
    "granularity_features",
    "steal_cost_estimate",
    "should_steal",
    "fold_cost_estimate",
    "should_fold_remote",
    "Autotuner",
]


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def granularity_features(block_counts: Sequence[int], ppl: int) -> tuple[int, int]:
    """``(n_tasks, span)`` a ppl would produce over per-location block counts.

    Mirrors the prepare/lowering pipeline: each location with ``b`` blocks
    contributes ``min(ppl, b)`` partitions, the largest of which holds
    ``ceil(b / min(ppl, b))`` blocks.  Ragged same-shape runs can add a few
    extra dispatches on top of ``n_tasks``; the model treats those as noise.
    """
    n_tasks = 0
    span = 0
    for b in block_counts:
        if b <= 0:
            continue
        k = min(ppl, b)
        n_tasks += k
        span = max(span, math.ceil(b / k))
    return n_tasks, span


@dataclasses.dataclass(frozen=True)
class CostModel:
    """ŵ(p) = c0 + c1·n_tasks(p) + c2·span(p)  (seconds)."""

    c0: float
    c1: float  # per-task (dispatch) overhead
    c2: float  # per-span (task size / straggler) cost

    def predict(self, n_tasks: int, span: int) -> float:
        return self.c0 + self.c1 * n_tasks + self.c2 * span


def fit_cost_model(
    samples: Sequence[tuple[int, int, float]],
    *,
    overhead_hint_s: float = 0.0,
) -> CostModel | None:
    """Least-squares fit of :class:`CostModel` on ``(n_tasks, span, wall_s)``.

    Needs ≥3 samples with ≥2 distinct ``n_tasks`` values; otherwise returns
    a degenerate model built from ``overhead_hint_s`` (the profiled mean
    per-task dispatch overhead) when available, else ``None``.  Negative
    fitted coefficients are clamped to 0 — noise must not make the model
    predict that infinite tasks (or infinite spans) are free.
    """
    if len(samples) >= 3 and len({n for n, _, _ in samples}) >= 2:
        x = np.array([[1.0, n, s] for n, s, _ in samples], np.float64)
        y = np.array([w for _, _, w in samples], np.float64)
        coef, *_ = np.linalg.lstsq(x, y, rcond=None)
        c0, c1, c2 = (max(float(c), 0.0) for c in coef)
        return CostModel(c0=c0, c1=c1, c2=c2)
    if overhead_hint_s > 0.0 and samples:
        # One/two samples: anchor the compute floor at the best sample and
        # extrapolate with the measured dispatch overhead alone.
        n0, s0, w0 = min(samples, key=lambda t: t[2])
        return CostModel(c0=max(w0 - overhead_hint_s * n0, 0.0),
                         c1=overhead_hint_s, c2=0.0)
    return None


def steal_cost_estimate(
    model: CostModel | None,
    *,
    queued_tasks: int,
    span: int = 1,
    operand_bytes: int = 0,
    fallback_task_s: float = 1e-3,
    pipe_bytes_per_s: float = 256e6,
    victim_task_s: float | None = None,
    thief_task_s: float = 0.0,
) -> tuple[float, float]:
    """(expected_wait_s, fetch_cost_s) for stealing a victim's queued units.

    ``expected_wait_s`` is what the queued work would cost if left on the
    overloaded victim: ``queued_tasks`` × the victim's per-task cost.
    That cost is ``victim_task_s`` when the caller has observed it (the
    executor's per-worker service-time EMA — what actually distinguishes
    a straggler from a merely busy sibling), else the model's *marginal*
    per-task cost (``c1 + c2·span`` — the fixed ``c0`` is paid either
    way, so it cancels out of the comparison).  ``fetch_cost_s`` is what
    moving it costs: one extra dispatch (``c1``), operand transport, and
    the thief's own execution of the stolen units (``queued_tasks`` ×
    ``thief_task_s``) — charging the thief's service time is what stops a
    slow worker from stealing work *back* from a fast one.  With the
    shared-memory data plane a steal moves *descriptors*, not bytes —
    callers pass ``operand_bytes=0`` and transport is just the dispatch
    overhead; with shm off, the operands re-cross the pipe at
    ``pipe_bytes_per_s``.

    Without a fitted model (early iterations), ``fallback_task_s`` — the
    profiled mean task wall when the caller has one — stands in for the
    marginal cost, and the dispatch overhead is taken as free; an unknown
    thief defaults to free execution.  Both optimistic, which is the
    right bias while there is no evidence either way.

    >>> m = CostModel(c0=0.1, c1=0.01, c2=0.0)
    >>> steal_cost_estimate(m, queued_tasks=4)
    (0.04, 0.01)
    """
    if victim_task_s is not None:
        per_task = victim_task_s
        dispatch_s = model.c1 if model is not None else 0.0
    elif model is not None and (model.c1 > 0.0 or model.c2 > 0.0):
        per_task = model.c1 + model.c2 * max(span, 1)
        dispatch_s = model.c1
    else:
        per_task = fallback_task_s
        dispatch_s = 0.0
    wait_s = queued_tasks * per_task
    fetch_s = (
        dispatch_s
        + (operand_bytes / pipe_bytes_per_s if operand_bytes else 0.0)
        + queued_tasks * thief_task_s
    )
    return wait_s, fetch_s


def should_steal(
    model: CostModel | None,
    *,
    queued_tasks: int,
    span: int = 1,
    operand_bytes: int = 0,
    fallback_task_s: float = 1e-3,
    pipe_bytes_per_s: float = 256e6,
    victim_task_s: float | None = None,
    thief_task_s: float = 0.0,
) -> bool:
    """The steal gate: True iff remote-fetch cost < expected wait.

    The locality-awareness contract of the elastic cluster (DESIGN.md §15):
    an idle worker may take a queued unit from an overloaded sibling only
    when this predicts the move pays for itself.  Deterministic in its
    inputs, so tests can pin the decision with crafted models.

    >>> should_steal(CostModel(0.0, 0.001, 0.0), queued_tasks=3)
    True
    >>> should_steal(  # huge operands over a slow pipe: stay put
    ...     CostModel(0.0, 0.001, 0.0), queued_tasks=1,
    ...     operand_bytes=1 << 30, pipe_bytes_per_s=64e6)
    False
    >>> should_steal(  # a straggler must not steal back from a fast sibling
    ...     None, queued_tasks=3, victim_task_s=0.002, thief_task_s=0.05)
    False
    >>> should_steal(  # ...while the fast sibling raids the straggler
    ...     None, queued_tasks=3, victim_task_s=0.05, thief_task_s=0.002)
    True
    """
    if queued_tasks < 1:
        return False
    wait_s, fetch_s = steal_cost_estimate(
        model,
        queued_tasks=queued_tasks,
        span=span,
        operand_bytes=operand_bytes,
        fallback_task_s=fallback_task_s,
        pipe_bytes_per_s=pipe_bytes_per_s,
        victim_task_s=victim_task_s,
        thief_task_s=thief_task_s,
    )
    return fetch_s < wait_s


def fold_cost_estimate(
    model: CostModel | None,
    *,
    partial_bytes: int,
    fan_in: int,
    pipe_bytes_per_s: float = 256e6,
) -> tuple[float, float]:
    """(driver_fold_s, remote_fold_s) for one location's merge chain.

    ``driver_fold_s`` is what the pinned path costs the driver: ``fan_in``
    partials of ``partial_bytes`` each crossing the reply channel before
    the driver can fold them.  ``remote_fold_s`` is the peer-exchange
    alternative (DESIGN.md §16): the partials stay in shared memory where
    the workers wrote them, one extra fold dispatch (the model's per-task
    overhead ``c1``) runs worker-side, and exactly ONE merged partial
    crosses back.  Deterministic in its inputs, like
    :func:`steal_cost_estimate`, so tests pin decisions with crafted
    models.

    >>> fold_cost_estimate(CostModel(0.0, 0.01, 0.0), partial_bytes=256_000_000, fan_in=4)
    (4.0, 1.01)
    """
    pipe = max(float(pipe_bytes_per_s), 1.0)
    driver_s = fan_in * partial_bytes / pipe
    remote_s = (model.c1 if model is not None else 0.0) + partial_bytes / pipe
    return driver_s, remote_s


def should_fold_remote(
    model: CostModel | None,
    *,
    partial_bytes: int,
    fan_in: int,
    min_bytes: int = 1 << 16,
    pipe_bytes_per_s: float = 256e6,
) -> bool:
    """The peer-exchange gate: fold worker-side iff it beats the driver pipe.

    Tiny partials keep the old path — below ``min_bytes`` the fold is
    cheaper than the extra dispatch it would take to avoid it, whatever
    the model says (the Tiny-Tasks regime: overhead dominates).  With at
    least two partials per location and partials worth moving, the gate
    compares the driver-pipe cost of shipping every partial against one
    worker-side fold dispatch plus one merged reply.

    >>> should_fold_remote(None, partial_bytes=1 << 20, fan_in=4)
    True
    >>> should_fold_remote(None, partial_bytes=512, fan_in=4)  # tiny: old path
    False
    >>> should_fold_remote(  # dispatch overhead outweighs the pipe saving
    ...     CostModel(0.0, 1.0, 0.0), partial_bytes=1 << 20, fan_in=2)
    False
    """
    if fan_in < 2 or partial_bytes < min_bytes:
        return False
    driver_s, remote_s = fold_cost_estimate(
        model,
        partial_bytes=partial_bytes,
        fan_in=fan_in,
        pipe_bytes_per_s=pipe_bytes_per_s,
    )
    return remote_s < driver_s


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Sample:
    wall_s: float
    n_tasks: int
    span: int
    traced: bool  # first visit recompiles; superseded by steady-state visits


class Autotuner:
    """Deterministic measure → model → retune schedule for one workload.

    One instance per (inputs, task) pair, owned by the executor.  The
    executor calls :meth:`propose` before each execution and
    :meth:`observe` after it with the measured wall time; the tuner never
    changes its proposal more than ``max_retunes`` times.

    The schedule is deterministic — same block counts + seed, same probes:

    >>> from repro.api import Autotuner
    >>> tuner = Autotuner([8, 8], seed=0)   # two locations, 8 blocks each
    >>> tuner.ladder                         # candidate ppls
    [1, 2, 4, 8]
    >>> tuner.propose()                      # first probe
    1
    >>> tuner.observe(1, wall_s=0.5)
    >>> tuner.propose()                      # schedule advances to probe 2
    2
    >>> tuner.probing
    True
    """

    def __init__(
        self,
        block_counts: Sequence[int],
        *,
        seed: int = 0,
        max_retunes: int = 3,
        probe_limit: int = 3,
        hysteresis: float = 0.05,
    ):
        self.block_counts = tuple(int(b) for b in block_counts)
        self.max_blocks = max(self.block_counts, default=1)
        self.ladder = self._ladder(self.max_blocks)
        # Deterministic, seedable probe order: rotate the (short) probe
        # prefix of the ladder so different seeds visit it in a different
        # order but always visit the same set.
        probes = self.ladder[: max(1, min(probe_limit, len(self.ladder)))]
        r = seed % len(probes)
        self.probe_plan = probes[r:] + probes[:r]
        self.max_retunes = max_retunes
        self.hysteresis = hysteresis
        self.samples: dict[int, _Sample] = {}
        self.model: CostModel | None = None
        self.retunes = 0
        self.frozen = False        # retune budget exhausted: proposal is final
        self.last_ppl: int | None = None
        self._proposal = self.probe_plan[0]
        self.overhead_hint_s = 0.0

    @staticmethod
    def _ladder(max_blocks: int) -> list[int]:
        """Candidate ppls: powers of two up to the largest local block count."""
        out = []
        p = 1
        while p < max_blocks:
            out.append(p)
            p *= 2
        out.append(max_blocks)
        return sorted(set(out))

    # -- the schedule ---------------------------------------------------------

    def propose(self) -> int:
        """The ppl to use for the next execution."""
        return self._proposal

    def describe(self) -> dict:
        """JSON-able schedule summary (diagnostics / JobServer snapshots).

        Shared-asset pools snapshot this per tuner so an operator can see
        what granularity each (geometry, task, policy) workload converged
        to across tenants; it is informational — resume never replays
        tuner state (a resumed job's policy is pinned in its journal).
        """
        return {
            "proposal": self._proposal,
            "last_ppl": self.last_ppl,
            "retunes": self.retunes,
            "frozen": self.frozen,
            "probing": self.probing,
            "samples": {str(k): v.wall_s for k, v in self.samples.items()},
        }

    @property
    def probing(self) -> bool:
        """True while probe-ladder candidates remain unmeasured (the window
        during which executors enable per-unit profile synchronization).
        A frozen schedule is never probing — a retune budget exhausted
        mid-ladder must not pin the executors' sync window open forever."""
        return not self.frozen and any(
            p not in self.samples for p in self.probe_plan
        )

    def observe(
        self,
        ppl: int,
        wall_s: float,
        *,
        n_tasks: int | None = None,
        span: int | None = None,
        traced: bool = False,
        overhead_s: float | None = None,
    ) -> None:
        """Feed one measured execution back; may advance the schedule."""
        if overhead_s is not None and overhead_s > 0.0:
            self.overhead_hint_s = overhead_s
        fn, fs = granularity_features(self.block_counts, ppl)
        sample = _Sample(
            wall_s=wall_s,
            n_tasks=n_tasks if n_tasks is not None else fn,
            span=span if span is not None else fs,
            traced=traced,
        )
        prev = self.samples.get(ppl)
        # Untraced beats traced; within the same tracedness the LATEST
        # sample wins — keeping a historical minimum would pin the tuner to
        # a phantom-fast measurement that later honest revisits could never
        # correct upward.
        if prev is None or not (sample.traced and not prev.traced):
            self.samples[ppl] = sample
        self.last_ppl = ppl
        if not self.frozen:
            self._advance()

    def _advance(self) -> None:
        for candidate in self.probe_plan:
            if candidate not in self.samples:
                self._retarget(candidate)
                return
        # Probing complete: (re)fit on everything observed so far —
        # steady-state revisits keep correcting trace-polluted probe
        # samples — and move to the predicted argmin only when it beats
        # the incumbent's prediction by the hysteresis margin.
        self.model = fit_cost_model(
            [(s.n_tasks, s.span, s.wall_s) for s in self.samples.values()],
            overhead_hint_s=self.overhead_hint_s,
        )
        best = self._argmin()
        if best == self._proposal:
            return
        if self.model is not None and self._proposal in self.samples:
            cur = self.model.predict(
                *granularity_features(self.block_counts, self._proposal)
            )
            cand = self.model.predict(
                *granularity_features(self.block_counts, best)
            )
            if cand > (1.0 - self.hysteresis) * cur:
                return  # not a clear enough win to spend a retune on
        self._retarget(best)

    def _argmin(self) -> int:
        if self.model is not None:
            scored = [
                (self.model.predict(*granularity_features(self.block_counts, p)), p)
                for p in self.ladder
            ]
        else:  # no model fit possible: measured argmin over the probes
            scored = [(s.wall_s, p) for p, s in self.samples.items()]
        # Deterministic tie-break: lowest predicted wall, then smallest ppl
        # (fewer tasks = less dispatch pressure at equal predicted cost).
        return min(scored)[1]

    def _retarget(self, ppl: int) -> None:
        if ppl != self._proposal and self.retunes >= self.max_retunes:
            self.frozen = True
            return
        if ppl != self._proposal:
            self.retunes += 1
        self._proposal = ppl
