"""Picklable function references — how remote workers rehydrate task code.

A distributed backend cannot ship the closures a :class:`~repro.api.lowering.TaskGraph`
holds: task ``fn``s are lambdas, ``functools.partial`` wrappers and
generated scan bodies, none of which the stdlib pickler accepts.  Following
the DuctTeip observation that distributed task dispatch lives or dies on
*cheap task descriptors*, this module turns a callable into a small
picklable *reference* that a worker process resolves back into the same
function:

``("import", module, qualname)``
    A module-level function: the worker imports ``module`` and walks
    ``qualname``.  The cheapest and preferred form — nothing but two
    strings crosses the wire.
``("partial", inner, args, kwargs)``
    A ``functools.partial`` over an encodable base with picklable statics
    (e.g. ``partial(histogramdd_block, bins=8, lo=0.0, hi=1.0)``).
``("code", module, code_bytes, name, defaults, closure)``
    The fallback for lambdas and closures: the marshalled code object plus
    pickled defaults and closure cell *values*.  The worker rebuilds the
    function against the defining module's ``__dict__`` (so globals like
    ``jnp`` resolve) with fresh cells.  Only meaningful between processes
    running the same interpreter on the same host — exactly the
    ClusterExecutor deployment model.

:func:`encode_fn` returns ``None`` when a callable cannot be referenced
(unpicklable cell values, no code object, ...); callers treat that as
"not remotable" and fall back to in-process execution.  References are
hashable, so workers key their jit caches on them directly.
"""

from __future__ import annotations

import functools
import importlib
import marshal
import pickle
import sys
import types
from typing import Callable

__all__ = ["encode_fn", "decode_fn"]


def _pickled(value) -> bytes | None:
    try:
        return pickle.dumps(value)
    except Exception:  # unpicklable static / cell value
        return None


def _importable(fn: Callable) -> tuple[str, str] | None:
    """(module, qualname) when walking it resolves back to ``fn`` itself."""
    module = getattr(fn, "__module__", None)
    qualname = getattr(fn, "__qualname__", None)
    if not module or not qualname or "<" in qualname:
        return None
    obj = sys.modules.get(module)
    if obj is None:
        return None
    for part in qualname.split("."):
        obj = getattr(obj, part, None)
    if obj is not fn:
        return None
    return module, qualname


def encode_fn(fn: Callable) -> tuple | None:
    """A picklable, hashable reference to ``fn``, or None if not remotable."""
    if isinstance(fn, functools.partial):
        inner = encode_fn(fn.func)
        if inner is None:
            return None
        args = _pickled(fn.args)
        kwargs = _pickled(tuple(sorted(fn.keywords.items())))
        if args is None or kwargs is None:
            return None
        return ("partial", inner, args, kwargs)

    imp = _importable(fn)
    if imp is not None:
        return ("import", *imp)

    code = getattr(fn, "__code__", None)
    module = getattr(fn, "__module__", None)
    if code is None or module is None:
        return None
    try:
        cells = tuple(c.cell_contents for c in fn.__closure__ or ())
    except ValueError:  # empty cell (fn referenced before definition)
        return None
    defaults = _pickled((fn.__defaults__, fn.__kwdefaults__))
    closure = _pickled(cells)
    if defaults is None or closure is None:
        return None
    return (
        "code",
        module,
        marshal.dumps(code),
        getattr(fn, "__name__", "<fn>"),
        defaults,
        closure,
    )


def decode_fn(ref: tuple) -> Callable:
    """Resolve a reference produced by :func:`encode_fn` in this process."""
    kind = ref[0]
    if kind == "partial":
        _, inner, args, kwargs = ref
        return functools.partial(
            decode_fn(inner), *pickle.loads(args), **dict(pickle.loads(kwargs))
        )
    if kind == "import":
        _, module, qualname = ref
        obj = importlib.import_module(module)
        for part in qualname.split("."):
            obj = getattr(obj, part)
        return obj
    if kind == "code":
        _, module, code_bytes, name, defaults, closure = ref
        mod = importlib.import_module(module)
        code = marshal.loads(code_bytes)
        dflt, kwdflt = pickle.loads(defaults)
        cells = tuple(types.CellType(v) for v in pickle.loads(closure))
        fn = types.FunctionType(code, mod.__dict__, name, dflt, cells or None)
        if kwdflt:
            fn.__kwdefaults__ = dict(kwdflt)
        return fn
    raise ValueError(f"unknown fn reference kind {kind!r}")
