"""StreamExecutor — out-of-core scheduling with double-buffered prefetch.

The fourth backend of the execution layer (DESIGN.md §10): it drives the
same dependency-driven scheduler core as every other executor, but assumes
the inputs' blocks are :class:`~repro.api.chunkstore.ChunkRef` handles into
a budgeted :class:`~repro.api.chunkstore.DiskStore`, so a dataset larger
than the residency budget streams through memory one partition at a time.

The streaming discipline (hybrid task/dataflow iteration — Ramon-Cortes et
al., FGCS 2020: task-based iteration composed with streaming stages):

* units run **in plan order on the calling thread** (bit-identical results
  to :class:`~repro.api.executors.LocalExecutor` — same TaskGraph, same
  merge fold order, and ``.npy`` spill round-trips preserve every bit);
* while unit *k* computes, a background **prefetch thread** pins and loads
  unit *k+1*'s chunks (``prefetch_depth`` units ahead, default 1 — the
  double buffer), so the disk read of the next partition overlaps the
  compute of the current one and its ``get()``s are *prefetch hits*;
* when unit *k* completes, its pins drop and the store's LRU eviction
  spills it (first pass) or simply releases it (later passes) — peak
  residency is bounded by roughly the current + prefetched working set,
  never the dataset.

``EngineReport`` rows gain the streaming bill: ``bytes_loaded`` /
``bytes_spilled`` / ``prefetch_hits`` (window deltas of the input stores'
counters).

Ownership: the streaming executor treats the chunk stores of datasets it
executed as its scratch tier — :meth:`close` closes them (deleting
``DiskStore`` spill files) unless constructed with ``close_stores=False``.
In-memory inputs (plain arrays or :class:`InMemoryStore` refs) degrade
gracefully: no refs → nothing to prefetch → plain sequential execution.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any

from repro.api.chunkstore import chunk_stores
from repro.api.executors import (
    _LIVE_POOLS,
    _LocationWorker,
    _PlanExecutor,
    _SchedulerState,
    _Unit,
)
from repro.api.lowering import Capabilities
from repro.api.plan import ExecutionPlan
from repro.core.engine import TaskEngine

__all__ = ["StreamExecutor"]


class _PrefetchJob:
    """One lookahead request: pin + load a unit's chunk refs.

    ``run``/``release`` execute on the prefetch worker thread (the shared
    :class:`~repro.api.executors._LocationWorker` machinery — one queue,
    poison-pill stop, joined before XLA teardown); ``wait`` re-raises any
    load failure on the scheduling thread.
    """

    __slots__ = ("refs", "done", "error")

    def __init__(self, refs: tuple):
        self.refs = refs
        self.done = threading.Event()
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            # Group per store so one prefetch() call can batch I/O.
            by_store: dict[int, list] = {}
            for ref in self.refs:
                by_store.setdefault(id(ref.store), []).append(ref)
            for refs in by_store.values():
                refs[0].store.prefetch(refs)
        except BaseException as e:  # noqa: BLE001 — re-raised at wait()
            self.error = e
        finally:
            self.done.set()

    def wait(self) -> None:
        self.done.wait()
        if self.error is not None:
            raise self.error

    def release(self) -> None:
        for ref in self.refs:
            ref.store.unpin(ref)


class StreamExecutor(_PlanExecutor):
    """Sequential plan-order execution with background chunk prefetch.

    Args:
      engine: shared :class:`TaskEngine` (accounting + jit cache).
      prefetch_depth: how many units ahead the background thread loads
        (default 1 = double buffering: partition *k+1* loads while *k*
        computes).  ``0`` disables lookahead (loads happen inline at
        operand resolution — still correct, no overlap).
      close_stores: when True (default), :meth:`close` also closes every
        chunk store backing datasets this executor ran — the streaming
        scratch tier (spill files) lives and dies with the executor.
    """

    #: pipelined iteration (DESIGN.md §14): queued submissions drain in
    #: submit order on the driving thread, and the prefetch lookahead
    #: crosses the iteration boundary — the next execute's first
    #: partitions load while the current execute still computes.
    _pipelined = True

    def __init__(
        self,
        engine: TaskEngine | None = None,
        *,
        prefetch_depth: int = 1,
        close_stores: bool = True,
    ):
        super().__init__(engine)
        assert prefetch_depth >= 0, prefetch_depth
        self.prefetch_depth = prefetch_depth
        self._close_stores = close_stores
        self._seen_stores: dict[int, Any] = {}
        self._prefetcher: _LocationWorker | None = None
        # The shared atexit sweep (executors._close_live_pools) close()s us
        # if the user never does: the prefetch thread ran jax work, so it
        # must be joined before XLA runtime teardown.
        _LIVE_POOLS.add(self)

    @property
    def capabilities(self) -> Capabilities:
        return dataclasses.replace(
            super().capabilities, name=type(self).__name__, out_of_core=True
        )

    # -- the Executor entry point (records stores for close()) ---------------

    def execute(self, plan: ExecutionPlan):
        for store in chunk_stores(plan.spec.inputs):
            self._seen_stores.setdefault(id(store), store)
        return super().execute(plan)

    def execute_async(self, plan: ExecutionPlan):
        for store in chunk_stores(plan.spec.inputs):
            self._seen_stores.setdefault(id(store), store)
        return super().execute_async(plan)

    # -- streaming drain -------------------------------------------------------

    def _drain(self, state: _SchedulerState) -> None:
        """Plan-order consumption with a bounded prefetch pipeline."""
        pending: collections.deque[_Unit] = collections.deque(state.initial_ready())
        inflight: dict[int, _PrefetchJob] = {}
        self._drain_loop(state, pending, inflight)

    def _drain_loop(
        self,
        state: _SchedulerState,
        pending: "collections.deque[_Unit]",
        inflight: dict[int, _PrefetchJob],
        entry=None,
    ) -> None:
        """The plan-order unit loop, shared by the sync and pipelined paths.

        ``entry`` (a pipelined :class:`_PipelineEntry`) lets the lookahead
        cross the iteration boundary: when this entry's own queue has
        fewer than ``prefetch_depth`` units left, the top-up continues
        into the NEXT queued submission's launched units.
        """
        try:
            while pending and not state.errors:
                self._top_up(pending, inflight, entry)  # current unit's load
                unit = pending.popleft()
                job = inflight.pop(unit.index, None)
                # Lookahead NOW, before this unit computes: unit k+1's disk
                # read overlaps unit k's dispatch+compute (the double buffer).
                self._top_up(pending, inflight, entry)
                if job is not None:
                    try:
                        job.wait()  # chunks resident + pinned (the hit path)
                    except BaseException as e:  # noqa: BLE001
                        job.release()
                        state.fail(e)
                        return
                try:
                    # _run_unit pins again around dispatch (the shared
                    # resolve/release hooks), so dropping the prefetch pin
                    # after it returns is what ends this unit's residency.
                    # The release goes to the background thread: the last
                    # unpin triggers the finished partition's spill write,
                    # which must not serialize into the compute path.
                    newly = self._run_unit(unit, state)
                except BaseException:
                    if job is not None:
                        job.release()
                    raise
                else:
                    if job is not None:
                        # Release on the worker thread: the last unpin
                        # evicts the finished partition, and a first-pass
                        # eviction performs the spill write — I/O serializes
                        # with I/O while compute keeps running.
                        self._prefetch_worker().submit(job.release)
                pending.extend(sorted(newly, key=lambda u: u.index))
        finally:
            for job in inflight.values():  # error path: drop leftover pins
                job.done.wait()
                job.release()
            inflight.clear()
            if self._prefetcher is not None:
                # Drain queued releases (and their spill writes) before the
                # run reports: pin counts and store stats are settled when
                # execute() reads the window deltas.
                done = threading.Event()
                self._prefetcher.submit(done.set)
                done.wait()

    def _top_up(
        self,
        pending: "collections.deque[_Unit]",
        inflight: dict[int, _PrefetchJob],
        entry=None,
    ) -> None:
        """Keep the next ``prefetch_depth`` upcoming units' chunks loading.

        Upcoming means drain order: this queue first, then — pipelined —
        the next submission's launched units, each job filed against its
        owning entry so the later drain finds it.
        """
        if self.prefetch_depth <= 0:
            return
        lookahead: list[tuple[_Unit, dict]] = [(u, inflight) for u in pending]
        nxt = self._entry_after(entry) if entry is not None else None
        if nxt is not None and nxt.jobs is not None:
            lookahead.extend((u, nxt.jobs) for u in nxt.pending)
        for unit, jobs in lookahead[: self.prefetch_depth]:
            if unit.index in jobs:
                continue
            refs = tuple(r for t in unit.tasks for r in t.chunk_refs)
            if not refs:
                continue
            job = _PrefetchJob(refs)
            # Pin on THIS thread, before the load is queued: the chunks
            # must already be eviction-proof while earlier units' releases
            # shrink the store.
            for ref in refs:
                ref.store.pin(ref)
            self._prefetch_worker().submit(job.run)
            jobs[unit.index] = job

    # -- pipelined execution (DESIGN.md §14) -----------------------------------

    def _entry_after(self, entry):
        """The next undrained submission after ``entry``, if any."""
        take = False
        for e in self._pipeline:
            if take and not e.draining:
                return e
            if e is entry:
                take = True
        return None

    def _start_entry(self, entry, prev) -> None:
        """Queue a pipelined submission; nothing computes until driven.

        Launched units accumulate in the entry's own pending deque (gate
        callbacks fire on this same thread, inside the previous entry's
        ``state.complete``), so when its turn comes the drain consumes
        them in plan order — bit-identical to the synchronous path.
        """
        entry.pending = collections.deque()
        entry.jobs = {}

        def launch(unit, entry=entry):
            if not entry.state.errors:
                entry.pending.append(unit)

        self._gate_units(entry, prev, launch)

    def _drive_raw(self, entry) -> None:
        """Drain queued submissions in submit order, up through ``entry``."""
        for e in list(self._pipeline):
            if not e.draining:
                self._drain_entry(e)
            if e is entry:
                break
        if not entry.draining and not entry.state.done.is_set():
            self._drain_entry(entry)  # already popped from the queue
        if not entry.state.done.is_set():
            entry.state.fail(
                RuntimeError(
                    f"stream drain stalled: execute #{entry.iteration} has "
                    "no runnable units left"
                )
            )

    def _drain_entry(self, entry) -> None:
        if entry.draining:
            return
        entry.draining = True
        # Window-based I/O accounting: this entry's streaming starts NOW —
        # re-mark so earlier entries' drain I/O stays out of its report.
        entry.mark_stores()
        state = entry.state
        if state.done.is_set():
            # Poisoned upstream (or already failed): nothing will run, but
            # cross-boundary prefetch may have pinned chunks for it.
            for job in entry.jobs.values():
                job.done.wait()
                job.release()
            entry.jobs.clear()
            return
        self._drain_loop(state, entry.pending, entry.jobs, entry)

    def _prefetch_worker(self) -> _LocationWorker:
        if self._prefetcher is None:
            self._prefetcher = _LocationWorker("repro-prefetch")
        return self._prefetcher

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        """Stop the prefetch thread; close (or trim) the streamed stores.

        With ``close_stores=True`` every :class:`DiskStore` this executor
        streamed is closed — its spill directory is deleted, so a
        StreamExecutor leaves no temp files behind.  With
        ``close_stores=False`` stores are only trimmed (resident chunks
        shed, spill files kept) and remain usable by other executors.

        Idempotent: the seen-store set is consumed by the first call, and
        a store that is already closed (by an earlier close, or by its
        owner) is never re-entered — calling ``close()`` again is a clean
        no-op, and the executor remains usable (the prefetch thread
        respawns on next use).
        """
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None
        stores = list(self._seen_stores.values())
        self._seen_stores.clear()
        super().close()
        for store in stores:
            if getattr(store, "closed", False):
                continue  # already torn down; re-entering close would be a bug
            if self._close_stores:
                store.close()
            else:
                store.trim()
