"""Shared-memory data plane — zero-copy block transport for the cluster backend.

The control channel of :class:`~repro.api.cluster_executor.ClusterExecutor`
is a ~64KB OS pipe: every operand and partial that crosses it as pickled
bytes is billed to ``EngineReport.ipc_bytes`` and paid twice (serialize +
copy).  DuctTeip's split — *tiny task descriptors on the control channel,
out-of-band data movement for blocks* — is reproduced here with POSIX
shared memory (``multiprocessing.shared_memory``, ``/dev/shm`` on Linux):

:class:`ShmBlockRef`
    A picklable ``(segment, offset, shape, dtype)`` descriptor.  The parent
    writes a block into a segment once; what crosses the pipe is this
    ~100-byte handle, and the worker resolves it against a read-only
    attachment of the same segment — the block bytes are never copied
    through the pipe in either direction.
:class:`ShmStore`
    The driver-side arena allocator: bump-allocates exported blocks into
    fixed-size segments under a byte budget, caches exports by object
    identity (an iterative app re-dispatching the same blocks pays ONE
    copy total), and evicts least-recently-used unpinned segments when the
    budget fills — callers fall back to the pickled/spill-file path when
    ``export`` returns ``None``.  Also a full
    :class:`~repro.api.chunkstore.ChunkStore`, so ``BlockedArray.to_store``
    can target shared memory directly.
:class:`ShmAttachments`
    The reader-side cache (workers, and the parent consuming worker
    partials): attaches segments by name, exposes zero-copy read-only
    ``np.ndarray`` views.
:func:`pack_tree` / :func:`unpack_tree`
    Reply-payload transport: a worker packs every large ndarray leaf of a
    result tree into ONE fresh segment and ships descriptors; the parent
    copies the leaves out and unlinks the segment — a strict per-reply
    lifecycle with no refcounting across messages.

Cleanup contract (the part POSIX makes hard): lifecycle is explicit — the
DRIVER owns every unlink: on :meth:`ShmStore.close`, on consuming or
discarding a reply, and by prefix sweep (:func:`sweep_segments`) when a
worker dies with undelivered replies.  ``resource_tracker`` bookkeeping
balances itself: the whole spawn tree shares ONE tracker whose cache is a
name set, ``SharedMemory`` registers on create and attach alike
(idempotent set-add), and ``unlink()`` unregisters exactly once — so a
normal run leaves the tracker cache empty (no exit-time leak warnings),
while an abnormal driver exit lets the tracker reap whatever our sweeps
never reached.  Tests and the CI fault lane assert
:func:`leaked_segments` is empty afterwards.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from multiprocessing import shared_memory
from typing import Any, Callable, Iterable

import numpy as np

__all__ = [
    "ShmBlockRef",
    "ShmStore",
    "ShmAttachments",
    "SegmentLease",
    "shm_available",
    "pack_tree",
    "unpack_tree",
    "discard_tree",
    "tree_lease",
    "attach_tree",
    "unlink_segments",
    "sweep_segments",
    "leaked_segments",
]

#: every segment name this module creates starts with this; the CI fault
#: lane greps /dev/shm for it to assert leak-freedom.
SEGMENT_PREFIX = "rshm"

_ALIGN = 64  # offsets cache-line aligned; keeps resolved views aligned too

_seq_lock = threading.Lock()
_prefix_seq = 0


def _next_prefix() -> str:
    """A process-unique segment-name prefix: ``rshm<pid>x<n>``."""
    global _prefix_seq
    with _seq_lock:
        _prefix_seq += 1
        return f"{SEGMENT_PREFIX}{os.getpid()}x{_prefix_seq}"


def _aligned(n: int) -> int:
    return (int(n) + _ALIGN - 1) & ~(_ALIGN - 1)


def shm_available() -> bool:
    """Can this host create + attach POSIX shared memory segments?"""
    try:
        probe = shared_memory.SharedMemory(create=True, size=64)
    except (OSError, ValueError, FileNotFoundError):
        return False
    probe.close()
    probe.unlink()
    return True


@dataclasses.dataclass(frozen=True)
class ShmBlockRef:
    """A picklable descriptor of one block inside a shared-memory segment.

    What crosses the control channel instead of the block's bytes: the
    receiver attaches ``segment`` (cached per segment, not per block) and
    builds a zero-copy ``np.ndarray`` view at ``offset``.

    >>> import pickle
    >>> ref = ShmBlockRef("rshm1x1a0", 128, (4, 2), "<f4")
    >>> pickle.loads(pickle.dumps(ref)) == ref
    True
    >>> ref.nbytes
    32
    """

    segment: str
    offset: int
    shape: tuple
    dtype_str: str

    @property
    def nbytes(self) -> int:
        dt = np.dtype(self.dtype_str)
        return int(np.prod(self.shape)) * dt.itemsize if self.shape else dt.itemsize


class _Segment:
    """One arena segment: a SharedMemory plus bump cursor and guards."""

    __slots__ = ("name", "shm", "size", "cursor", "pins", "locks", "last_use", "keys")

    def __init__(self, name: str, shm: shared_memory.SharedMemory, size: int):
        self.name = name
        self.shm = shm
        self.size = size
        self.cursor = 0
        self.pins = 0        # in-flight dispatches referencing this segment
        self.locks = 0       # manifest entries: never evict while > 0
        self.last_use = 0
        self.keys: list = []  # export-cache keys allocated here (for eviction)


class ShmStore:
    """Driver-side shared-memory arena + :class:`ChunkStore` implementation.

    Args:
      budget_bytes: cap on total allocated segment bytes.  When a new
        export would exceed it, least-recently-used unpinned, unlocked
        segments are evicted (unlinked; their cached exports drop); if
        nothing is evictable, :meth:`export` returns ``None`` and the
        caller falls back to the pickle/spill path.
      segment_bytes: arena segment size; blocks larger than one segment
        get a dedicated segment of their own size.
      min_bytes: blocks smaller than this are not worth a segment round
        trip — :meth:`export` declines them (``put`` ignores the floor:
        a stored chunk must live somewhere).

    Export caching: keyed by ``id(obj)`` (with a keepalive reference so
    ids cannot be recycled under us) or an explicit ``key``.  An iterative
    workload dispatching the same blocks every iteration copies each block
    into shared memory exactly once; ``bytes_exported`` counts only
    genuine copies, which is what ``EngineReport.shm_bytes`` bills.
    """

    def __init__(
        self,
        *,
        budget_bytes: int = 256 << 20,
        segment_bytes: int = 4 << 20,
        min_bytes: int = 1024,
        prefix: str | None = None,
    ):
        self.budget_bytes = int(budget_bytes)
        self.segment_bytes = int(segment_bytes)
        self.min_bytes = int(min_bytes)
        self.prefix = prefix or _next_prefix()
        self.uid = f"shm-{os.getpid()}-{self.prefix}"
        self.bytes_exported = 0  # genuine copies into shared memory
        self.allocated_bytes = 0
        # ChunkStore accounting (imported lazily: chunkstore imports us)
        from repro.api.chunkstore import StoreStats

        self.stats = StoreStats()
        self._segments: OrderedDict[str, _Segment] = OrderedDict()
        self._open: _Segment | None = None  # current bump-allocation target
        self._exports: dict[Any, tuple[ShmBlockRef, _Segment]] = {}
        self._keepalive: dict[Any, Any] = {}
        self._chunks: dict[int, ShmBlockRef] = {}  # ChunkStore: cid -> ref
        self._next_cid = 0
        self._seg_seq = 0
        self._use_seq = 0
        self._lock = threading.RLock()

    # -- the export API (the cluster data plane) ------------------------------

    def export(
        self,
        obj,
        *,
        key: Any = None,
        min_bytes: int | None = None,
        lock: bool = False,
        materialize: Callable[[], np.ndarray] | None = None,
    ) -> tuple[ShmBlockRef | None, int]:
        """``obj`` as a shared block: ``(ref, bytes_copied)`` or ``(None, 0)``.

        ``bytes_copied`` is 0 on a cache hit — the block is already in a
        segment and only the descriptor ships again.  ``materialize``
        defers producing the bytes (e.g. resolving a chunk ref) until the
        size/budget checks pass.  ``lock=True`` marks the segment
        never-evictable (manifest entries, whose descriptors outlive any
        single dispatch).
        """
        key = key if key is not None else id(obj)
        floor = self.min_bytes if min_bytes is None else min_bytes
        size_hint = getattr(obj, "nbytes", None)
        if size_hint is not None and size_hint < floor:
            return None, 0
        with self._lock:
            hit = self._exports.get(key)
            if hit is not None:
                ref, seg = hit
                self._use_seq += 1
                seg.last_use = self._use_seq
                if lock:
                    seg.locks += 1
                return ref, 0
        arr = np.asarray(materialize() if materialize is not None else obj)
        if arr.nbytes < floor or arr.nbytes == 0:
            return None, 0
        arr = np.ascontiguousarray(arr)
        with self._lock:
            seg, offset = self._alloc(_aligned(arr.nbytes))
            if seg is None:
                return None, 0
            view = np.ndarray(arr.shape, arr.dtype, buffer=seg.shm.buf, offset=offset)
            view[...] = arr
            ref = ShmBlockRef(seg.name, offset, tuple(arr.shape), arr.dtype.str)
            self._exports[key] = (ref, seg)
            self._keepalive[key] = obj
            seg.keys.append(key)
            self._use_seq += 1
            seg.last_use = self._use_seq
            if lock:
                seg.locks += 1
            self.bytes_exported += arr.nbytes
            return ref, arr.nbytes

    def pin_refs(self, refs: Iterable[ShmBlockRef]) -> None:
        """Guard the refs' segments against eviction for an in-flight unit."""
        with self._lock:
            for name in {r.segment for r in refs}:
                seg = self._segments.get(name)
                if seg is not None:
                    seg.pins += 1

    def unpin_refs(self, refs: Iterable[ShmBlockRef]) -> None:
        with self._lock:
            for name in {r.segment for r in refs}:
                seg = self._segments.get(name)
                if seg is not None and seg.pins > 0:
                    seg.pins -= 1

    def live_segments(self) -> list[str]:
        with self._lock:
            return list(self._segments)

    def pinned_segments(self) -> dict[str, int]:
        """Segment name → live pin count (pins > 0 only).

        The audit hook for pin accounting across ownership changes: after
        a drain settles — steals, preemptions, deaths included — every
        dispatch-scoped pin must have been released exactly once, so this
        must be empty (``locks``, the manifest lifecycle guards, are a
        separate counter and do not show up here).
        """
        with self._lock:
            return {
                name: seg.pins
                for name, seg in self._segments.items()
                if seg.pins > 0
            }

    # -- allocation internals (lock held) -------------------------------------

    def _alloc(self, need: int) -> tuple[_Segment | None, int]:
        seg = self._open
        if seg is not None and seg.size - seg.cursor >= need:
            offset = seg.cursor
            seg.cursor += need
            return seg, offset
        size = max(self.segment_bytes, need)
        while self.allocated_bytes + size > self.budget_bytes:
            if not self._evict_one():
                return None, 0
        self._seg_seq += 1
        name = f"{self.prefix}a{self._seg_seq}"
        try:
            shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        except OSError:  # /dev/shm itself is full: decline, caller falls back
            return None, 0
        seg = _Segment(name, shm, size)
        self._segments[name] = seg
        self._open = seg
        self.allocated_bytes += size
        offset = seg.cursor
        seg.cursor += need
        return seg, offset

    def _evict_one(self) -> bool:
        """Unlink the LRU unpinned, unlocked, non-open segment.  False: none."""
        victims = sorted(
            (
                s
                for s in self._segments.values()
                if s.pins == 0 and s.locks == 0 and s is not self._open
            ),
            key=lambda s: s.last_use,
        )
        if not victims:
            return False
        self._drop_segment(victims[0])
        return True

    def _drop_segment(self, seg: _Segment) -> None:
        for key in seg.keys:
            self._exports.pop(key, None)
            self._keepalive.pop(key, None)
        self._segments.pop(seg.name, None)
        if self._open is seg:
            self._open = None
        self.allocated_bytes -= seg.size
        seg.shm.close()
        try:
            seg.shm.unlink()
        except FileNotFoundError:  # pragma: no cover — already swept
            pass

    # -- the ChunkStore contract ----------------------------------------------

    def put(self, array):
        """Store one chunk in shared memory; raises when the budget is out."""
        from repro.api.chunkstore import ChunkRef, ChunkStoreError

        arr = np.ascontiguousarray(np.asarray(array))
        with self._lock:
            cid = self._next_cid
            self._next_cid += 1
            ref, _wrote = self.export(arr, key=("chunk", cid), min_bytes=0, lock=True)
            if ref is None:
                raise ChunkStoreError(
                    f"ShmStore budget exhausted ({self.budget_bytes} bytes); "
                    f"cannot store a {arr.nbytes}-byte chunk"
                )
            self._chunks[cid] = ref
            self.stats.resident_bytes += arr.nbytes
            self.stats.peak_resident_bytes = max(
                self.stats.peak_resident_bytes, self.stats.resident_bytes
            )
        return ChunkRef(self, cid, arr.shape, arr.dtype)

    def get(self, ref):
        import jax.numpy as jnp

        from repro.api.chunkstore import ChunkStoreError

        with self._lock:
            blk = self._chunks.get(ref.chunk_id)
            if blk is None:
                raise ChunkStoreError(f"unknown or released chunk {ref.chunk_id}")
            seg = self._segments.get(blk.segment)
            if seg is None:  # pragma: no cover — put-chunks lock their segment
                raise ChunkStoreError(f"segment {blk.segment} gone for {ref.chunk_id}")
            view = np.ndarray(
                blk.shape, np.dtype(blk.dtype_str), buffer=seg.shm.buf, offset=blk.offset
            )
            return jnp.asarray(np.asarray(view))

    def handle(self, ref) -> ShmBlockRef | None:
        """The picklable descriptor for a stored chunk (the cluster payload)."""
        with self._lock:
            return self._chunks.get(ref.chunk_id)

    def pin(self, ref) -> None:
        with self._lock:
            blk = self._chunks.get(ref.chunk_id)
            if blk is not None:
                self.pin_refs((blk,))

    def unpin(self, ref) -> None:
        with self._lock:
            blk = self._chunks.get(ref.chunk_id)
            if blk is not None:
                self.unpin_refs((blk,))

    def prefetch(self, refs) -> None:  # segments are memory: nothing to stage
        pass

    def trim(self) -> None:  # chunks have no backing tier to shed to
        pass

    def close(self) -> None:
        """Unlink every segment and reset to an empty, reusable store."""
        with self._lock:
            for seg in list(self._segments.values()):
                self._drop_segment(seg)
            self._segments.clear()
            self._open = None
            self._exports.clear()
            self._keepalive.clear()
            self._chunks.clear()
            self.allocated_bytes = 0
            self.stats.resident_bytes = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class ShmAttachments:
    """Reader-side segment cache: name → attached ``SharedMemory``.

    Resolution is zero-copy: :meth:`view` returns a read-only ndarray over
    the attached segment's buffer.  Callers that outlive the view's
    segment (task operands) copy during operand construction
    (``jnp.stack``/``jnp.asarray`` already do).  The cache is LRU-capped:
    a closed attachment only releases this process's mapping — unlink
    stays the driver's job.
    """

    def __init__(self, *, max_segments: int = 64):
        self.max_segments = max_segments
        self._segs: OrderedDict[str, shared_memory.SharedMemory] = OrderedDict()
        self._lock = threading.Lock()

    def view(self, ref: ShmBlockRef) -> np.ndarray:
        with self._lock:
            seg = self._segs.get(ref.segment)
            if seg is None:
                seg = shared_memory.SharedMemory(name=ref.segment)
                self._segs[ref.segment] = seg
                while len(self._segs) > self.max_segments:
                    _, old = self._segs.popitem(last=False)
                    old.close()
            else:
                self._segs.move_to_end(ref.segment)
        out = np.ndarray(
            ref.shape, np.dtype(ref.dtype_str), buffer=seg.buf, offset=ref.offset
        )
        out.flags.writeable = False
        return out

    def close(self) -> None:
        with self._lock:
            for seg in self._segs.values():
                seg.close()
            self._segs.clear()


# ---------------------------------------------------------------------------
# reply-payload transport: one fresh segment per reply
# ---------------------------------------------------------------------------


def pack_tree(tree, *, threshold: int, name: str):
    """Move large ndarray leaves of ``tree`` into ONE fresh segment.

    Returns ``(tree_with_refs, segment_name | None, bytes_copied)``; the
    name is ``None`` (tree untouched) when no leaf clears ``threshold`` or
    the segment cannot be created (e.g. ``/dev/shm`` full) — the caller
    then ships the values inline, exactly as before.  The creator's
    mapping is closed immediately; the receiver owns the unlink
    (:func:`unpack_tree` / :func:`discard_tree`), giving every reply
    segment a strict send→consume→unlink lifecycle.
    """
    import jax

    leaves, treedef = jax.tree.flatten(tree)
    big = [
        i
        for i, leaf in enumerate(leaves)
        if isinstance(leaf, np.ndarray) and leaf.nbytes >= threshold
    ]
    if not big:
        return tree, None, 0
    total = sum(_aligned(leaves[i].nbytes) for i in big)
    try:
        seg = shared_memory.SharedMemory(name=name, create=True, size=total)
    except OSError:
        return tree, None, 0
    offset = 0
    wrote = 0
    for i in big:
        arr = np.ascontiguousarray(leaves[i])
        view = np.ndarray(arr.shape, arr.dtype, buffer=seg.buf, offset=offset)
        view[...] = arr
        leaves[i] = ShmBlockRef(name, offset, tuple(arr.shape), arr.dtype.str)
        offset += _aligned(arr.nbytes)
        wrote += arr.nbytes
    seg.close()
    return jax.tree.unflatten(treedef, leaves), name, wrote


def unpack_tree(tree):
    """Copy :class:`ShmBlockRef` leaves back to ndarrays; unlink their segments.

    Returns ``(tree, segment_names)``.  The consume half of the reply
    contract: after this, the segments are gone from ``/dev/shm``.
    """
    import jax

    leaves, treedef = jax.tree.flatten(tree)
    segs: dict[str, shared_memory.SharedMemory] = {}
    for i, leaf in enumerate(leaves):
        if not isinstance(leaf, ShmBlockRef):
            continue
        seg = segs.get(leaf.segment)
        if seg is None:
            seg = shared_memory.SharedMemory(name=leaf.segment)
            segs[leaf.segment] = seg
        leaves[i] = np.array(
            np.ndarray(
                leaf.shape, np.dtype(leaf.dtype_str), buffer=seg.buf, offset=leaf.offset
            )
        )
    for seg in segs.values():
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:  # pragma: no cover — a sweep raced us
            pass
    return jax.tree.unflatten(treedef, leaves), list(segs)


@dataclasses.dataclass(frozen=True)
class SegmentLease:
    """Ownership-transfer record for a published reply (DESIGN.md §16).

    The peer-exchange path inverts the strict send→consume→unlink reply
    lifecycle: a worker *publishes* its partial into a named segment that a
    sibling will attach directly, so the driver must NOT copy-and-unlink on
    receipt.  Instead it records this lease — the segment names and the
    partial bytes they hold — and stays the owner of the unlink: the lease
    is settled when the consuming fold completes (the saved bytes bill
    ``EngineReport.p2p_bytes``), or swept on any failure path (poison,
    context teardown, executor close).  Every published segment is under a
    lease or already unlinked; that is the zero-leak contract across kills
    mid-exchange.
    """

    segments: tuple[str, ...]
    nbytes: int


def tree_lease(tree) -> SegmentLease | None:
    """The :class:`SegmentLease` over a packed tree's ref leaves (or None).

    ``None`` means the tree carries no :class:`ShmBlockRef` leaves — the
    publish was declined (``/dev/shm`` full) and the partial travelled
    inline, so there is nothing to own.
    """
    import jax

    refs = [
        leaf for leaf in jax.tree.leaves(tree) if isinstance(leaf, ShmBlockRef)
    ]
    if not refs:
        return None
    return SegmentLease(
        segments=tuple(sorted({r.segment for r in refs})),
        nbytes=sum(r.nbytes for r in refs),
    )


def attach_tree(tree, attachments: "ShmAttachments"):
    """Resolve a packed tree's ref leaves to zero-copy views (cross-worker).

    The consumer half of the peer exchange: a sibling worker (or the
    driver's fallback path) maps the published segments read-only through
    its :class:`ShmAttachments` cache and gets the partial back without a
    copy.  Unlinking stays with the lease owner — this only reads.
    """
    import jax

    return jax.tree.map(
        lambda leaf: attachments.view(leaf) if isinstance(leaf, ShmBlockRef) else leaf,
        tree,
    )


def discard_tree(tree) -> None:
    """Unlink the segments of a reply that will never be consumed.

    Stale/duplicate replies (a salvaged result landing after its unit was
    replayed) still carry live segments; dropping the message without this
    would leak them.
    """
    import jax

    names = {
        leaf.segment for leaf in jax.tree.leaves(tree) if isinstance(leaf, ShmBlockRef)
    }
    unlink_segments(names)


# ---------------------------------------------------------------------------
# cleanup helpers (tests, CI fault lane, worker-death sweeps)
# ---------------------------------------------------------------------------


def unlink_segments(names: Iterable[str]) -> None:
    """Best-effort unlink of segments by name (missing ones are fine)."""
    for name in names:
        try:
            seg = shared_memory.SharedMemory(name=name)
        except FileNotFoundError:
            continue
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:  # pragma: no cover
            pass


def leaked_segments(prefix: str = SEGMENT_PREFIX) -> list[str]:
    """Live ``/dev/shm`` segment names starting with ``prefix`` (Linux).

    On hosts without a browsable ``/dev/shm`` this returns ``[]`` — the
    leak assertions become vacuous rather than false.
    """
    shm_dir = "/dev/shm"
    if not os.path.isdir(shm_dir):
        return []
    try:
        return sorted(n for n in os.listdir(shm_dir) if n.startswith(prefix))
    except OSError:  # pragma: no cover
        return []


def sweep_segments(prefix: str) -> int:
    """Unlink every live segment under ``prefix``; returns how many.

    The backstop for segments whose owner can no longer unlink them: a
    dead worker's unsent reply segments, or a whole executor's arena on
    ``close()``.
    """
    names = leaked_segments(prefix)
    unlink_segments(names)
    return len(names)
