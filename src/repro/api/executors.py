"""Executor backends — the scheduling half of the execution layer.

Execution is split into two explicit stages (DESIGN.md §5):

1. **lowering** (:mod:`repro.api.lowering`): ``(ExecutionPlan, policy,
   backend capabilities)`` → a frozen :class:`~repro.api.lowering.TaskGraph`
   of placed, keyed task descriptors — all fusion/task-construction
   decisions happen there;
2. **scheduling** (this module): an :class:`Executor` prepares the policy's
   placement (cached, LRU-bounded), lowers the plan against its declared
   :class:`~repro.api.lowering.Capabilities`, and schedules the TaskGraph.

All three backends schedule through ONE dependency-driven scheduler core
(:meth:`_PlanExecutor._schedule`): the backend turns the TaskGraph into
dispatch *units* (hook ``_plan_dispatches`` — one unit per task by default,
one per sharded bucket on :class:`~repro.api.mesh_executor.MeshExecutor`),
the core appends the merge as a unit depending on every task unit, and the
backend drains the ready set (hook ``_drain`` — inline on the calling
thread, or via the persistent per-location worker pool).  Every unit runs
instrumented: the core emits a :class:`~repro.api.profile.ProfileEvent`
(dispatch overhead, wall, bytes) into the executor's
:class:`~repro.api.profile.ProfileStore` — the *measure* third of the
adaptive-granularity loop (DESIGN.md §9).

Backends:

:class:`LocalExecutor`
    Sequential dispatch on the calling thread, with the seed's
    dispatch/trace/bytes accounting in :class:`~repro.core.engine.EngineReport`.
:class:`ThreadedExecutor`
    A persistent worker thread per *location* (created on first use, reused
    across ``execute`` calls so iterative workloads don't pay thread startup
    per iteration), overlapping per-partition dispatch across locations.
    Partials are collected by unit index and merged in plan order, so
    results are bit-identical to :class:`LocalExecutor`.
:class:`~repro.api.mesh_executor.MeshExecutor`
    Sharded dispatch over a JAX device mesh (own module).
:class:`~repro.api.cluster_executor.ClusterExecutor`
    Multi-process, fault-tolerant scheduling over spawn-based worker
    processes (own module, DESIGN.md §11): picklable
    :class:`~repro.api.lowering.TaskSpec` descriptors cross a real
    serialization/IPC boundary, units route to the worker owning their
    partition's location, and a supervisor replays the in-flight units of
    a dead worker on a survivor (``EngineReport.retries``).  The
    :class:`_SchedulerState` ownership hooks (``assign`` / ``requeue`` /
    ``is_done``) are what it shares with this module.
:class:`~repro.api.stream_executor.StreamExecutor`
    Out-of-core streaming over chunk-backed collections with
    double-buffered prefetch (own module, DESIGN.md §10).  The shared
    core brackets every unit with resolve/release hooks
    (:meth:`_PlanExecutor._acquire_unit` / ``_release_unit``) that pin the
    unit's :class:`~repro.api.chunkstore.ChunkRef` operands around
    dispatch, so chunk-backed plans run correctly on EVERY backend —
    streaming ones add lookahead, budget-bounded residency and the
    ``bytes_loaded`` / ``bytes_spilled`` / ``prefetch_hits`` report bill.

``SplIter(partitions_per_location="auto")`` closes the loop: the executor
owns an :class:`~repro.api.autotune.Autotuner` per workload that proposes
the granularity before each execution and is fed the measured wall time
after it.  A granularity retune between iterations is **logical regrouping
only**: the prepare cache keeps a ppl-independent :class:`_SplitBase` (the
placement scan, paid once) and derives the retuned ``PlacedGroup`` list
from the already-split blocks — zero re-splits, zero bytes moved
(``prepare_stats`` counts hits/splits/regroups so tests can assert it).

Executors also expose the engine-level ``task()`` registration for app
stages that do not fit the map/reduce plan shape (k-NN's lookup/merge
loops, Cascade SVM's binary cascade), and a ``scope()`` context manager
that accumulates plan executions plus custom task dispatches into a single
report.
"""

from __future__ import annotations

import atexit
import collections
import contextlib
import contextvars
import dataclasses
import math
import queue
import threading
import time
import warnings
import weakref
from typing import Any, Callable, Hashable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.autotune import Autotuner
from repro.api.chunkstore import chunk_stores
from repro.api.futures import ComputeFuture, Deferred, PipelineBrokenError
from repro.api.lowering import (
    Capabilities,
    MergeSpec,
    PartitionView,
    PlacedGroup,
    Task,
    TaskGraph,
    cross_iteration_edges,
    fold_plan,
    inputs_signature,
    lower,
    partition_key,
    planned_fold,
    stable_task_key,
    stacked_fold,
)
from repro.api.plan import ExecutionPlan, MapReduceSpec
from repro.api.policy import Baseline, ExecutionPolicy, Rechunk, SplIter
from repro.api.profile import ProfileStore
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport, TaskEngine
from repro.core.rechunk import rechunk
from repro.core.spliter import stripe_local_blocks

__all__ = [
    "ComputeResult",
    "ComputeFuture",
    "Deferred",
    "PipelineBrokenError",
    "PartitionView",
    "Executor",
    "LocalExecutor",
    "ThreadedExecutor",
    "PrepareStats",
    "SharedAssets",
]


@dataclasses.dataclass
class ComputeResult:
    """What ``Collection.compute`` returns: the value plus its cost report."""

    value: Any
    report: EngineReport

    def __iter__(self):
        # Allow ``value, report = plan.compute(...)`` unpacking.
        yield self.value
        yield self.report


@runtime_checkable
class Executor(Protocol):
    """The contract every execution backend satisfies (DESIGN.md §5).

    ``execute`` runs a validated plan; ``execute_async`` submits one and
    returns a :class:`~repro.api.futures.ComputeFuture` (pipelined backends
    overlap consecutive submissions — DESIGN.md §14; the rest complete it
    synchronously); ``task`` registers out-of-plan app stages against the
    same jit cache and accounting; ``report`` exposes the current
    :class:`~repro.core.engine.EngineReport`.  All five backends are
    structural instances:

    >>> from repro.api import (Executor, LocalExecutor, ThreadedExecutor,
    ...                        MeshExecutor, StreamExecutor, ClusterExecutor)
    >>> [isinstance(ex(), Executor) for ex in (LocalExecutor, ThreadedExecutor,
    ...                                        MeshExecutor, StreamExecutor,
    ...                                        ClusterExecutor)]
    [True, True, True, True, True]
    """

    def execute(self, plan: ExecutionPlan) -> ComputeResult: ...

    def execute_async(self, plan: ExecutionPlan) -> ComputeFuture: ...

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable: ...

    @property
    def report(self) -> EngineReport: ...


# ---------------------------------------------------------------------------
# prepared placement: policy -> (arrays, task groups), regroup-aware
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrepareStats:
    """Counters over the prepare cache (DESIGN.md §9.3).

    ``splits`` counts *physical* split derivations (the placement scan that
    builds a :class:`_SplitBase`); ``regroups`` counts granularity changes
    served by logically regrouping an already-split base — the
    regroup-without-resplit path.  A well-behaved autotuned iteration shows
    ``splits == 1`` and ``regroups == retunes`` with ``bytes_moved == 0``.
    """

    hits: int = 0        # prepare-cache hits (base or prepared entry)
    misses: int = 0      # cache misses (entry built)
    splits: int = 0      # placement scans (SplitBase builds)
    regroups: int = 0    # ppl regroups served WITHOUT re-splitting
    rechunks: int = 0    # physical rechunk preparations


@dataclasses.dataclass
class SharedAssets:
    """Cross-executor caches, owned by a long-lived service (DESIGN.md §12).

    A standalone executor owns a private copy of each of these; a
    :class:`~repro.api.jobserver.JobServer` builds ONE ``SharedAssets`` and
    has every pooled executor :meth:`~_PlanExecutor.adopt_shared_assets`, so
    prepared placements, profile events and autotuner state accumulate
    across tenants: tenant B's ``SplIter("auto")`` submission starts from
    the granularity tenant A's probes already converged on, keyed by the
    geometry-based :func:`~repro.api.lowering.inputs_signature` rather than
    object ids (two tenants never share array objects).

    Mutation happens under whichever thread runs units; the JobServer's
    single scheduler thread serializes unit execution, so no extra locking
    is layered on top of what each structure already has.
    """

    prepare_cache: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict
    )
    prepare_stats: PrepareStats = dataclasses.field(default_factory=PrepareStats)
    profile: ProfileStore = dataclasses.field(default_factory=ProfileStore)
    tuners: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict
    )


@dataclasses.dataclass
class _Prepared:
    """Cached result of applying a policy to a set of inputs.

    ``inputs`` retains the original arrays: the cache key uses their ids,
    so the entry must pin them alive — otherwise a gc'd input whose id is
    reused by a new BlockedArray would silently hit a stale entry.  The
    cache itself is a small LRU (see ``_PlanExecutor._prepare``) so a
    long-lived executor pins at most ``prepare_cache_size`` datasets, not
    every dataset it ever saw.
    """

    inputs: tuple[BlockedArray, ...]
    arrays: tuple[BlockedArray, ...]
    groups: list[PlacedGroup]


@dataclasses.dataclass
class _SplitBase:
    """The ppl-independent half of a SplIter preparation.

    Holds the placement scan (which blocks live where — the paper's
    dataClay-metadata / ``who_has`` query) once per (inputs) cache entry;
    any ``partitions_per_location`` is then a *logical regrouping* of these
    block-id lists (``stripe_local_blocks``) with zero data movement — the
    regroup-without-resplit contract the autotuner relies on between
    retunes.  Derived group lists are memoized per ppl (bounded by the
    granularity ladder, a handful of entries).
    """

    inputs: tuple[BlockedArray, ...]
    local_blocks: tuple[tuple[int, tuple[int, ...]], ...]  # (location, ids)
    groups_by_ppl: dict[int, list[PlacedGroup]] = dataclasses.field(
        default_factory=dict
    )

    def groups_for(self, ppl: int) -> tuple[list[PlacedGroup], bool]:
        """Groups at a granularity; True when freshly derived (a regroup)."""
        groups = self.groups_by_ppl.get(ppl)
        if groups is not None:
            return groups, False
        derived = bool(self.groups_by_ppl)
        groups = [
            PlacedGroup(loc, ids)
            for loc, local in self.local_blocks
            for ids in stripe_local_blocks(local, ppl)
        ]
        self.groups_by_ppl[ppl] = groups
        return groups, derived


def _tree_nbytes(tree) -> int:
    """Total ndarray bytes across a pytree's leaves (0 for non-arrays)."""
    return sum(
        int(getattr(leaf, "nbytes", 0) or 0) for leaf in jax.tree.leaves(tree)
    )


def _merge_partials(
    engine: TaskEngine,
    merge: MergeSpec,
    partials: list[Any],
    plan: tuple[tuple[int, tuple[int, ...]], ...] | None = None,
) -> Any:
    """Single merge task over the stacked partials (paper's @reduction task).

    Keyed by the MergeSpec's stable key — NOT the combine object, which apps
    typically recreate per call — so iterative workloads hit the jit cache.
    The fold body is the shared :func:`~repro.api.lowering.stacked_fold`
    (also the MeshExecutor's cross-rank fold — one source of truth).

    ``plan`` is the :func:`~repro.api.lowering.fold_plan` over the partials'
    list positions: when it has more than one group and any group chains,
    the fold runs along that tree via
    :func:`~repro.api.lowering.planned_fold` — still ONE dispatch, but with
    the per-location association the peer-exchange path (DESIGN.md §16)
    reproduces worker-side, so driver-merged and peer-merged executes are
    bit-identical.  A trivial plan (one group, or all singletons) keeps the
    original flat chain, bit-for-bit.

    ``driver_merge_bytes`` bills the partial bytes that had to be present
    in the driver for this fold — the counter the peer-exchange tests and
    benches compare against the pinned path.
    """
    if len(partials) == 1:
        return partials[0]
    engine.current_report.driver_merge_bytes += _tree_nbytes(partials)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *partials)
    groups = tuple(members for _, members in plan) if plan else ()
    if len(groups) > 1 and any(len(m) > 1 for m in groups):
        fold = planned_fold(merge.combine, groups)
        out = engine.task(fold, key=(merge.key, "fold_plan", groups))(stacked)
    else:
        out = engine.task(stacked_fold(merge.combine), key=merge.key)(stacked)
    engine.current_report.merges += 1
    return out


# ---------------------------------------------------------------------------
# the shared scheduler core: dispatch units + dependency bookkeeping
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Unit:
    """One schedulable unit: a task, a sharded bucket, a fold, or the merge.

    ``run`` is a nullary thunk; ``deps`` are unit indices that must
    complete first (the merge depends on every task unit — the dependency
    edge all three backends honor through the shared core).

    ``kind == "fold"`` units exist only when a backend materializes a
    :func:`~repro.api.lowering.fold_plan` group as its own schedulable
    unit (the cluster's peer-exchange path): ``fold_group`` holds the
    member unit indices (== ``deps``), ``origin`` the first member's task
    descriptor (error attribution names the originating app task, never
    the synthetic fold), and ``merge`` the graph's
    :class:`~repro.api.lowering.MergeSpec`.  ``publish`` marks a task unit
    whose partial a sibling fold consumes in place — the cluster dispatch
    asks the worker to leave the result in a named shared-memory segment
    instead of shipping it back.
    """

    index: int
    location: int                  # -1: any thread (merge / sharded bucket)
    tasks: tuple[Task, ...]        # graph descriptors covered (merge: ())
    run: Callable[[], Any] | None
    deps: tuple[int, ...] = ()
    kind: str = "task"
    fold_group: tuple[int, ...] = ()
    origin: Task | None = None
    merge: MergeSpec | None = None
    publish: bool = False


class _SchedulerState:
    """Thread-safe dependency/result bookkeeping for one TaskGraph run.

    Beyond the dependency core, the state tracks *ownership*: which
    executor-defined owner (a worker thread, a cluster worker process) a
    unit was assigned to, how many times it has been attempted, and —
    via :meth:`requeue` — which in-flight units an owner took down with it.
    Owners are opaque hashables; the hooks are what make fault-tolerant
    backends (ClusterExecutor) a scheduling concern instead of a fork of
    the core.

    Pipelined executes (DESIGN.md §14) add three things:

    * ``report`` — the :class:`~repro.core.engine.EngineReport` this
      graph's units bill (``None``: the engine's current report, the
      synchronous path).  With several graphs in flight, billing must ride
      with the graph, not with whichever report the engine points at.
    * per-unit / completion *subscriptions* — :meth:`subscribe` /
      :meth:`on_all_done` / :meth:`on_fail`: how the NEXT iteration's
      gated units learn their cross-iteration predecessors finished.
      :meth:`complete` fires unit subscriptions before completion
      subscriptions before ``done.set()``, all outside the lock — so a
      dependent iteration's launch is enqueued before the completed
      iteration's future can resolve, and the overlap is deterministic.
    * ``partition_versions`` — the versioned-key counter: for each
      :func:`~repro.api.lowering.partition_key` this graph covers, which
      pipelined version of that partition it computes (predecessor's
      version + 1; first submission: 1).
    """

    def __init__(self, units: list[_Unit], report: EngineReport | None = None):
        self.units = units
        self.report = report
        self.results: list[Any] = [None] * len(units)
        self.errors: list[BaseException] = []
        self._lock = threading.Lock()
        self._indegree = [len(u.deps) for u in units]
        self._dependents: list[list[int]] = [[] for _ in units]
        for u in units:
            for d in u.deps:
                self._dependents[d].append(u.index)
        self._remaining = len(units)
        self._done_units: set[int] = set()
        self.owner: dict[int, Hashable] = {}        # unit index -> owner
        self.attempts: collections.Counter = collections.Counter()
        self._unit_subs: dict[int, list[Callable[[], None]]] = {}
        self._done_subs: list[Callable[[], None]] = []
        self._fail_subs: list[Callable[[BaseException], None]] = []
        self.partition_versions: dict[tuple, int] = {}
        self.done = threading.Event()
        if not units:
            self.done.set()

    def initial_ready(self) -> list[_Unit]:
        return [u for u in self.units if not u.deps]

    def assign(self, unit: _Unit, owner: Hashable) -> None:
        """Record who is executing ``unit`` (attempt counted on assign).

        Double-claim prevention (the work-stealing invariant): a unit that
        is still owned by a *different* live owner cannot be re-assigned —
        ownership must first move through :meth:`requeue` (death),
        :meth:`release` (steal / preemption), or completion.  A late
        assign raced against a completed unit is equally rejected; both
        raise so the property suite can falsify any interleaving that
        would run a unit twice.
        """
        with self._lock:
            if unit.index in self._done_units:
                raise RuntimeError(
                    f"unit {unit.index} assigned to {owner!r} after completion"
                )
            prev = self.owner.get(unit.index)
            if prev is not None and prev != owner:
                raise RuntimeError(
                    f"unit {unit.index} double-claimed: owned by {prev!r}, "
                    f"assigned to {owner!r}"
                )
            self.owner[unit.index] = owner
            self.attempts[unit.index] += 1

    def release(self, unit: _Unit) -> bool:
        """Disown a claimed-but-unstarted unit (steal grant / preemption).

        The voided dispatch's attempt is refunded: a steal is a scheduling
        decision, not a failure, so it must not count against
        ``max_retries``.  Returns False — and changes nothing — when the
        unit already completed (the victim raced the grant) or was never
        owned, so callers can treat the grant as stale.
        """
        with self._lock:
            if unit.index in self._done_units or unit.index not in self.owner:
                return False
            del self.owner[unit.index]
            if self.attempts[unit.index] > 0:
                self.attempts[unit.index] -= 1
            return True

    def refund_attempt(self, index: int) -> None:
        """Refund one attempt after :meth:`requeue` of a *planned* preemption.

        Scale-down drains through the same requeue/replay path as a death,
        but a deliberate shrink must not push units toward retry
        exhaustion — spot-instance semantics.
        """
        with self._lock:
            if self.attempts[index] > 0:
                self.attempts[index] -= 1

    def is_done(self, index: int) -> bool:
        with self._lock:
            return index in self._done_units

    def requeue(self, owner: Hashable) -> list[_Unit]:
        """Disown ``owner``'s incomplete units (worker death) for replay.

        Returns the lost units; their ownership entries are cleared so a
        late/duplicate completion from the dead owner is ignorable via
        :meth:`is_done`, and re-assignment restarts the attempt count
        bookkeeping for the surviving owner.
        """
        with self._lock:
            lost = [
                self.units[i]
                for i, o in list(self.owner.items())
                if o == owner and i not in self._done_units
            ]
            for u in lost:
                del self.owner[u.index]
        return lost

    def subscribe(self, index: int, cb: Callable[[], None]) -> bool:
        """Fire ``cb`` when unit ``index`` completes; False if already done.

        On False the caller runs its callback inline — the unit finished
        before the subscription landed, so there is nothing to wait for.
        """
        with self._lock:
            if index in self._done_units:
                return False
            self._unit_subs.setdefault(index, []).append(cb)
            return True

    def on_all_done(self, cb: Callable[[], None]) -> None:
        """Fire ``cb`` once every unit has completed (not on failure)."""
        with self._lock:
            if self._remaining > 0:
                self._done_subs.append(cb)
                return
        cb()

    def on_fail(self, cb: Callable[[BaseException], None]) -> None:
        """Fire ``cb`` on the first failure (immediately if already failed)."""
        with self._lock:
            if not self.errors:
                self._fail_subs.append(cb)
                return
            exc = self.errors[0]
        cb(exc)

    def complete(self, unit: _Unit, value: Any) -> list[_Unit]:
        """Record a result; return units that just became ready.

        Subscription ordering contract (pipelined overlap): unit
        subscriptions (cross-iteration launches) fire first, then — when
        this was the last unit — completion subscriptions (the future's raw
        value), then ``done.set()``.  All fire OUTSIDE the lock, on the
        completing thread.
        """
        newly: list[_Unit] = []
        finished = False
        with self._lock:
            if unit.index in self._done_units:  # duplicate (replayed) result
                return []
            self._done_units.add(unit.index)
            self.results[unit.index] = value
            for di in self._dependents[unit.index]:
                self._indegree[di] -= 1
                if self._indegree[di] == 0:
                    newly.append(self.units[di])
            self._remaining -= 1
            subs = self._unit_subs.pop(unit.index, ())
            if self._remaining == 0:
                finished = True
                done_subs, self._done_subs = self._done_subs, []
        for cb in subs:
            cb()
        if finished:
            for cb in done_subs:
                cb()
            self.done.set()
        return newly

    def fail(self, exc: BaseException) -> None:
        with self._lock:
            self.errors.append(exc)
            fail_subs, self._fail_subs = self._fail_subs, []
        for cb in fail_subs:
            cb(exc)
        self.done.set()


@dataclasses.dataclass
class _PipelineEntry:
    """One in-flight pipelined execute (DESIGN.md §14).

    Everything the synchronous ``execute`` keeps on its stack — graph,
    scheduler state, report, policy/tuner snapshot, store marks, timing —
    promoted to an object so several executes can be in flight at once.
    Finalization (:meth:`_PlanExecutor._finalize_entry`) consumes it
    exactly once.
    """

    iteration: int
    graph: TaskGraph
    state: _SchedulerState
    merge_index: int | None
    report: EngineReport
    future: ComputeFuture
    policy: ExecutionPolicy
    tuner: Autotuner | None
    t0: float
    t_done: float = 0.0
    finalized: bool = False
    result: ComputeResult | None = None
    store_marks: list = dataclasses.field(default_factory=list)
    # Backend drive attachments (opaque to the core):
    ctx: Any = None          # ClusterExecutor: the entry's _DrainContext
    pending: Any = None      # StreamExecutor: this entry's pending unit deque
    jobs: Any = None         # StreamExecutor: unit index -> prefetch job
    draining: bool = False   # StreamExecutor: drain in progress/finished

    def mark_stores(self, stores=None) -> None:
        """(Re)snapshot the input stores' lifetime counters.

        Pipelined report exactness for chunk I/O is *window-based*: the
        entry bills the store-counter delta between this mark and its
        finalization.  Backends that begin real I/O later than submit
        (StreamExecutor drains entries in order) re-mark at drain start so
        the window covers exactly this entry's streaming.
        """
        src = stores if stores is not None else [s for s, _ in self.store_marks]
        self.store_marks = [(s, s.stats.snapshot()) for s in src]


#: True while repro.api.engine() is constructing a backend — direct
#: constructor calls outside the factory get a DeprecationWarning nudge.
_via_factory: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_engine_via_factory", default=False
)


@contextlib.contextmanager
def _factory_construction():
    """Suppress the direct-construction warning (factory / internal defaults)."""
    token = _via_factory.set(True)
    try:
        yield
    finally:
        _via_factory.reset(token)


def _warn_direct_construction(cls: type) -> None:
    """One DeprecationWarning per direct (non-factory) backend construction.

    The per-backend constructors keep working — this is the shim half of
    the ``repro.api.engine()`` redesign: existing code runs unchanged, new
    code is pointed at the factory.
    """
    if not _via_factory.get():
        warnings.warn(
            f"constructing {cls.__name__} directly is deprecated; use "
            f'repro.api.engine(backend=..., config=EngineConfig(...)) '
            f"(DESIGN.md §16)",
            DeprecationWarning,
            stacklevel=3,
        )


class _PlanExecutor:
    """Shared prepare/lower/schedule core; subclasses customize dispatch."""

    #: bound on cached (inputs, policy) preparations (LRU eviction).
    prepare_cache_size: int = 8

    #: backend overlaps consecutive execute_async submissions (DESIGN.md §14).
    _pipelined: bool = False

    #: in-flight window for execute_async: admitting a submission beyond
    #: this many unresolved entries finalizes the oldest first (the PR 5
    #: flow-control shape, lifted to whole executes).
    pipeline_depth: int = 2

    def __init__(self, engine: TaskEngine | None = None):
        _warn_direct_construction(type(self))
        self.engine = engine or TaskEngine()
        self._prepare_cache: collections.OrderedDict[tuple, Any] = (
            collections.OrderedDict()
        )
        self.prepare_stats = PrepareStats()
        self.profile = ProfileStore()
        self._tuners: collections.OrderedDict[tuple, tuple] = (
            collections.OrderedDict()
        )
        self._scope_depth = 0
        self._pipeline: collections.deque[_PipelineEntry] = collections.deque()
        self._iteration = 0  # execute_async submit counter (error attribution)

    def adopt_shared_assets(self, assets: SharedAssets) -> None:
        """Rebind this executor's caches to server-owned :class:`SharedAssets`.

        After adoption the executor reads and writes the shared structures
        directly (no copies), so probes/preparations done through any
        sibling executor in the pool are visible here.  Pre-adoption
        private profile history folds into the shared store
        (:meth:`~repro.api.profile.ProfileStore.merge`) so earlier probes
        keep informing the shared overhead hint; prepare/tuner entries
        migrate by dict update (shared entries win on key collision).
        """
        assets.profile.merge(self.profile)
        for key, entry in self._prepare_cache.items():
            assets.prepare_cache.setdefault(key, entry)
        for key, entry in self._tuners.items():
            assets.tuners.setdefault(key, entry)
        self._prepare_cache = assets.prepare_cache
        self.prepare_stats = assets.prepare_stats
        self.profile = assets.profile
        self._tuners = assets.tuners

    # -- backend capabilities (consumed by the lowering pass) -----------------

    @property
    def capabilities(self) -> Capabilities:
        # prefer_pallas is resolved lazily: compiled Pallas beats the scan on
        # TPU, interpret mode does not — and querying the backend at import
        # time would lock jax device state before tests can set XLA_FLAGS.
        return Capabilities(
            name=type(self).__name__,
            prefer_pallas=jax.default_backend() == "tpu",
            pipelined=self._pipelined,
        )

    # -- engine passthroughs -------------------------------------------------

    @property
    def report(self) -> EngineReport:
        return self.engine.report

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        return self.engine.task(fn, key=key)

    @contextlib.contextmanager
    def scope(self, mode: str):
        """Accumulate plan executions + custom dispatches into one report."""
        report = self.engine.new_report(mode)
        self._scope_depth += 1
        t0 = time.perf_counter()
        try:
            yield report
        finally:
            self._scope_depth -= 1
            report.wall_s = time.perf_counter() - t0

    # -- the Executor entry point --------------------------------------------

    def execute(self, plan: ExecutionPlan) -> ComputeResult:
        # Barrier rule: a synchronous execute never overlaps — any in-flight
        # pipelined submissions resolve first, in submit order (their
        # futures keep the outcomes; errors surface there, not here).
        if self._pipeline:
            self._drain_pipeline()
        spec = plan.spec
        own_report = self._scope_depth == 0
        if own_report:
            report = self.engine.new_report(spec.policy.mode_name)
        else:
            report = self.engine.report
        t0 = time.perf_counter()
        traces0 = self.engine.traces_total

        policy, tuner = self._resolve_policy(spec)
        if (
            tuner is not None
            and tuner.last_ppl is not None
            and policy.partitions_per_location != tuner.last_ppl
        ):
            report.retunes += 1
        # Chunk-store accounting: report the I/O this execution caused as
        # window deltas of the input stores' lifetime counters.
        stores = chunk_stores(spec.inputs)
        store_marks = [(s, s.stats.snapshot()) for s in stores]
        prepared = self._prepare(spec.inputs, policy, report)
        graph = lower(spec, prepared.arrays, prepared.groups, self.capabilities)
        # Per-unit wall profiling (block_until_ready between units) would
        # serialize the async-dispatch pipeline, so it is enabled only for
        # the tuner's probe iterations — the window that needs real
        # per-task walls and is trace-dominated anyway.
        sync_prev = self.profile.sync
        if tuner is not None and tuner.probing:
            self.profile.sync = True
        try:
            value = self._schedule(graph)
        finally:
            self.profile.sync = sync_prev
        value = jax.block_until_ready(value)
        dt = time.perf_counter() - t0

        for store, mark in store_marks:
            report.bytes_loaded += store.stats.bytes_loaded - mark.bytes_loaded
            report.bytes_spilled += store.stats.bytes_spilled - mark.bytes_spilled
            report.prefetch_hits += store.stats.prefetch_hits - mark.prefetch_hits
        if isinstance(policy, SplIter):
            report.granularity = policy.partitions_per_location
        if tuner is not None:
            self._feed_tuner(tuner, policy, graph, dt, traced=(
                self.engine.traces_total > traces0
            ))
        if own_report:
            report.wall_s = dt
        return ComputeResult(value=value, report=report)

    # -- pipelined (asynchronous) execution — DESIGN.md §14 --------------------

    def execute_async(self, plan: ExecutionPlan) -> ComputeFuture:
        """Submit a plan without draining it; returns a :class:`ComputeFuture`.

        On a pipelined backend (``capabilities.pipelined``) consecutive
        submissions overlap: each unit of this plan is gated on its
        same-partition predecessors in the previous in-flight submission
        (plus any :class:`~repro.api.futures.Deferred` operand's source
        merge) via :func:`~repro.api.lowering.cross_iteration_edges`, and
        launches the moment those complete.  At most :attr:`pipeline_depth`
        submissions stay unresolved; admitting one past the window
        finalizes the oldest first.

        Everywhere else — non-pipelined backends, inside a :meth:`scope`
        (one accumulated report means one report window at a time), or
        during an autotuner *probe* window (profiled walls must never
        measure overlapped executes; the guard forces depth 1) — this is a
        synchronous execute wrapped in an already-completed future, so
        application code is identical either way.
        """
        spec = plan.spec
        if not self.capabilities.pipelined or self._scope_depth:
            return self._sync_future(plan)
        policy, tuner = self._resolve_policy(spec)
        if tuner is not None and tuner.probing:
            # Probe guard (DESIGN.md §14): a probe iteration's wall feeds
            # the cost model; overlapping it with a neighbour would record
            # contended walls and mistune granularity for every later
            # iteration.  Probes run barriered (depth 1).
            return self._sync_future(plan)
        return self._submit_entry(spec, policy, tuner)

    def _sync_future(self, plan: ExecutionPlan) -> ComputeFuture:
        """The non-overlapping fallback: execute now, return a done future."""
        self._drain_pipeline()
        iteration, self._iteration = self._iteration, self._iteration + 1
        try:
            result = self.execute(plan)
        except BaseException as e:  # noqa: BLE001 — surfaced via the future
            return ComputeFuture.failed(e, iteration=iteration)
        return ComputeFuture.completed(result, iteration=iteration)

    def _submit_entry(
        self, spec: MapReduceSpec, policy: ExecutionPolicy, tuner: Autotuner | None
    ) -> ComputeFuture:
        # Flow control: the in-flight window is pipeline_depth whole
        # executes; the oldest entry resolves before a new one is admitted.
        while len(self._pipeline) >= max(1, int(self.pipeline_depth)):
            try:
                self._finalize_entry(self._pipeline[0])
            except BaseException:  # noqa: BLE001 — kept on the evicted future
                pass

        prev = self._pipeline[-1] if self._pipeline else None
        iteration, self._iteration = self._iteration, self._iteration + 1
        report = EngineReport(mode=spec.policy.mode_name)
        if (
            tuner is not None
            and tuner.last_ppl is not None
            and policy.partitions_per_location != tuner.last_ppl
        ):
            report.retunes += 1
        t0 = time.perf_counter()
        # Prepare/lower/build under the entry's report binding so traces
        # paid at registration time are credited to this submission.
        with self.engine.bind_report(report):
            prepared = self._prepare(spec.inputs, policy, report)
            graph = lower(spec, prepared.arrays, prepared.groups, self.capabilities)
            units, state, merge_unit = self._build_units(graph, report=report)

        fut = ComputeFuture(iteration=iteration)
        entry = _PipelineEntry(
            iteration=iteration,
            graph=graph,
            state=state,
            merge_index=None if merge_unit is None else merge_unit.index,
            report=report,
            future=fut,
            policy=policy,
            tuner=tuner,
            t0=t0,
        )
        entry.mark_stores(chunk_stores(spec.inputs))
        fut._finalize = lambda: self._finalize_entry(entry)
        fut._drive = lambda: self._drive_raw(entry)

        # Versioned keys: each partition this graph covers computes the
        # next version after its predecessor's (1 on first submission).
        for t in graph.tasks:
            k = partition_key(t)
            base = prev.state.partition_versions.get(k, 0) if prev is not None else 0
            state.partition_versions[k] = base + 1

        self._wire_future(entry)
        if prev is not None:
            self._wire_poison(entry, prev)
            # Overlap accounting, frozen at SUBMIT time: an earlier
            # unresolved submission exists, so every unit of this one is
            # admitted before the previous execute's merge resolution — a
            # function of the application's call order alone, identical
            # across backends and runs (a launch-time check against the
            # previous merge would be a host-speed race).
            report.overlapped_launches = len(units)
        self._pipeline.append(entry)
        self._start_entry(entry, prev)
        return fut

    def _wire_future(self, entry: _PipelineEntry) -> None:
        """Raw-phase completion: state outcome → the entry's future."""
        state, fut = entry.state, entry.future
        merge_index = entry.merge_index

        def on_done():
            entry.t_done = time.perf_counter()
            fut._set_raw(
                state.results[merge_index]
                if merge_index is not None
                else list(state.results)
            )

        def on_fail(exc: BaseException):
            entry.t_done = time.perf_counter()
            fut._set_error(exc)

        state.on_all_done(on_done)
        state.on_fail(on_fail)

    def _wire_poison(self, entry: _PipelineEntry, prev: _PipelineEntry) -> None:
        """Failure propagation: an upstream failure poisons this entry.

        The typed error names the originating iteration; gated units that
        never launched stay unlaunched (their cross-iteration
        subscriptions simply never fire), and this entry's own failure
        subscriptions cascade the poison to anything gated on *it*.
        """

        def poison(exc: BaseException):
            entry.state.fail(
                PipelineBrokenError(
                    f"pipelined execute #{entry.iteration} aborted: upstream "
                    f"iteration #{prev.iteration} failed: {exc}",
                    iteration=prev.iteration,
                )
            )

        prev.state.on_fail(poison)

    def _gate_units(
        self,
        entry: _PipelineEntry,
        prev: _PipelineEntry | None,
        launch: Callable[[_Unit], None],
    ) -> None:
        """Launch ``entry``'s initially-ready units behind their cross-
        iteration gates.

        Each unit waits on (a) its same-partition predecessors in ``prev``
        (:func:`~repro.api.lowering.cross_iteration_edges`; units a retune
        left unmatched fall back to ``prev``'s merge — correct, just
        barrier-shaped for that boundary), plus (b) the merge fold of any
        in-flight submission one of this plan's ``Deferred`` operands
        resolves against — a hard data dependency, so resolution never
        blocks inside a dispatch.  Ungated units launch immediately.
        ``launch`` is the backend's primitive; gate callbacks fire on
        whichever thread completed the last predecessor.
        """
        state = entry.state
        ready = state.initial_ready()
        gates: dict[int, list[tuple[_SchedulerState, int]]] = {}
        if prev is not None:
            edges = cross_iteration_edges(prev.graph, entry.graph)
            fallback = (
                [(prev.state, prev.merge_index)]
                if prev.merge_index is not None
                else []
            )
            for u in ready:
                if u.location < 0 or not u.tasks:
                    continue
                deps = [(prev.state, i) for i in edges.get(u.index, ())]
                gates[u.index] = deps if deps else list(fallback)
        merge_gates: list[tuple[_SchedulerState, int]] = []
        for e in entry.graph.spec.extra_args:
            if isinstance(e, Deferred):
                src = next(
                    (
                        p
                        for p in self._pipeline
                        if p is not entry and p.future is e.future
                    ),
                    None,
                )
                if src is not None and src.merge_index is not None:
                    merge_gates.append((src.state, src.merge_index))
        if merge_gates:
            for u in ready:
                if u.location < 0 or not u.tasks:
                    continue
                gates.setdefault(u.index, []).extend(merge_gates)

        for u in ready:
            seen: set[tuple[int, int]] = set()
            uniq: list[tuple[_SchedulerState, int]] = []
            for dep in gates.get(u.index) or ():
                mark = (id(dep[0]), dep[1])
                if mark not in seen:
                    seen.add(mark)
                    uniq.append(dep)
            if not uniq:
                launch(u)
                continue
            hold = threading.Lock()
            left = [len(uniq)]

            def advance(u=u, hold=hold, left=left):
                with hold:
                    left[0] -= 1
                    fire = left[0] == 0
                if fire:
                    launch(u)

            for src_state, idx in uniq:
                if not src_state.subscribe(idx, advance):
                    advance()  # predecessor already completed

    def _start_entry(
        self, entry: _PipelineEntry, prev: _PipelineEntry | None
    ) -> None:  # pragma: no cover — every pipelined backend overrides
        """Begin executing a submitted entry (pipelined-backend hook)."""
        raise NotImplementedError(
            f"{type(self).__name__} declares pipelined capabilities but "
            "does not implement _start_entry"
        )

    def _drive_raw(self, entry: _PipelineEntry) -> None:
        """Make progress until ``entry`` reaches raw completion (hook).

        No-op by default: push-driven backends (ThreadedExecutor) complete
        entries from their worker threads and waiters just block on the
        state event.  Cooperative backends (ClusterExecutor,
        StreamExecutor) override this to pump their event loop / drain
        queued entries on the calling thread.
        """

    def _drive_entry(self, entry: _PipelineEntry) -> None:
        if not entry.state.done.is_set():
            self._drive_raw(entry)
            entry.state.done.wait()

    def _finalize_entry(self, entry: _PipelineEntry) -> ComputeResult:
        """The deferred half of ``execute()``: run exactly once per entry.

        Waits for raw completion, then performs the per-execute bookkeeping
        the synchronous path does behind its barrier — device sync, store
        window deltas, granularity stamp, tuner feedback, ``wall_s`` — and
        seals the entry's ComputeResult.  Raises the entry's failure (the
        future carries it too).
        """
        if not entry.finalized:
            entry.finalized = True
            try:
                self._drive_entry(entry)
            finally:
                try:
                    self._pipeline.remove(entry)
                except ValueError:
                    pass
            state, report = entry.state, entry.report
            dt = (entry.t_done or time.perf_counter()) - entry.t0
            report.wall_s = dt
            if not state.errors:
                try:
                    value = (
                        state.results[entry.merge_index]
                        if entry.merge_index is not None
                        else list(state.results)
                    )
                    value = jax.block_until_ready(value)
                except BaseException as e:  # noqa: BLE001 — kept on the future
                    state.errors.append(e)
                    entry.future._set_error(e)
                else:
                    for store, mark in entry.store_marks:
                        st = store.stats
                        report.bytes_loaded += st.bytes_loaded - mark.bytes_loaded
                        report.bytes_spilled += st.bytes_spilled - mark.bytes_spilled
                        report.prefetch_hits += st.prefetch_hits - mark.prefetch_hits
                    if isinstance(entry.policy, SplIter):
                        report.granularity = entry.policy.partitions_per_location
                    if entry.tuner is not None:
                        self._feed_tuner(
                            entry.tuner,
                            entry.policy,
                            entry.graph,
                            dt,
                            traced=report.traces > 0,
                        )
                    entry.result = ComputeResult(value=value, report=report)
                    entry.future._result = entry.result
        if entry.state.errors:
            raise entry.state.errors[0]
        return entry.result

    def _drain_pipeline(self) -> None:
        """Resolve every in-flight pipelined execute, in submit order.

        The pipeline's barrier: ``execute``, ``close`` and the sync
        fallback call this before doing anything else.  Failures stay on
        the entries' futures (where the application observes them); the
        barrier itself never raises another submission's error.
        """
        while self._pipeline:
            entry = self._pipeline[0]
            try:
                self._finalize_entry(entry)
            except BaseException:  # noqa: BLE001 — kept on the entry's future
                pass
            if self._pipeline and self._pipeline[0] is entry:
                self._pipeline.popleft()  # defensive: never spin

    def lower(self, plan: ExecutionPlan) -> TaskGraph:
        """Lower a plan for this backend without running it (inspection)."""
        spec = plan.spec
        policy, _ = self._resolve_policy(spec)
        prepared = self._prepare(spec.inputs, policy, self.engine.report)
        return lower(spec, prepared.arrays, prepared.groups, self.capabilities)

    # -- autotuning: resolve SplIter("auto") against the workload's tuner ------

    def _resolve_policy(
        self, spec: MapReduceSpec
    ) -> tuple[ExecutionPolicy, Autotuner | None]:
        pol = spec.policy
        if not (isinstance(pol, SplIter) and pol.autotuned):
            return pol, None
        tuner = self._tuner_for(spec, pol)
        return (
            dataclasses.replace(pol, partitions_per_location=tuner.propose()),
            tuner,
        )

    def _tuner_for(self, spec: MapReduceSpec, pol: SplIter) -> Autotuner:
        # Geometry-keyed (not id-keyed): two equal-geometry datasets — e.g.
        # two tenants submitting over the same blocking through a JobServer
        # pool with SharedAssets, or a journal-rebuilt array after a server
        # restart — resolve to the SAME tuner, so probe cost is paid once
        # per (geometry, kind, fn, policy) rather than once per array object.
        key = (
            inputs_signature(spec.inputs),
            spec.kind,
            stable_task_key(spec.fn),
            pol,
        )
        entry = self._tuners.get(key)
        if entry is not None:
            self._tuners.move_to_end(key)
            return entry[1]
        x0 = spec.inputs[0]
        counts = [len(x0.blocks_at(loc)) for loc in range(x0.num_locations)]
        tuner = Autotuner(counts, seed=pol.autotune_seed)
        # Tuple value kept for compat with snapshot/introspection call
        # sites; the geometry key does not pin the input arrays alive.
        self._tuners[key] = (None, tuner)
        while len(self._tuners) > self.prepare_cache_size:
            self._tuners.popitem(last=False)
        return tuner

    def _feed_tuner(
        self,
        tuner: Autotuner,
        policy: SplIter,
        graph: TaskGraph,
        wall_s: float,
        *,
        traced: bool,
    ) -> None:
        counted = sum(1 for t in graph.tasks if t.counted)
        span = max((len(t.block_ids) for t in graph.tasks), default=0)
        tuner.observe(
            policy.partitions_per_location,
            wall_s,
            n_tasks=counted or None,
            span=span or None,
            traced=traced,
            # The overhead hint is scoped to THIS workload's task keys so
            # other policies/datasets run through the same executor don't
            # pollute the 1–2-sample fallback model.
            overhead_s=self.profile.mean_task_overhead_s(
                kinds=(
                    "block",
                    "partition_scan",
                    "partition_pallas",
                    "partition_materialized",
                    "sharded",
                ),
                keys={t.key for t in graph.tasks if t.counted},
            ),
        )

    # -- prepare: policy -> (arrays, task groups), LRU-cached ------------------

    def _prepare(
        self,
        inputs: tuple[BlockedArray, ...],
        policy: ExecutionPolicy,
        report: EngineReport,
    ) -> _Prepared:
        stats = self.prepare_stats
        ids = tuple(id(a) for a in inputs)

        if isinstance(policy, SplIter):
            # SplIter preparations share ONE ppl-independent base per input
            # set: the placement scan is paid once; every granularity —
            # including autotuner retunes — is a logical regroup of the
            # already-split block-id lists (zero movement, zero re-splits).
            ppl = policy.partitions_per_location
            assert isinstance(ppl, int), "auto must be resolved before prepare"
            key = (ids, SplIter)
            base = self._prepare_cache.get(key)
            if base is not None:
                self._prepare_cache.move_to_end(key)
                stats.hits += 1
            else:
                stats.misses += 1
                stats.splits += 1
                x0 = inputs[0]
                local_blocks = []
                for loc in range(x0.num_locations):
                    local = x0.blocks_at(loc)
                    if local:
                        local_blocks.append((loc, tuple(local)))
                base = _SplitBase(inputs=inputs, local_blocks=tuple(local_blocks))
                self._cache_put(key, base)
            groups, regrouped = base.groups_for(ppl)
            if regrouped:
                stats.regroups += 1
            return _Prepared(inputs=inputs, arrays=inputs, groups=groups)

        key = (ids, policy)
        hit = self._prepare_cache.get(key)
        if hit is not None:
            self._prepare_cache.move_to_end(key)
            stats.hits += 1
            return hit
        stats.misses += 1

        x0 = inputs[0]
        if isinstance(policy, Rechunk):
            stats.rechunks += 1
            target = policy.target_rows or math.ceil(x0.num_rows / x0.num_locations)
            arrays = []
            for a in inputs:
                na, st = rechunk(a, target)
                report.bytes_moved += st.bytes_moved
                arrays.append(na)
            arrays = tuple(arrays)
            groups = [
                PlacedGroup(int(arrays[0].placements[i]), (i,))
                for i in range(arrays[0].num_blocks)
            ]
        elif isinstance(policy, Baseline):
            arrays = inputs
            groups = [
                PlacedGroup(int(x0.placements[i]), (i,)) for i in range(x0.num_blocks)
            ]
        else:  # pragma: no cover
            raise TypeError(f"unknown policy {policy!r}")

        prepared = _Prepared(inputs=inputs, arrays=arrays, groups=groups)
        self._cache_put(key, prepared)
        return prepared

    def _cache_put(self, key: tuple, entry: Any) -> None:
        self._prepare_cache[key] = entry
        while len(self._prepare_cache) > self.prepare_cache_size:
            _, evicted = self._prepare_cache.popitem(last=False)
            self._release_prepared(evicted)

    def _release_prepared(self, entry: Any) -> None:
        """Un-cache hook: trim the chunk stores an evicted entry pinned.

        The prepare cache is what keeps a dataset *warm* across iterations;
        once its entry falls out of the LRU the dataset's resident chunks
        have no scheduled consumer, so unpinned residency is shed back to
        the spill tier (in-memory stores: no-op).
        """
        for store in chunk_stores(getattr(entry, "inputs", ())):
            store.trim()

    def close(self) -> None:
        """Release cached preparations and trim their chunk stores.

        In-flight pipelined futures drain first (their results stay
        retrievable through ``result()`` after close) — the clean-shutdown
        half of the §14 contract.  Idempotent; backends with extra
        resources (worker pools, prefetch threads, owned stores) extend it
        and MUST drain the pipeline before stopping whatever executes it.
        """
        self._drain_pipeline()
        entries = list(self._prepare_cache.values())
        self._prepare_cache.clear()
        self._tuners.clear()
        for entry in entries:
            self._release_prepared(entry)

    def __enter__(self):
        """``with engine(...) as ex:`` — the documented construction idiom."""
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- the shared scheduler core ---------------------------------------------

    def _bind(self, task: Task) -> Callable[[], Any]:
        """A nullary thunk running one task through the engine's jit cache."""
        if not task.counted:
            return lambda: task.fn(*task.operands())
        t = self.engine.task(task.fn, key=task.key)
        return lambda: t(*task.operands())

    def _plan_dispatches(self, graph: TaskGraph) -> list[_Unit]:
        """TaskGraph → dispatch units (backend hook; default one per task)."""
        return [
            _Unit(index=i, location=t.location, tasks=(t,), run=self._bind(t),
                  kind=t.kind)
            for i, t in enumerate(graph.tasks)
        ]

    def _build_units(
        self, graph: TaskGraph, *, report: EngineReport | None = None
    ) -> tuple[list[_Unit], _SchedulerState, _Unit | None]:
        """TaskGraph → ``(units, state, merge_unit)``, merge closure bound.

        The unit-level handoff point: :meth:`_schedule` drains the whole
        list through the backend's ``_drain`` hook, while a
        :class:`~repro.api.jobserver.JobServer` calls this directly and
        interleaves units from MANY graphs on one scheduler thread via
        :meth:`_run_unit` — the gap between two units is the preemption
        point where per-tenant fair scheduling happens.
        """
        units = list(self._plan_dispatches(graph))
        merge_unit = None
        fold_units: list[_Unit] = []
        merge_plan: tuple = ()
        if graph.merge is not None:
            # The canonical merge tree (DESIGN.md §16): per-location chains,
            # then a root chain over the per-location values.  Backends that
            # fold location chains elsewhere (the cluster's peer exchange)
            # materialize those groups as their own "fold" units via the
            # _remote_fold_plan hook; every other backend keeps one merge
            # unit that folds along the same tree in a single dispatch.
            plan = fold_plan((u.index, u.location) for u in units)
            remote_groups = set(self._remote_fold_plan(graph, units, plan))
            merge_deps: list[int] = []
            merge_plan_groups: list[tuple[int, tuple[int, ...]]] = []
            for loc, members in plan:
                if members in remote_groups and len(members) > 1:
                    fu = _Unit(
                        index=len(units),
                        location=loc,
                        tasks=(),
                        run=None,
                        deps=members,
                        kind="fold",
                        fold_group=members,
                        origin=units[members[0]].tasks[0]
                        if units[members[0]].tasks
                        else None,
                        merge=graph.merge,
                    )
                    units.append(fu)
                    fold_units.append(fu)
                    merge_plan_groups.append((loc, (len(merge_deps),)))
                    merge_deps.append(fu.index)
                else:
                    merge_plan_groups.append(
                        (loc, tuple(range(len(merge_deps), len(merge_deps) + len(members))))
                    )
                    merge_deps.extend(members)
            merge_plan = tuple(merge_plan_groups)
            merge_unit = _Unit(
                index=len(units),
                location=-1,
                tasks=(),
                run=None,
                deps=tuple(merge_deps),
                kind="merge",
            )
            units.append(merge_unit)
        state = _SchedulerState(units, report=report)
        state.merge_key = graph.merge.key if graph.merge is not None else None
        if merge_unit is not None:
            for fu in fold_units:
                # Driver-side fallback (and the JobServer path): the same
                # chain the worker-side fold runs — bit-identical either way.
                def run_fold(members=fu.fold_group):
                    partials = [state.results[i] for i in members]
                    return _merge_partials(self.engine, graph.merge, partials)

                fu.run = run_fold
            deps = merge_unit.deps

            def run_merge():
                partials = [state.results[i] for i in deps]
                return _merge_partials(
                    self.engine, graph.merge, partials, plan=merge_plan
                )

            merge_unit.run = run_merge
        return units, state, merge_unit

    def _remote_fold_plan(
        self, graph: TaskGraph, units: list[_Unit], plan: tuple
    ) -> tuple[tuple[int, ...], ...]:
        """Fold groups to materialize as standalone units (backend hook).

        Default: none — the merge unit folds the whole plan itself.  The
        cluster backend returns the multi-member groups whose chains should
        run worker-side over the peer-exchange data plane (DESIGN.md §16),
        and marks their member units ``publish``.
        """
        return ()

    def _schedule(self, graph: TaskGraph) -> Any:
        """Run a TaskGraph through the shared dependency-driven core.

        One implementation for every backend: plan dispatch units (hook),
        append the merge as a unit depending on all of them, drain the
        ready set (hook) with per-unit profiling.  Returns the merged value
        when the graph has a merge, else the per-task partials in plan
        order.
        """
        units, state, merge_unit = self._build_units(graph)
        if units:
            self._drain(state)
        if state.errors:
            raise state.errors[0]
        if merge_unit is not None:
            return state.results[merge_unit.index]
        return list(state.results)

    def _acquire_unit(self, unit: _Unit) -> None:
        """Resolve hook before dispatch: pin the unit's chunk operands.

        Pins are refcounted eviction guards — while the unit runs, the
        residency-budget eviction of its store(s) must not drop buffers the
        ``operands()`` closure is about to (or did just) resolve.  Units of
        non-chunked inputs carry no refs and the hook is free.

        The pin routes through the store protocol, so it covers shared
        memory too: a :class:`~repro.api.shm.ShmStore`-backed chunk's pin
        guards its *segment* against budget eviction for the round-trip
        (the cluster backend additionally pins the shm descriptors its
        dispatch exported — see ``ClusterExecutor``).
        """
        for task in unit.tasks:
            for ref in task.chunk_refs:
                ref.store.pin(ref)

    def _release_unit(self, unit: _Unit) -> None:
        """Release hook after dispatch: unpin, making the chunks evictable.

        Once ``run()`` returned, the dispatched program holds its own
        (device) buffers, so the store copies may be spilled — this unpin
        is what lets a streaming pass shed partition *k* while *k+1* loads.
        """
        for task in unit.tasks:
            for ref in task.chunk_refs:
                ref.store.unpin(ref)

    def _run_unit(self, unit: _Unit, state: _SchedulerState) -> list[_Unit]:
        """Profiled execution of one ready unit; returns newly-ready units.

        When the state carries its own report (a pipelined entry), the
        unit's dispatches/merges/traces bill that report via the engine's
        thread-local binding — several overlapped graphs each keep exact
        per-execute accounting no matter which thread runs what.
        """
        if state.report is not None:
            with self.engine.bind_report(state.report):
                return self._run_unit_inner(unit, state)
        return self._run_unit_inner(unit, state)

    def _run_unit_inner(self, unit: _Unit, state: _SchedulerState) -> list[_Unit]:
        try:
            self._acquire_unit(unit)
            try:
                t0 = time.perf_counter()
                value = unit.run()
                t1 = time.perf_counter()
                if self.profile.sync:
                    value = jax.block_until_ready(value)
                wall = time.perf_counter() - t0
            finally:
                self._release_unit(unit)
            self.profile.record_tasks(
                unit.tasks,
                kind=unit.kind,
                location=unit.location,
                dispatch_s=t1 - t0,
                wall_s=wall,
            )
        except BaseException as e:  # noqa: BLE001 — re-raised by _schedule
            state.fail(e)
            return []
        return state.complete(unit, value)

    def _drain(self, state: _SchedulerState) -> None:
        """Run ready units to completion (backend hook; default: inline)."""
        q = collections.deque(state.initial_ready())
        while q and not state.errors:
            q.extend(self._run_unit(q.popleft(), state))


class LocalExecutor(_PlanExecutor):
    """Sequential dispatch on the calling thread — the seed TaskEngine path."""


def _default_local(engine: TaskEngine | None = None) -> "LocalExecutor":
    """The library's internal default backend, constructed warning-free.

    App entry points and ``Collection.compute`` fall back to a
    LocalExecutor when no executor is passed; that fallback is the
    library's own idiom, not user code reaching for a deprecated
    constructor, so it must not trip the factory-redirection warning.
    """
    with _factory_construction():
        return LocalExecutor(engine=engine)


class _LocationWorker:
    """A persistent worker thread draining one location's job queue."""

    def __init__(self, name: str):
        self._jobs: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            job()

    def submit(self, job: Callable[[], None]) -> None:
        self._jobs.put(job)

    def stop(self) -> None:
        """Post the poison pill and JOIN: a worker that ran jax work must not
        still be alive during XLA runtime teardown (C++ terminate at exit)."""
        self._jobs.put(None)
        self._thread.join(timeout=5.0)


# Live worker-owning executors (ThreadedExecutor pools, StreamExecutor
# prefetchers), closed at interpreter exit so instances that were never
# explicitly close()d don't leave threads that ran jax work alive into
# XLA runtime teardown.
_LIVE_POOLS: "weakref.WeakSet" = None  # set below


def _close_live_pools() -> None:
    for ex in list(_LIVE_POOLS or ()):
        ex.close()


class ThreadedExecutor(_PlanExecutor):
    """One persistent worker thread per location: overlapped dispatch.

    Workers are created lazily per location id and REUSED across ``execute``
    calls, so iterative workloads pay thread startup once per executor
    lifetime instead of once per iteration.  Call :meth:`close` (or rely on
    daemon threads at interpreter exit) to stop them.

    Determinism: the shared scheduler core indexes partials by unit
    position and the merge unit folds them in plan order (on whichever
    worker completed the last dependency), so the value is bit-identical
    to :class:`LocalExecutor` regardless of thread timing.

    Pipelined (``execute_async``): submissions overlap push-style — gated
    units are submitted to the location workers from the completion
    callbacks of their cross-iteration predecessors, so iteration *k+1*
    starts on a location the moment *k* finishes there.  The pipelined
    path always routes through the worker pool (never the single-location
    inline fallback below, which would serialize the overlap on the
    submitting thread).
    """

    _pipelined = True

    def __init__(self, engine: TaskEngine | None = None):
        super().__init__(engine)
        self._workers: dict[int, _LocationWorker] = {}
        _LIVE_POOLS.add(self)

    def _worker(self, location: int) -> _LocationWorker:
        w = self._workers.get(location)
        if w is None:
            w = self._workers[location] = _LocationWorker(f"repro-loc-{location}")
            # Workers respawn after close(): re-register for the atexit
            # sweep so a reused-then-abandoned executor is still joined
            # before XLA teardown.
            _LIVE_POOLS.add(self)
        return w

    def _drain(self, state: _SchedulerState) -> None:
        locations = {u.location for u in state.units if u.location >= 0}
        cur = threading.current_thread()
        nested = any(w._thread is cur for w in self._workers.values())
        if len(locations) <= 1 or nested:
            # Single location — or a nested compute() issued from inside one
            # of our own workers (e.g. a map_partitions callback): submitting
            # to the pool from a pool thread would deadlock the single-thread
            # location queue, so run inline on the calling thread instead.
            return super()._drain(state)
        for u in state.initial_ready():
            self._submit_unit(u, state)
        state.done.wait()

    def _submit_unit(self, unit: _Unit, state: _SchedulerState) -> None:
        if unit.location < 0:
            # Placement-free unit (the merge): run on the thread that
            # unblocked it — jax dispatch is thread-safe and the fold order
            # is fixed by unit indices, so the result stays deterministic.
            self._step(unit, state)
        else:
            self._worker(unit.location).submit(
                lambda: self._step(unit, state)
            )

    def _step(self, unit: _Unit, state: _SchedulerState) -> None:
        for nxt in self._run_unit(unit, state):
            self._submit_unit(nxt, state)

    def _start_entry(
        self, entry: _PipelineEntry, prev: _PipelineEntry | None
    ) -> None:
        state = entry.state

        def launch(unit: _Unit) -> None:
            if not state.errors:  # poisoned entries stop launching
                self._submit_unit(unit, state)

        self._gate_units(entry, prev, launch)

    def _on_pool_thread(self) -> bool:
        cur = threading.current_thread()
        return any(w._thread is cur for w in self._workers.values())

    def execute_async(self, plan: ExecutionPlan) -> ComputeFuture:
        if self._on_pool_thread():
            # Nested submission from inside one of our own units (e.g. a
            # map_partitions callback): pipelining through the pool would
            # queue work behind the very unit that is waiting for it.
            return self._sync_future(plan)
        return super().execute_async(plan)

    def _drain_pipeline(self) -> None:
        if self._on_pool_thread():
            # A pool thread must not block on entries whose units are
            # queued on itself; the pool keeps draining them regardless.
            return
        super()._drain_pipeline()

    def close(self) -> None:
        """Stop the worker pool (idempotent; workers respawn on next use)."""
        # In-flight pipelined entries need the workers to finish; drain
        # BEFORE stopping the pool (super().close() re-drains: no-op).
        self._drain_pipeline()
        for w in self._workers.values():
            w.stop()
        self._workers.clear()
        super().close()


_LIVE_POOLS = weakref.WeakSet()
atexit.register(_close_live_pools)
