"""Executor backends — run an :class:`~repro.api.plan.ExecutionPlan`.

The seed's ``run_map_reduce`` hard-wired execution strategy selection into
one function; this module splits it into an :class:`Executor` contract with
two backends:

:class:`LocalExecutor`
    The seed :class:`~repro.core.engine.TaskEngine` behaviour, refactored:
    sequential dispatch on the calling thread, with the same
    dispatch/trace/bytes accounting in :class:`~repro.core.engine.EngineReport`.
:class:`ThreadedExecutor`
    One worker thread per *location*, overlapping per-partition (or
    per-block) task dispatch across locations — the first step toward
    genuinely concurrent location-parallel execution.  Partials are
    collected by task index and merged in plan order, so results are
    bit-identical to :class:`LocalExecutor`.

Both backends cache the *prepared* form of ``(inputs, policy)`` — the
partition structure, or the rechunked arrays with their traffic bill — so
iterative workloads pay the split/rechunk cost once (paper §6.3.1) without
app-level special casing.

Executors also expose the engine-level ``task()`` registration for app
stages that do not fit the map/reduce plan shape (k-NN's lookup/merge
loops, Cascade SVM's binary cascade), and a ``scope()`` context manager
that accumulates plan executions plus custom task dispatches into a single
report.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import time
from typing import Any, Callable, Hashable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import ExecutionPlan, MapReduceSpec
from repro.api.policy import Baseline, ExecutionPolicy, Rechunk, SplIter
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport, TaskEngine
from repro.core.rechunk import rechunk
from repro.core.spliter import spliter

__all__ = [
    "ComputeResult",
    "PartitionView",
    "Executor",
    "LocalExecutor",
    "ThreadedExecutor",
]


@dataclasses.dataclass
class ComputeResult:
    """What ``Collection.compute`` returns: the value plus its cost report."""

    value: Any
    report: EngineReport

    def __iter__(self):
        # Allow ``value, report = plan.compute(...)`` unpacking.
        yield self.value
        yield self.report


@dataclasses.dataclass(frozen=True)
class PartitionView:
    """A single-location group of aligned blocks, as seen by map_partitions.

    Generalizes :class:`~repro.core.spliter.Partition` to multi-input plans
    (e.g. Cascade SVM's aligned points+labels) and to the Baseline policy,
    where every block is its own single-block partition.
    """

    arrays: tuple[BlockedArray, ...]
    location: int
    block_ids: tuple[int, ...]

    @property
    def blocks(self) -> list[jax.Array]:
        """Blocks of the first (or only) input array."""
        return self.blocks_of(0)

    def blocks_of(self, i: int) -> list[jax.Array]:
        return [self.arrays[i].blocks[b] for b in self.block_ids]

    @property
    def num_rows(self) -> int:
        return int(sum(self.arrays[0].block_rows[b] for b in self.block_ids))

    @property
    def item_indexes(self) -> np.ndarray:
        """Global row ids of every element (paper §4.1 ``get_item_indexes``)."""
        x = self.arrays[0]
        offs = x.row_offsets()
        rows = x.block_rows
        return np.concatenate(
            [np.arange(offs[b], offs[b] + rows[b], dtype=np.int64) for b in self.block_ids]
        )

    @property
    def materialized(self) -> tuple[jax.Array, ...]:
        """Local concat of each input's blocks — intra-location copy only."""
        return tuple(
            jnp.concatenate(self.blocks_of(i), axis=0) for i in range(len(self.arrays))
        )


@runtime_checkable
class Executor(Protocol):
    """The contract every execution backend satisfies (DESIGN.md §5)."""

    def execute(self, plan: ExecutionPlan) -> ComputeResult: ...

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable: ...

    @property
    def report(self) -> EngineReport: ...


@dataclasses.dataclass(frozen=True)
class _Group:
    """Prepared task group: which blocks one task consumes, and where."""

    location: int
    block_ids: tuple[int, ...]


@dataclasses.dataclass
class _Prepared:
    """Cached result of applying a policy to a set of inputs.

    ``inputs`` retains the original arrays: the cache key uses their ids,
    so the entry must pin them alive — otherwise a gc'd input whose id is
    reused by a new BlockedArray would silently hit a stale entry.
    """

    inputs: tuple[BlockedArray, ...]
    arrays: tuple[BlockedArray, ...]
    groups: list[_Group]


def _partition_body(block_fn: Callable, combine: Callable, n_in: int) -> Callable:
    """The fused per-partition task (paper Listing 5 as a ``lax.scan``)."""

    def partition_task(*operands):
        data, extra = operands[:n_in], operands[n_in:]

        def body(acc, blk):
            p = block_fn(*blk, *extra)
            return combine(acc, p), None

        first = block_fn(*(s[0] for s in data), *extra)
        acc, _ = jax.lax.scan(body, first, jax.tree.map(lambda s: s[1:], data))
        return acc

    return partition_task


def _merge_partials(engine: TaskEngine, combine: Callable, partials: list[Any]) -> Any:
    """Single merge task over the stacked partials (paper's @reduction task)."""

    def merge(stacked):
        def body(acc, p):
            return combine(acc, p), None

        first = jax.tree.map(lambda s: s[0], stacked)
        rest = jax.tree.map(lambda s: s[1:], stacked)
        acc, _ = jax.lax.scan(body, first, rest)
        return acc

    if len(partials) == 1:
        return partials[0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *partials)
    out = engine.task(merge, key=("merge", combine))(stacked)
    engine.report.merges += 1
    return out


class _PlanExecutor:
    """Shared plan normalization/prepare/merge; subclasses choose scheduling."""

    def __init__(self, engine: TaskEngine | None = None):
        self.engine = engine or TaskEngine()
        self._prepare_cache: dict[tuple, _Prepared] = {}
        self._scope_depth = 0

    # -- engine passthroughs -------------------------------------------------

    @property
    def report(self) -> EngineReport:
        return self.engine.report

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        return self.engine.task(fn, key=key)

    @contextlib.contextmanager
    def scope(self, mode: str):
        """Accumulate plan executions + custom dispatches into one report."""
        report = self.engine.new_report(mode)
        self._scope_depth += 1
        t0 = time.perf_counter()
        try:
            yield report
        finally:
            self._scope_depth -= 1
            report.wall_s = time.perf_counter() - t0

    # -- the Executor entry point --------------------------------------------

    def execute(self, plan: ExecutionPlan) -> ComputeResult:
        spec = plan.spec
        own_report = self._scope_depth == 0
        if own_report:
            report = self.engine.new_report(spec.policy.mode_name)
        else:
            report = self.engine.report
        t0 = time.perf_counter()

        prepared = self._prepare(spec.inputs, spec.policy, report)
        if spec.kind == "map_partitions":
            tasks = self._partition_view_tasks(spec, prepared)
        else:
            tasks = self._map_block_tasks(spec, prepared)
        partials = self._run(tasks)
        if spec.combine is not None:
            value = _merge_partials(self.engine, spec.combine, partials)
        else:
            value = partials
        value = jax.block_until_ready(value)

        if own_report:
            report.wall_s = time.perf_counter() - t0
        return ComputeResult(value=value, report=report)

    # -- prepare: policy -> (arrays, task groups), cached ---------------------

    def _prepare(
        self,
        inputs: tuple[BlockedArray, ...],
        policy: ExecutionPolicy,
        report: EngineReport,
    ) -> _Prepared:
        key = (tuple(id(a) for a in inputs), policy)
        hit = self._prepare_cache.get(key)
        if hit is not None:
            return hit

        x0 = inputs[0]
        if isinstance(policy, Rechunk):
            target = policy.target_rows or math.ceil(x0.num_rows / x0.num_locations)
            arrays = []
            for a in inputs:
                na, st = rechunk(a, target)
                report.bytes_moved += st.bytes_moved
                arrays.append(na)
            arrays = tuple(arrays)
            groups = [
                _Group(int(arrays[0].placements[i]), (i,))
                for i in range(arrays[0].num_blocks)
            ]
        elif isinstance(policy, SplIter):
            parts = spliter(x0, partitions_per_location=policy.partitions_per_location)
            arrays = inputs
            groups = [_Group(p.location, p.block_ids) for p in parts]
        elif isinstance(policy, Baseline):
            arrays = inputs
            groups = [
                _Group(int(x0.placements[i]), (i,)) for i in range(x0.num_blocks)
            ]
        else:  # pragma: no cover
            raise TypeError(f"unknown policy {policy!r}")

        prepared = _Prepared(inputs=inputs, arrays=arrays, groups=groups)
        self._prepare_cache[key] = prepared
        return prepared

    # -- task construction -----------------------------------------------------

    def _map_block_tasks(self, spec: MapReduceSpec, prepared: _Prepared):
        engine = self.engine
        arrays, groups = prepared.arrays, prepared.groups
        extra = spec.extra_args
        n_in = len(arrays)
        pol = spec.policy
        tasks: list[tuple[int, Callable[[], Any]]] = []

        if isinstance(pol, SplIter) and not pol.materialize and spec.combine is not None:
            # Fused iteration: ONE dispatch scanning the partition's local
            # blocks, carrying the partition-local reduction.  Ragged tails
            # scan per same-shape run — at most one extra dispatch per tail.
            t = engine.task(
                _partition_body(spec.fn, spec.combine, n_in),
                key=("part", spec.fn, spec.combine, n_in),
            )
            for g in groups:
                by_shape: dict[tuple, list[int]] = {}
                for b in g.block_ids:
                    by_shape.setdefault(arrays[0].blocks[b].shape, []).append(b)
                for ids in by_shape.values():
                    def thunk(ids=tuple(ids), t=t):
                        stacks = tuple(
                            jnp.stack([a.blocks[b] for b in ids], axis=0)
                            for a in arrays
                        )
                        return t(*stacks, *extra)

                    tasks.append((g.location, thunk))
        elif isinstance(pol, SplIter) and pol.materialize:
            # Materialized partition (paper §7): local concat, one call.
            t = engine.task(spec.fn, key=("block", spec.fn))
            for g in groups:
                def thunk(g=g, t=t):
                    bufs = tuple(
                        jnp.concatenate([a.blocks[b] for b in g.block_ids], axis=0)
                        for a in arrays
                    )
                    return t(*bufs, *extra)

                tasks.append((g.location, thunk))
        else:
            # Baseline / Rechunk (single-block groups), or an un-reduced
            # SplIter map: one dispatch per block.  Emitted in GLOBAL block
            # order so an un-reduced compute() returns partials aligned
            # with the blocking regardless of policy/partition layout.
            t = engine.task(spec.fn, key=("block", spec.fn))
            placed = sorted(
                (b, g.location) for g in groups for b in g.block_ids
            )
            for b, loc in placed:
                def thunk(b=b, t=t):
                    return t(*(a.blocks[b] for a in arrays), *extra)

                tasks.append((loc, thunk))
        return tasks

    def _partition_view_tasks(self, spec: MapReduceSpec, prepared: _Prepared):
        arrays = prepared.arrays
        tasks = []
        for g in prepared.groups:
            view = PartitionView(arrays=arrays, location=g.location, block_ids=g.block_ids)
            tasks.append((g.location, lambda view=view: spec.fn(view)))
        return tasks

    # -- scheduling (backend-specific) ----------------------------------------

    def _run(self, tasks: list[tuple[int, Callable[[], Any]]]) -> list[Any]:
        raise NotImplementedError


class LocalExecutor(_PlanExecutor):
    """Sequential dispatch on the calling thread — the seed TaskEngine path."""

    def _run(self, tasks):
        return [thunk() for _, thunk in tasks]


class ThreadedExecutor(_PlanExecutor):
    """One worker thread per location: overlapped per-partition dispatch.

    Determinism: partials land in a results list indexed by task position
    and the merge runs in plan order on the calling thread, so the value is
    bit-identical to :class:`LocalExecutor` regardless of thread timing.
    """

    def _run(self, tasks):
        by_loc: dict[int, list[tuple[int, Callable[[], Any]]]] = {}
        for i, (loc, thunk) in enumerate(tasks):
            by_loc.setdefault(loc, []).append((i, thunk))
        if len(by_loc) <= 1:
            return [thunk() for _, thunk in tasks]

        results: list[Any] = [None] * len(tasks)
        errors: list[BaseException] = []

        def worker(items):
            try:
                for i, thunk in items:
                    results[i] = thunk()
            except BaseException as e:  # noqa: BLE001 — re-raised on the caller
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(items,), daemon=True)
            for items in by_loc.values()
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return results
