"""Executor backends — the scheduling half of the execution layer.

Execution is split into two explicit stages (DESIGN.md §5):

1. **lowering** (:mod:`repro.api.lowering`): ``(ExecutionPlan, policy,
   backend capabilities)`` → a frozen :class:`~repro.api.lowering.TaskGraph`
   of placed, keyed task descriptors — all fusion/task-construction
   decisions happen there;
2. **scheduling** (this module): an :class:`Executor` prepares the policy's
   placement (cached, LRU-bounded), lowers the plan against its declared
   :class:`~repro.api.lowering.Capabilities`, and schedules the TaskGraph.

Backends:

:class:`LocalExecutor`
    Sequential dispatch on the calling thread, with the seed's
    dispatch/trace/bytes accounting in :class:`~repro.core.engine.EngineReport`.
:class:`ThreadedExecutor`
    A persistent worker thread per *location* (created on first use, reused
    across ``execute`` calls so iterative workloads don't pay thread startup
    per iteration), overlapping per-partition dispatch across locations.
    Partials are collected by task index and merged in plan order, so
    results are bit-identical to :class:`LocalExecutor`.
:class:`~repro.api.mesh_executor.MeshExecutor`
    Sharded dispatch over a JAX device mesh (own module).

Executors also expose the engine-level ``task()`` registration for app
stages that do not fit the map/reduce plan shape (k-NN's lookup/merge
loops, Cascade SVM's binary cascade), and a ``scope()`` context manager
that accumulates plan executions plus custom task dispatches into a single
report.
"""

from __future__ import annotations

import atexit
import collections
import contextlib
import dataclasses
import math
import queue
import threading
import time
import weakref
from typing import Any, Callable, Hashable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.lowering import (
    Capabilities,
    MergeSpec,
    PartitionView,
    PlacedGroup,
    Task,
    TaskGraph,
    lower,
)
from repro.api.plan import ExecutionPlan
from repro.api.policy import Baseline, ExecutionPolicy, Rechunk, SplIter
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport, TaskEngine
from repro.core.rechunk import rechunk
from repro.core.spliter import spliter

__all__ = [
    "ComputeResult",
    "PartitionView",
    "Executor",
    "LocalExecutor",
    "ThreadedExecutor",
]


@dataclasses.dataclass
class ComputeResult:
    """What ``Collection.compute`` returns: the value plus its cost report."""

    value: Any
    report: EngineReport

    def __iter__(self):
        # Allow ``value, report = plan.compute(...)`` unpacking.
        yield self.value
        yield self.report


@runtime_checkable
class Executor(Protocol):
    """The contract every execution backend satisfies (DESIGN.md §5)."""

    def execute(self, plan: ExecutionPlan) -> ComputeResult: ...

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable: ...

    @property
    def report(self) -> EngineReport: ...


@dataclasses.dataclass
class _Prepared:
    """Cached result of applying a policy to a set of inputs.

    ``inputs`` retains the original arrays: the cache key uses their ids,
    so the entry must pin them alive — otherwise a gc'd input whose id is
    reused by a new BlockedArray would silently hit a stale entry.  The
    cache itself is a small LRU (see ``_PlanExecutor._prepare``) so a
    long-lived executor pins at most ``prepare_cache_size`` datasets, not
    every dataset it ever saw.
    """

    inputs: tuple[BlockedArray, ...]
    arrays: tuple[BlockedArray, ...]
    groups: list[PlacedGroup]


def _merge_partials(engine: TaskEngine, merge: MergeSpec, partials: list[Any]) -> Any:
    """Single merge task over the stacked partials (paper's @reduction task).

    Keyed by the MergeSpec's stable key — NOT the combine object, which apps
    typically recreate per call — so iterative workloads hit the jit cache.
    """
    combine = merge.combine

    def merge_fn(stacked):
        def body(acc, p):
            return combine(acc, p), None

        first = jax.tree.map(lambda s: s[0], stacked)
        rest = jax.tree.map(lambda s: s[1:], stacked)
        acc, _ = jax.lax.scan(body, first, rest)
        return acc

    if len(partials) == 1:
        return partials[0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *partials)
    out = engine.task(merge_fn, key=merge.key)(stacked)
    engine.report.merges += 1
    return out


class _PlanExecutor:
    """Shared prepare/lower/merge; subclasses schedule the TaskGraph."""

    #: bound on cached (inputs, policy) preparations (LRU eviction).
    prepare_cache_size: int = 8

    def __init__(self, engine: TaskEngine | None = None):
        self.engine = engine or TaskEngine()
        self._prepare_cache: collections.OrderedDict[tuple, _Prepared] = (
            collections.OrderedDict()
        )
        self._scope_depth = 0

    # -- backend capabilities (consumed by the lowering pass) -----------------

    @property
    def capabilities(self) -> Capabilities:
        # prefer_pallas is resolved lazily: compiled Pallas beats the scan on
        # TPU, interpret mode does not — and querying the backend at import
        # time would lock jax device state before tests can set XLA_FLAGS.
        return Capabilities(
            name=type(self).__name__,
            prefer_pallas=jax.default_backend() == "tpu",
        )

    # -- engine passthroughs -------------------------------------------------

    @property
    def report(self) -> EngineReport:
        return self.engine.report

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        return self.engine.task(fn, key=key)

    @contextlib.contextmanager
    def scope(self, mode: str):
        """Accumulate plan executions + custom dispatches into one report."""
        report = self.engine.new_report(mode)
        self._scope_depth += 1
        t0 = time.perf_counter()
        try:
            yield report
        finally:
            self._scope_depth -= 1
            report.wall_s = time.perf_counter() - t0

    # -- the Executor entry point --------------------------------------------

    def execute(self, plan: ExecutionPlan) -> ComputeResult:
        spec = plan.spec
        own_report = self._scope_depth == 0
        if own_report:
            report = self.engine.new_report(spec.policy.mode_name)
        else:
            report = self.engine.report
        t0 = time.perf_counter()

        prepared = self._prepare(spec.inputs, spec.policy, report)
        graph = lower(spec, prepared.arrays, prepared.groups, self.capabilities)
        partials = self._schedule(graph)
        if graph.merge is not None:
            value = _merge_partials(self.engine, graph.merge, partials)
        else:
            value = partials
        value = jax.block_until_ready(value)

        if own_report:
            report.wall_s = time.perf_counter() - t0
        return ComputeResult(value=value, report=report)

    def lower(self, plan: ExecutionPlan) -> TaskGraph:
        """Lower a plan for this backend without running it (inspection)."""
        spec = plan.spec
        prepared = self._prepare(spec.inputs, spec.policy, self.engine.report)
        return lower(spec, prepared.arrays, prepared.groups, self.capabilities)

    # -- prepare: policy -> (arrays, task groups), LRU-cached ------------------

    def _prepare(
        self,
        inputs: tuple[BlockedArray, ...],
        policy: ExecutionPolicy,
        report: EngineReport,
    ) -> _Prepared:
        key = (tuple(id(a) for a in inputs), policy)
        hit = self._prepare_cache.get(key)
        if hit is not None:
            self._prepare_cache.move_to_end(key)
            return hit

        x0 = inputs[0]
        if isinstance(policy, Rechunk):
            target = policy.target_rows or math.ceil(x0.num_rows / x0.num_locations)
            arrays = []
            for a in inputs:
                na, st = rechunk(a, target)
                report.bytes_moved += st.bytes_moved
                arrays.append(na)
            arrays = tuple(arrays)
            groups = [
                PlacedGroup(int(arrays[0].placements[i]), (i,))
                for i in range(arrays[0].num_blocks)
            ]
        elif isinstance(policy, SplIter):
            parts = spliter(x0, partitions_per_location=policy.partitions_per_location)
            arrays = inputs
            groups = [PlacedGroup(p.location, p.block_ids) for p in parts]
        elif isinstance(policy, Baseline):
            arrays = inputs
            groups = [
                PlacedGroup(int(x0.placements[i]), (i,)) for i in range(x0.num_blocks)
            ]
        else:  # pragma: no cover
            raise TypeError(f"unknown policy {policy!r}")

        prepared = _Prepared(inputs=inputs, arrays=arrays, groups=groups)
        self._prepare_cache[key] = prepared
        while len(self._prepare_cache) > self.prepare_cache_size:
            self._prepare_cache.popitem(last=False)
        return prepared

    # -- scheduling (backend-specific) ----------------------------------------

    def _bind(self, task: Task) -> Callable[[], Any]:
        """A nullary thunk running one task through the engine's jit cache."""
        if not task.counted:
            return lambda: task.fn(*task.operands())
        t = self.engine.task(task.fn, key=task.key)
        return lambda: t(*task.operands())

    def _schedule(self, graph: TaskGraph) -> list[Any]:
        raise NotImplementedError


class LocalExecutor(_PlanExecutor):
    """Sequential dispatch on the calling thread — the seed TaskEngine path."""

    def _schedule(self, graph: TaskGraph) -> list[Any]:
        return [self._bind(t)() for t in graph.tasks]


class _LocationWorker:
    """A persistent worker thread draining one location's job queue."""

    def __init__(self, name: str):
        self._jobs: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            job()

    def submit(self, job: Callable[[], None]) -> None:
        self._jobs.put(job)

    def stop(self) -> None:
        """Post the poison pill and JOIN: a worker that ran jax work must not
        still be alive during XLA runtime teardown (C++ terminate at exit)."""
        self._jobs.put(None)
        self._thread.join(timeout=5.0)


# Live pools, closed at interpreter exit so executors that were never
# explicitly close()d don't leave worker threads running into teardown.
_LIVE_POOLS: "weakref.WeakSet[ThreadedExecutor]" = None  # set below


def _close_live_pools() -> None:
    for ex in list(_LIVE_POOLS or ()):
        ex.close()


class ThreadedExecutor(_PlanExecutor):
    """One persistent worker thread per location: overlapped dispatch.

    Workers are created lazily per location id and REUSED across ``execute``
    calls, so iterative workloads pay thread startup once per executor
    lifetime instead of once per iteration.  Call :meth:`close` (or rely on
    daemon threads at interpreter exit) to stop them.

    Determinism: partials land in a results list indexed by task position
    and the merge runs in plan order on the calling thread, so the value is
    bit-identical to :class:`LocalExecutor` regardless of thread timing.
    """

    def __init__(self, engine: TaskEngine | None = None):
        super().__init__(engine)
        self._workers: dict[int, _LocationWorker] = {}
        _LIVE_POOLS.add(self)

    def _worker(self, location: int) -> _LocationWorker:
        w = self._workers.get(location)
        if w is None:
            w = self._workers[location] = _LocationWorker(f"repro-loc-{location}")
        return w

    def _schedule(self, graph: TaskGraph) -> list[Any]:
        thunks = [self._bind(t) for t in graph.tasks]
        by_loc: dict[int, list[tuple[int, Callable[[], Any]]]] = {}
        for i, t in enumerate(graph.tasks):
            by_loc.setdefault(t.location, []).append((i, thunks[i]))
        cur = threading.current_thread()
        nested = any(w._thread is cur for w in self._workers.values())
        if len(by_loc) <= 1 or nested:
            # Single location — or a nested compute() issued from inside one
            # of our own workers (e.g. a map_partitions callback): submitting
            # to the pool from a pool thread would deadlock the single-thread
            # location queue, so run inline on the calling thread instead.
            return [thunk() for thunk in thunks]

        results: list[Any] = [None] * len(thunks)
        errors: list[BaseException] = []
        done = threading.Event()
        remaining = [len(by_loc)]
        lock = threading.Lock()

        def run(items):
            try:
                for i, thunk in items:
                    results[i] = thunk()
            except BaseException as e:  # noqa: BLE001 — re-raised on the caller
                errors.append(e)
            finally:
                with lock:
                    remaining[0] -= 1
                    if remaining[0] == 0:
                        done.set()

        for loc, items in by_loc.items():
            self._worker(loc).submit(lambda items=items: run(items))
        done.wait()
        if errors:
            raise errors[0]
        return results

    def close(self) -> None:
        """Stop the worker pool (idempotent; workers respawn on next use)."""
        for w in self._workers.values():
            w.stop()
        self._workers.clear()


_LIVE_POOLS = weakref.WeakSet()
atexit.register(_close_live_pools)
