"""Worker-process main loop for :class:`~repro.api.cluster_executor.ClusterExecutor`.

Each worker is a **spawn**-started process (fork is unsafe under JAX/XLA)
that owns one logical *location* of the cluster.  It drains a byte-framed
pickle protocol from its command connection and writes replies to its own
reply connection — per-worker pipes, NOT a shared queue, because a worker
that dies mid-write (exactly what fault injection does) must only be able
to corrupt *its own* channel: the parent reads the torn end as EOF and
buries that worker, while every other worker's replies keep flowing.  (A
shared ``multiprocessing.Queue`` fails this: a killed producer can leave
the common pipe locked/torn for everyone.)

parent → worker (either one bare message, or ``("batch", [messages])`` —
the parent coalesces a scheduling sweep's commands into one send)
    ``("attach", StoreManifest)`` — build an
    :class:`~repro.api.chunkstore.AttachedStore` so later units can
    resolve :class:`~repro.api.chunkstore.ChunkHandle` payloads; a second
    attach for the same store is a *delta* of a grown store and merges
    into the existing attach (bytes never transit the control channel);
    ``("unit", epoch, TaskSpec, attempt)`` — execute one task descriptor;
    ``("call", epoch, call_id, fn_ref, args, key)`` — execute one
    driver-level task RPC (the ``executor.task()`` path);
    ``("steal", token, ((epoch, index), ...))`` — a steal probe: grant
    every listed unit still sitting *unstarted* in the local queue back
    to the parent (reply ``steal_ok``); anything already started or
    finished is silently kept — exactly-once by construction;
    ``("stop",)`` — exit cleanly.

Work stealing (DESIGN.md §15): a batched send can park several units in
the worker's local queue, so the main loop keeps a pending deque and
polls the command channel between unit executions — that poll is where
steal probes are answered, bounding probe latency by one unit's wall
time.  A granted unit is removed from the queue *before* any of its
work runs, so a steal can never double-execute; the parent re-dispatches
granted units to the idle thief with their shared-memory descriptors
(a steal moves descriptors, not bytes).

worker → parent, over the worker's own reply connection (each message
pre-pickled so the parent can bill exact ``ipc_bytes``)
    ``("ready", wid, pid)``, ``("hb", wid, t)`` — liveness;
    ``("unit_done", wid, epoch, index, result, loaded, shm_wrote)`` /
    ``("unit_error", wid, epoch, index, err)`` — unit outcomes;
    ``("call_done", wid, epoch, call_id, result, shm_wrote)`` /
    ``("call_error", wid, epoch, call_id, err)`` — RPC outcomes.

The shared-memory data plane (:mod:`repro.api.shm`): operand payloads may
arrive as ``ShmBlockRef`` descriptors, resolved zero-copy against
read-only attachments of the parent's segments.  Results above
``result_min_bytes`` travel back the same way — packed into ONE fresh
segment per reply named ``<result_prefix><seq>`` (the parent unlinks it
on consume, or sweeps the prefix if this worker dies first);
``shm_wrote`` in the reply bills the copied bytes to the parent's
``EngineReport.shm_bytes``.

Determinism: the worker rebuilds exactly the stack/concat + function the
in-process lowering would have dispatched (same jnp ops, same fold order,
same host), so a replayed unit — or the same unit on a different worker —
produces bit-identical partials.  That is the Chunks-and-Tasks replay
story: fault tolerance is "run the pure task descriptor again".

Fault injection (tests / the CI fault lane): ``kill_after`` makes the
worker ``os._exit`` on *receiving* its nth dispatch (the unit is lost
in-flight, exercising requeue); ``kill_on_retry`` does the same when it
receives an already-replayed unit (exercising retry exhaustion);
``mute_after`` silences heartbeats and hangs (exercising the
heartbeat-timeout detector while the process stays alive); ``slow_s``
sleeps before every unit execution — the deterministic straggler hook
the elastic bench and chaos harness slow one worker with.  Dispatch
counts are per unit/call message, so a fault keyed on "the nth dispatch"
fires identically whether the commands arrived batched or one by one.
"""

from __future__ import annotations

import collections
import os
import pickle
import threading
import time
import traceback

__all__ = ["worker_main"]

#: exit codes used by injected faults (visible in worker logs / waitpid)
KILLED_EXIT = 23
RETRY_KILLED_EXIT = 24


def _log_line(log, wid: int, msg: str) -> None:
    if log is not None:
        log.write(f"[w{wid} +{time.monotonic():.3f}] {msg}\n")
        log.flush()


def _resolve_fn(fn_ref: tuple, cache: dict):
    """Rehydrate + jit a task function from its picklable reference."""
    fn = cache.get(fn_ref)
    if fn is not None:
        return fn
    import jax

    from repro.api.fnref import decode_fn

    kind = fn_ref[0]
    if kind == "scan":
        from repro.api.lowering import _partition_body

        _, efn, ecomb, n_in = fn_ref
        body = _partition_body(decode_fn(efn), decode_fn(ecomb), n_in)
    elif kind == "fold":
        # A peer-exchange merge chain: the same stacked_fold program the
        # driver's merge task jits — separate jit, same HLO, same bits.
        from repro.api.lowering import stacked_fold

        body = stacked_fold(decode_fn(fn_ref[1]))
    elif kind == "kernel":
        from repro.api.kernels import kernel_from_ref

        kernel = kernel_from_ref(fn_ref[1])
        if kernel is None:
            raise RuntimeError(f"no registered kernel for {fn_ref[1]!r}")
        body = kernel.fn
    elif kind == "fn":
        body = decode_fn(fn_ref[1])
    else:
        raise RuntimeError(f"unknown fn_ref kind {kind!r}")
    fn = cache[fn_ref] = jax.jit(body)
    return fn


def _build_operands(kind: str, data: tuple, extras: tuple, stores: dict, shm_att):
    """Payloads → operand tuple, mirroring the in-process lowering exactly.

    Stacked kinds (``partition_scan``/``partition_pallas``) stack the
    blocks on a new leading axis, ``partition_materialized`` concatenates,
    ``block`` passes the single block through.  Returns the operands plus
    the chunk bytes read from spill files (billed upstream as
    ``bytes_loaded`` — shared-memory resolutions move no file bytes and
    bill nothing).
    """
    import jax.numpy as jnp

    from repro.api.chunkstore import ChunkHandle, ChunkStoreError
    from repro.api.shm import ShmBlockRef

    def resolve(b):
        nonlocal loaded
        if isinstance(b, ChunkHandle):
            store = stores.get(b.store_uid)
            if store is None:
                raise ChunkStoreError(f"store {b.store_uid} not attached")
            entry = store.manifest.chunks.get(b.chunk_id)
            if entry is not None and entry[0] == "file":
                loaded += b.nbytes
            return store.resolve(b)
        if isinstance(b, ShmBlockRef):
            return jnp.asarray(shm_att.view(b))  # zero-copy off the pipe
        return jnp.asarray(b)

    loaded = 0
    ops = []
    for blocks in data:
        arrs = [resolve(b) for b in blocks]
        if kind in ("partition_scan", "partition_pallas"):
            ops.append(jnp.stack(arrs, axis=0))
        elif kind == "partition_materialized":
            ops.append(jnp.concatenate(arrs, axis=0))
        else:
            ops.append(arrs[0])
    ops.extend(resolve(e) for e in extras)
    return tuple(ops), loaded


def worker_main(
    worker_id: int,
    location: int,
    conn,
    reply_conn,
    *,
    heartbeat_s: float = 0.2,
    kill_after: int | None = None,
    kill_on_retry: bool = False,
    mute_after: int | None = None,
    slow_s: float | None = None,
    log_path: str | None = None,
    result_prefix: str | None = None,
    result_min_bytes: int = 1024,
) -> None:
    """Entry point of one cluster worker process."""
    log = open(log_path, "a") if log_path else None
    _log_line(log, worker_id, f"start pid={os.getpid()} location={location}")

    reply_lock = threading.Lock()  # main thread + heartbeat thread share the pipe

    def reply(msg) -> None:
        payload = pickle.dumps(msg)
        with reply_lock:
            reply_conn.send_bytes(payload)

    stop_beat = threading.Event()

    def beat() -> None:
        while not stop_beat.is_set():
            try:
                reply(("hb", worker_id, time.time()))
            except (OSError, ValueError):  # parent gone / pipe torn down
                return
            stop_beat.wait(heartbeat_s)

    threading.Thread(target=beat, name="hb", daemon=True).start()
    reply(("ready", worker_id, os.getpid()))

    import numpy as np  # deferred: keep the pre-ready window minimal

    from repro.api import shm as shm_mod

    shm_att = shm_mod.ShmAttachments()
    fns: dict = {}
    stores: dict = {}
    dispatches = 0
    reply_seq = 0

    def to_host(tree):
        import jax

        return jax.tree.map(np.asarray, tree)

    def pack(tree, *, publish=None):
        """Large reply leaves → one fresh segment; (tree, bytes_copied).

        ``publish`` overrides the segment name and drops the size floor to
        0: a published partial (peer exchange, DESIGN.md §16) must land at
        the deterministic name the driver derived — addressed by unit
        key/epoch/attempt, never by worker id, so replays and steals
        publish to the same place — and must pack EVERY leaf, because a
        sibling attaches the segment instead of reading the reply.
        """
        nonlocal reply_seq
        if result_prefix is None:
            return tree, 0
        if publish is not None:
            packed, _seg, wrote = shm_mod.pack_tree(tree, threshold=0, name=publish)
            return packed, wrote
        reply_seq += 1
        packed, _seg, wrote = shm_mod.pack_tree(
            tree,
            threshold=result_min_bytes,
            name=f"{result_prefix}{reply_seq}",
        )
        return packed, wrote

    #: unit/call messages received but not yet executed — the local queue
    #: steal probes are answered against.
    pending: collections.deque = collections.deque()

    def handle_steal(msg) -> None:
        """Grant every probed unit still unstarted in the local queue.

        Exactly-once hinges on ordering: a unit is granted only while its
        message is still in ``pending`` — removal here happens before any
        of its work runs, and a unit already popped (running or finished)
        is silently kept, so the parent's grant list and this worker's
        execution set can never overlap.
        """
        _, token, wants = msg
        want = set(wants)
        granted = []
        kept: collections.deque = collections.deque()
        for qm in pending:
            if qm[0] == "unit" and (qm[1], qm[2].index) in want:
                granted.append((qm[1], qm[2].index))
            elif qm[0] == "fold" and (qm[1], qm[2]) in want:
                granted.append((qm[1], qm[2]))
            else:
                kept.append(qm)
        pending.clear()
        pending.extend(kept)
        reply(("steal_ok", worker_id, token, tuple(granted)))
        _log_line(
            log,
            worker_id,
            f"steal probe token={token} wants={len(wants)} "
            f"granted={len(granted)}",
        )

    def handle(msg) -> bool:
        """Execute one unit/call message; False means exit the main loop."""
        nonlocal dispatches
        kind = msg[0]

        dispatches += 1
        if mute_after is not None and dispatches >= mute_after:
            _log_line(log, worker_id, "FAULT: muting heartbeats and hanging")
            stop_beat.set()
            while True:  # injected hang: only the parent's timeout saves us
                time.sleep(3600)
        if kill_after is not None and dispatches >= kill_after:
            _log_line(log, worker_id, f"FAULT: killing on dispatch #{dispatches}")
            os._exit(KILLED_EXIT)

        if kind == "unit":
            _, epoch, spec, attempt = msg[:4]
            publish = msg[4] if len(msg) > 4 else None
            if kill_on_retry and attempt > 0:
                _log_line(
                    log, worker_id, f"FAULT: killing on retried unit {spec.index}"
                )
                os._exit(RETRY_KILLED_EXIT)
            if slow_s:
                time.sleep(slow_s)  # injected straggler: 10×-ish per unit
            try:
                fn = _resolve_fn(spec.fn_ref, fns)
                ops, loaded = _build_operands(
                    spec.kind, spec.data, spec.extras, stores, shm_att
                )
                out, wrote = pack(to_host(fn(*ops)), publish=publish)
                reply(
                    ("unit_done", worker_id, epoch, spec.index, out, loaded, wrote)
                )
                _log_line(
                    log,
                    worker_id,
                    f"unit {spec.index} kind={spec.kind} blocks={spec.block_ids} "
                    f"attempt={attempt} ok"
                    + (f" published={publish}" if publish else ""),
                )
            except BaseException:
                err = traceback.format_exc()
                _log_line(log, worker_id, f"unit {spec.index} FAILED\n{err}")
                reply(("unit_error", worker_id, epoch, spec.index, err))
        elif kind == "fold":
            # Peer exchange (DESIGN.md §16): fold a sibling-published merge
            # chain in place.  The operands are packed ref trees the driver
            # forwarded — attach each published segment read-only, stack,
            # and run the SAME jitted stacked_fold chain the driver's merge
            # task would have run, so the partial is bit-identical however
            # the subtree was routed.  Unlink stays with the driver's lease.
            _, epoch, index, attempt, combine_ref, key_repr, trees = msg
            if kill_on_retry and attempt > 0:
                _log_line(log, worker_id, f"FAULT: killing on retried fold {index}")
                os._exit(RETRY_KILLED_EXIT)
            if slow_s:
                time.sleep(slow_s)
            try:
                import jax
                import jax.numpy as jnp

                fold = _resolve_fn(("fold", combine_ref), fns)
                partials = [
                    jax.tree.map(jnp.asarray, shm_mod.attach_tree(t, shm_att))
                    for t in trees
                ]
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *partials)
                out, wrote = pack(to_host(fold(stacked)))
                reply(("unit_done", worker_id, epoch, index, out, 0, wrote))
                _log_line(
                    log,
                    worker_id,
                    f"fold {index} key={key_repr} fan_in={len(trees)} "
                    f"attempt={attempt} ok",
                )
            except BaseException:
                err = traceback.format_exc()
                _log_line(log, worker_id, f"fold {index} FAILED\n{err}")
                reply(("unit_error", worker_id, epoch, index, err))
        elif kind == "call":
            _, epoch, call_id, fn_ref, args, key = msg
            try:
                fn = _resolve_fn(fn_ref, fns)
                import jax.numpy as jnp

                from repro.api.shm import ShmBlockRef

                ops = (
                    jnp.asarray(shm_att.view(a))
                    if isinstance(a, ShmBlockRef)
                    else jnp.asarray(a)
                    for a in args
                )
                out, wrote = pack(to_host(fn(*ops)))
                reply(("call_done", worker_id, epoch, call_id, out, wrote))
                _log_line(log, worker_id, f"call {call_id} key={key} ok")
            except BaseException:
                err = traceback.format_exc()
                _log_line(log, worker_id, f"call {call_id} key={key} FAILED\n{err}")
                reply(("call_error", worker_id, epoch, call_id, err))
        else:
            _log_line(log, worker_id, f"unknown message {kind!r}; ignoring")
        return True

    def ingest(payload) -> bool:
        """Route one received message; False means stop was seen.

        Control traffic (attach, steal probes, stop) is handled inline so
        it takes effect ahead of queued work; unit/call messages append to
        ``pending`` in arrival order — execution order equals receive
        order minus whatever a steal removed.
        """
        msg = pickle.loads(payload)
        for m in msg[1] if msg[0] == "batch" else (msg,):
            kind = m[0]
            if kind == "stop":
                _log_line(log, worker_id, "stop")
                return False
            if kind == "attach":
                manifest = m[1]
                from repro.api.chunkstore import AttachedStore

                store = stores.get(manifest.uid)
                if store is not None:
                    store.merge(manifest)  # a grown store's delta
                else:
                    stores[manifest.uid] = AttachedStore(manifest)
                _log_line(
                    log,
                    worker_id,
                    f"attach store={manifest.uid} chunks={len(manifest.chunks)}",
                )
            elif kind == "steal":
                handle_steal(m)
            else:
                pending.append(m)
        return True

    running = True
    while running:
        if pending:
            # Between units: drain whatever control traffic has arrived —
            # this is where steal probes are answered, so probe latency is
            # bounded by one unit's wall time.
            try:
                while running and conn.poll(0):
                    running = ingest(conn.recv_bytes())
            except (EOFError, OSError):
                _log_line(log, worker_id, "command channel closed; exiting")
                break
            if not running or not pending:
                continue
            if not handle(pending.popleft()):
                running = False
        else:
            try:
                payload = conn.recv_bytes()
            except EOFError:
                _log_line(log, worker_id, "command channel closed; exiting")
                break
            running = ingest(payload)

    stop_beat.set()
    shm_att.close()  # release our mappings; unlink stays the parent's job
    for store in stores.values():
        store.close()
    if log is not None:
        log.close()
