"""ClusterExecutor — multi-process, fault-tolerant scheduling of a TaskGraph.

The fifth backend of the execution layer (DESIGN.md §11) and the first
where dispatch crosses a real serialization/IPC boundary: the shared
scheduler core (:meth:`~repro.api.executors._PlanExecutor._schedule`) runs
in the parent, but task units execute in **spawn-based worker processes**,
one per logical location by default.  What crosses the control channel is
a DuctTeip-style *cheap task descriptor* — the picklable
:class:`~repro.api.lowering.TaskSpec` projection (code reference via
:mod:`repro.api.fnref` / the named kernel registry, geometry, operand
payloads) — never a closure.

Locality (the paper's placement story, now with real transport costs):

* units route to the worker that owns their partition's location, reusing
  the ``PlacedGroup`` placement metadata the SplIter prepare derived;
* chunk-backed plans hand off their :class:`~repro.api.chunkstore.DiskStore`
  via shm-first, *incremental* manifests
  (:meth:`~repro.api.chunkstore.DiskStore.manifest`): resident chunks
  export as shared-memory descriptors, already-spilled chunks reuse their
  files, and a grown store ships only the delta — workers resolve
  :class:`~repro.api.chunkstore.ChunkHandle`\\ s against an attached
  per-worker store, so block bytes never transit the control channel;
* ``EngineReport`` bills the boundary: ``ipc_bytes`` (exact serialized
  control-channel bytes both directions), ``shm_bytes`` (block bytes
  copied into shared memory, once per block), ``remote_dispatches`` and
  ``retries``.

The data plane (:mod:`repro.api.shm`): operands and large worker partials
cross as ``ShmBlockRef`` descriptors over POSIX shared memory instead of
pickled bytes.  The driver owns segment lifecycle — its arena
(:class:`~repro.api.shm.ShmStore`) caches exports so iterative plans copy
each block once, reply segments are unlinked the moment a partial is
consumed (or discarded as stale), a dead worker's undelivered reply
segments are swept by name prefix, and :meth:`close` unlinks everything.
When shared memory is unavailable (or ``shm=False``), every payload falls
back to the PR 5 pickle/spill-file paths unchanged.

Flow control: the parent keeps at most ONE un-replied *send* in flight
per worker.  The drain sweep stages ready units per target and flushes
each worker's staging queue as a single batched ``send_bytes`` (small
command descriptors amortize per-message pipe overhead); a batch is only
flushed to a worker with an empty window, so every send still targets a
worker that is parked in ``recv`` — the ~64KB-pipe deadlock guard from
the PR 5 hardening.  Busy targets are deferred past the next reply pump,
and driver RPCs flush pending batches, then pump until their target's
window clears.

Fault tolerance (the Chunks-and-Tasks deterministic-replay model):

* workers heartbeat on the shared reply queue; the drain loop doubles as
  supervisor, detecting death by process liveness or heartbeat staleness
  (an injected :class:`FaultPlan` drives both paths in tests);
* a dead worker's in-flight units are disowned through the scheduler
  state's :meth:`~repro.api.executors._SchedulerState.requeue` hook, their
  chunk pins released, and the units replayed on a surviving worker —
  task descriptors are pure, so the replay is bit-identical;
* a unit that out-lives ``max_retries`` replays poisons the run with a
  typed :class:`ClusterFailedError` naming the task key.

Driver-level stages (``executor.task`` — k-NN's lookup/merge loops,
Cascade SVM's cascade) ship over the same channel as synchronous RPCs
when their function is referencable, so even ``map_partitions``-shaped
apps pay (and report) real IPC dispatch costs; unreferencable callables
fall back to in-process dispatch transparently.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import multiprocessing
import os
import pickle
import time
from multiprocessing import connection
from typing import Any, Callable, Hashable

import jax
import numpy as np

from repro.api import shm
from repro.api.autotune import should_fold_remote, should_steal
from repro.api.chunkstore import ChunkHandle, StoreManifest, chunk_stores, resolve_chunk
from repro.api.shm import ShmBlockRef, ShmStore, shm_available
from repro.api.executors import (
    _LIVE_POOLS,
    _PlanExecutor,
    _SchedulerState,
    _Unit,
    _tree_nbytes,
)
from repro.api.fnref import encode_fn
from repro.api.lowering import Capabilities, key_summary, stable_task_key
from repro.core.engine import TaskEngine

__all__ = ["ClusterExecutor", "ClusterFailedError", "FaultPlan", "ChaosSchedule"]

#: task kinds that may execute in a worker process; everything else
#: (merge folds, driver-view callbacks) stays in the parent.
_REMOTE_KINDS = frozenset(
    {"block", "partition_scan", "partition_pallas", "partition_materialized"}
)


class ClusterFailedError(RuntimeError):
    """A task exhausted its replays (or the pool died under it).

    ``task_key`` names the poisoned task so operators can tell *which*
    work item keeps killing workers, not just that something did.
    ``attempts`` is the per-attempt history — one ``{"worker", "error"}``
    dict per failed attempt, in order, each carrying the worker id and a
    one-line cause summary ("process died", "hung (heartbeat stale)", or
    the remote exception's first line).  ``log_paths`` lists the involved
    workers' log files when worker logging is on (the ``log_dir``
    argument, or the ``REPRO_CLUSTER_LOG_DIR`` environment default), so a
    poisoned run points straight at the evidence.
    """

    def __init__(
        self,
        message: str,
        *,
        task_key: str | None = None,
        attempts: tuple = (),
        log_paths: tuple = (),
    ):
        if attempts:
            lines = [
                f"  attempt {i + 1}: worker {a['worker']}: {a['error']}"
                for i, a in enumerate(attempts)
            ]
            message = message + "\nattempt history:\n" + "\n".join(lines)
        if log_paths:
            message += "\nworker logs: " + ", ".join(log_paths)
        super().__init__(message)
        self.task_key = task_key
        self.attempts = tuple(attempts)
        self.log_paths = tuple(log_paths)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault injection for tests and the CI fault lane.

    Worker ids of the initial pool equal their location id, so
    ``FaultPlan(kill_after=((0, 2),))`` means "location 0's worker exits
    upon receiving its 2nd dispatch".  Respawned workers get fresh ids
    and never inherit a fault.

    Attributes:
      kill_after: ``((worker_id, nth_dispatch), ...)`` — ``os._exit``
        on *receiving* the nth dispatch, losing it in flight.
      kill_on_retry: worker ids that exit when handed an already-replayed
        unit (drives retry exhaustion → :class:`ClusterFailedError`).
      mute_after: ``((worker_id, nth_dispatch), ...)`` — stop heartbeats
        and hang, exercising the heartbeat-staleness detector.
      slow: ``((worker_id, seconds), ...)`` — sleep before every unit
        execution: the deterministic straggler hook the elastic bench and
        chaos harness use to make one worker ~10× slower.

    >>> FaultPlan(kill_after=((0, 1),)).kill_after_for(0)
    1
    >>> FaultPlan().kill_after_for(0) is None
    True
    """

    kill_after: tuple = ()
    kill_on_retry: tuple = ()
    mute_after: tuple = ()
    slow: tuple = ()

    def kill_after_for(self, worker_id: int) -> int | None:
        return dict(self.kill_after).get(worker_id)

    def mute_after_for(self, worker_id: int) -> int | None:
        return dict(self.mute_after).get(worker_id)

    def slow_for(self, worker_id: int) -> float | None:
        return dict(self.slow).get(worker_id)


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """Seeded, reproducible chaos for the elastic cluster (tests / CI).

    Extends :class:`FaultPlan` injection with the elasticity axes: from
    one integer seed it derives (a) a fault plan that kills some initial
    workers mid-run and slows another into a straggler — the steal
    trigger — and (b) a per-round grow/shrink action sequence the harness
    applies between executes.  Everything is a pure function of the
    constructor arguments (``random.Random`` seeded with ints, never
    wall-clock), so a failing seed replays bit-identically in CI and at a
    desk.

    >>> ChaosSchedule(seed=11).actions() == ChaosSchedule(seed=11).actions()
    True
    >>> ChaosSchedule(seed=11).fault_plan() == ChaosSchedule(seed=11).fault_plan()
    True
    """

    seed: int
    rounds: int = 4
    workers: tuple = (0, 1)
    kill_rate: float = 0.5
    slow_rate: float = 0.5
    slow_s: float = 0.02

    def _rng(self, salt: int):
        import random

        return random.Random((self.seed + 1) * 1_000_003 + salt)

    def fault_plan(self) -> FaultPlan:
        """Kills and stragglers for the initial pool, derived from the seed.

        At most one initial worker is killed (on a dispatch in the first
        few) and at most one *other* worker is slowed — a schedule that
        killed everything at once would only ever test the respawn path.
        """
        rng = self._rng(0)
        kills = []
        slows = []
        wids = list(self.workers)
        if wids and rng.random() < self.kill_rate:
            kills.append((rng.choice(wids), rng.randint(1, 4)))
        candidates = [w for w in wids if w not in dict(kills)]
        if candidates and rng.random() < self.slow_rate:
            slows.append((rng.choice(candidates), self.slow_s))
        return FaultPlan(kill_after=tuple(kills), slow=tuple(slows))

    def actions(self) -> tuple[str, ...]:
        """One pool action per round: ``"grow"``, ``"shrink"`` or ``"none"``.

        Shrink never outruns growth (the pool cannot shrink below its
        location owners anyway — :meth:`ClusterExecutor.shrink` respawns
        owners on demand), and the first round always runs the un-scaled
        pool so every schedule covers the baseline too.
        """
        rng = self._rng(1)
        out = ["none"]
        grown = 0
        for _ in range(1, self.rounds):
            roll = rng.random()
            if roll < 0.4:
                out.append("grow")
                grown += 1
            elif roll < 0.7 and grown > 0:
                out.append("shrink")
                grown -= 1
            else:
                out.append("none")
        return tuple(out)


class _WorkerHandle:
    """Parent-side handle: process + command/reply connections + fault config.

    Each worker gets its OWN reply pipe (no shared queue): a worker killed
    mid-write can only tear its own channel, which the parent reads as
    EOF and folds into the death path — the other workers' replies keep
    flowing.
    """

    def __init__(
        self,
        wid: int,
        location: int,
        ctx,
        *,
        heartbeat_s: float,
        fault: FaultPlan | None,
        log_dir: str | None,
        result_prefix: str | None = None,
        result_min_bytes: int = 1024,
    ):
        self.id = wid
        self.location = location
        self.log_path = (
            os.path.join(log_dir, f"worker-{wid}.log") if log_dir else None
        )
        # Name prefix for the worker's reply segments; the parent sweeps
        # it when the worker dies with undelivered replies.
        self.result_prefix = result_prefix
        cmd_recv, cmd_send = ctx.Pipe(duplex=False)
        rep_recv, rep_send = ctx.Pipe(duplex=False)
        self._conn = cmd_send
        self.reply = rep_recv
        from repro.api import cluster_worker

        self.process = ctx.Process(
            target=cluster_worker.worker_main,
            args=(wid, location, cmd_recv, rep_send),
            kwargs=dict(
                heartbeat_s=heartbeat_s,
                kill_after=fault.kill_after_for(wid) if fault else None,
                kill_on_retry=bool(fault and wid in fault.kill_on_retry),
                mute_after=fault.mute_after_for(wid) if fault else None,
                slow_s=fault.slow_for(wid) if fault else None,
                log_path=self.log_path,
                result_prefix=result_prefix,
                result_min_bytes=result_min_bytes,
            ),
            name=f"repro-cluster-w{wid}",
            daemon=True,
        )
        self.process.start()
        cmd_recv.close()  # child owns these ends now
        rep_send.close()

    def alive(self) -> bool:
        return self.process.is_alive()

    def send(self, msg) -> int:
        """Pickle + send one command; returns the exact serialized size.

        Pickling errors propagate untouched — only the transport write
        (``OSError`` out of :meth:`send_raw`) signals worker death.
        """
        return self.send_raw(pickle.dumps(msg))

    def send_raw(self, payload: bytes) -> int:
        self._conn.send_bytes(payload)
        return len(payload)

    def stop(self, timeout: float = 5.0) -> None:
        try:
            self.send(("stop",))
        except (OSError, ValueError):
            pass  # already dead / connection torn down
        self.process.join(timeout)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(timeout)
        for conn in (self._conn, self.reply):
            try:
                conn.close()
            except OSError:  # pragma: no cover
                pass


class _DrainContext:
    """Per-graph scheduling context, registered in the executor's
    epoch-keyed ``_contexts`` map.

    One context per in-flight TaskGraph: the synchronous ``execute`` path
    opens exactly one for the duration of its drain, while pipelined
    submissions (DESIGN.md §14) keep one open per unresolved entry — the
    reply pump routes each unit reply to its context by epoch, so two
    iterations' units can interleave on the same worker pool with their
    costs billed to the right per-execute report.
    """

    def __init__(self, state: _SchedulerState, epoch: int, report):
        self.state = state
        self.epoch = epoch
        self.report = report
        self.ready: collections.deque[_Unit] = collections.deque()
        self.replays: collections.deque[_Unit] = collections.deque()
        self.inflight: dict[int, _Unit] = {}
        self.meta: dict[int, tuple] = {}  # unit index -> (t0_send, send_seconds)
        # unit index -> shm refs this dispatch exported: segment pins that
        # must drop on reply, requeue, or drain teardown.
        self.shm_pins: dict[int, tuple] = {}
        # unit index -> [{"worker", "error", "log"}, ...]: one entry per
        # FAILED attempt, consumed by ClusterFailedError on poison.
        self.history: dict[int, list[dict]] = {}
        # unit index -> SegmentLease over that unit's *published* partial
        # (peer exchange, DESIGN.md §16).  The driver owns every published
        # segment through these leases: a lease is released when the
        # sibling fold consumes it (billing p2p_bytes), when the fold is
        # localized back into the driver, or — the backstop — when the
        # context closes, so no publish outlives its graph.
        self.leases: dict[int, shm.SegmentLease] = {}

    def record_failure(
        self, index: int, wid: int, error: str, log_path: str | None
    ) -> None:
        self.history.setdefault(index, []).append(
            {"worker": wid, "error": error, "log": log_path}
        )

    def error_kwargs(self, index: int) -> dict:
        """attempts/log_paths keyword payload for a ClusterFailedError."""
        attempts = tuple(self.history.get(index, ()))
        return {
            "attempts": attempts,
            "log_paths": tuple(
                dict.fromkeys(a["log"] for a in attempts if a["log"])
            ),
        }


class ClusterExecutor(_PlanExecutor):
    """Schedule TaskGraphs over a pool of spawn-based worker processes.

    Args:
      engine: shared :class:`TaskEngine` (parent-side accounting + the jit
        cache used by in-process units such as the merge).
      max_retries: replays a unit may consume across worker deaths before
        the run fails with :class:`ClusterFailedError`.
      heartbeat_s: worker heartbeat period.
      heartbeat_timeout_s: silence span after which a live-looking process
        is declared dead (hung worker); generous by default so loaded CI
        hosts don't false-positive.
      fault_plan: injected :class:`FaultPlan` (tests / the CI fault lane).
      log_dir: directory for per-worker log files (created if needed);
        None disables worker logging.  The CI fault lane sets this and
        uploads the logs as artifacts on failure.
      poll_s: supervisor tick — reply-queue wait quantum between liveness
        checks.
      shm: use the shared-memory data plane (:mod:`repro.api.shm`) for
        operand and partial transport.  ``None`` (default) enables it
        when the host supports POSIX shared memory, honoring the
        ``REPRO_CLUSTER_SHM=0`` kill switch; ``False`` forces the PR 5
        pickle/spill-file paths (useful for A/B-measuring ``ipc_bytes``).
      shm_min_bytes: payloads below this ship inline — a descriptor
        round-trip is not worth it for tiny arrays.
      shm_segment_bytes: arena segment size of the driver's
        :class:`~repro.api.shm.ShmStore`.
      shm_budget_bytes: cap on live segment bytes (default 256 MiB, or
        the ``REPRO_SHM_BUDGET`` environment variable).  Exhaustion falls
        back to inline/spill-file transport, never to an error.
      p2p: peer-to-peer partial exchange (DESIGN.md §16).  ``"auto"``
        (default) lets the :func:`~repro.api.autotune.should_fold_remote`
        cost gate decide per execute, fed by an observed per-merge-key
        partial-size EMA — small partials keep the pinned driver-merge
        path, structurally identical to before.  ``True`` forces
        worker-side folds whenever the plan and data plane allow;
        ``False`` disables the mechanism outright.  When active, each
        multi-member fold-plan group's partials are *published* as named
        shared-memory segments a sibling worker attaches directly, the
        per-location merge chain runs worker-side as its own ``fold``
        unit, and the driver receives ONE merged value per location —
        ``EngineReport.p2p_bytes`` bills the bytes that skipped the
        driver, ``driver_merge_bytes`` the bytes that did not.
      p2p_min_bytes: ``auto``-gate floor — observed partials below this
        never leave the pinned path (a descriptor round-trip is not worth
        it for tiny accumulators).
      steal: enable work stealing (DESIGN.md §15): an idle worker takes
        queued units off an overloaded sibling when the cost model says
        remote fetch beats the expected wait.  Off by default — steal
        counts are timing-dependent, and the default pool must stay
        structurally deterministic for the bench baselines.
      autoscale: enable the autoscaler: the pool grows *roamer* workers
        (no partition to own; fed purely by stealing) when queue depth
        outruns the live workers, and shrinks them again — planned
        preemption through the requeue/replay path — once they idle.
        Implies ``steal``.
      min_workers / max_workers: autoscaler pool bounds (defaults: 1 and
        ``os.cpu_count()``).
      scale_up_backlog: grow when queued-behind-running units exceed this
        many per live worker.
      scale_idle_ticks: consecutive idle supervisor ticks before a roamer
        is preempted (ticks, not seconds — deterministic under test).

    Elasticity accounting: successful steals bill
    ``EngineReport.steals`` and append to :attr:`steal_log`; grow/shrink
    bill ``EngineReport.scale_events`` and append to :attr:`scale_log`;
    every replay billed to ``retries`` appends to :attr:`retry_log` — the
    chaos harness cross-checks report sums against these event logs
    exactly.

    Workers spawn lazily (first dispatch needing their location) and are
    reused across ``execute`` calls; :meth:`close` is idempotent (it
    unlinks every shared-memory segment) and also runs from the shared
    atexit sweep.

    Pipelined iteration (DESIGN.md §14): ``execute_async`` keeps up to
    ``pipeline_depth`` submissions in flight, each with its own
    :class:`_DrainContext`; the reply pump routes unit replies to their
    context by epoch, so iteration k+1's units dispatch the moment their
    same-partition k predecessors reply — no global drain between
    executes.  All driving happens on the submitting (driver) thread:
    progress is made whenever the application submits, resolves a future,
    or the executor drains.
    """

    _pipelined = True

    def __init__(
        self,
        engine: TaskEngine | None = None,
        *,
        max_retries: int = 2,
        heartbeat_s: float = 0.2,
        heartbeat_timeout_s: float = 30.0,
        fault_plan: FaultPlan | None = None,
        log_dir: str | None = None,
        poll_s: float = 0.02,
        shm: bool | None = None,
        shm_min_bytes: int = 1024,
        shm_segment_bytes: int = 4 << 20,
        shm_budget_bytes: int | None = None,
        p2p: bool | str = "auto",
        p2p_min_bytes: int = 1 << 16,
        steal: bool = False,
        autoscale: bool = False,
        min_workers: int = 1,
        max_workers: int | None = None,
        scale_up_backlog: int = 2,
        scale_idle_ticks: int = 50,
    ):
        super().__init__(engine)
        self.max_retries = max_retries
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.fault_plan = fault_plan
        self.steal_enabled = bool(steal or autoscale)
        self.autoscale = autoscale
        self.min_workers = min_workers
        self.max_workers = max_workers if max_workers else (os.cpu_count() or 4)
        self.scale_up_backlog = scale_up_backlog
        self.scale_idle_ticks = scale_idle_ticks
        # Env default: the CI fault lane (and any operator) can turn on
        # worker logging for every executor in a process without plumbing
        # the argument through app code.
        self.log_dir = log_dir or os.environ.get("REPRO_CLUSTER_LOG_DIR") or None
        self.poll_s = poll_s
        if shm is None:
            shm = (
                os.environ.get("REPRO_CLUSTER_SHM", "1") != "0" and shm_available()
            )
        if shm_budget_bytes is None:
            shm_budget_bytes = int(os.environ.get("REPRO_SHM_BUDGET", 256 << 20))
        self._shm = (
            ShmStore(
                budget_bytes=shm_budget_bytes,
                segment_bytes=shm_segment_bytes,
                min_bytes=shm_min_bytes,
            )
            if shm
            else None
        )
        self.shm_min_bytes = shm_min_bytes
        self.p2p = p2p
        self.p2p_min_bytes = p2p_min_bytes
        # merge key -> observed partial-size EMA (bytes): the auto gate's
        # evidence.  Populated from unit replies, so an iterative app pays
        # one pinned execute before the gate can switch it to peer folds.
        self._fold_ema: dict[Hashable, float] = {}
        self._fold_refs: dict[Hashable, tuple | None] = {}
        self._ctx = multiprocessing.get_context("spawn")
        self._workers: dict[int, _WorkerHandle] = {}
        self._by_location: dict[int, int] = {}
        self._used_wids: set[int] = set()
        self._next_wid = itertools.count(1000)  # respawns: fresh, fault-free ids
        self._epoch = 0
        self._last_hb: dict[int, float] = {}
        self._manifests: dict[str, Any] = {}
        # (wid, uid) -> chunk ids already shipped: attach messages carry
        # only the manifest delta a worker has not seen.
        self._attached: dict[tuple[int, str], set] = {}
        self._call_seq = itertools.count()
        self._call_results: dict[int, tuple] = {}
        self._pending_calls: set[int] = set()  # issued, not yet resolved
        self._outstanding: dict[int, int] = {}  # wid -> un-replied commands
        # wid -> staged (attach_msgs, unit_msg, unit, ctx) entries, flushed
        # as one batched send per sweep (see _flush_outbox).
        self._outbox: dict[int, list] = {}
        # epoch -> live _DrainContext, in open order.  The sync path keeps
        # exactly one; pipelined submissions keep one per in-flight entry.
        self._contexts: dict[int, _DrainContext] = {}
        # -- elasticity state (DESIGN.md §15) --
        # wid -> send-ordered [(ctx, unit), ...] of un-replied unit
        # dispatches: the victim queue steal probes select from.
        self._dispatch_order: dict[int, list] = {}
        self._steal_probes: dict[int, tuple] = {}  # victim wid -> (token, wants)
        self._steal_seq = itertools.count(1)
        self._roamers: set[int] = set()            # autoscaler-grown workers
        self._idle_ticks: dict[int, int] = {}      # roamer wid -> idle streak
        self._preempting: set[int] = set()         # planned shrinks in progress
        # wid -> observed per-unit service-time EMA (and the last reply /
        # batch-send mark the next sample measures from): the steal gate's
        # per-worker evidence — see _on_reply and _steal_gate.
        self._task_ema: dict[int, float] = {}
        self._reply_mark: dict[int, float] = {}
        # Heartbeat debounce: staleness counts only *observed* silence —
        # time the driver actually spent pumping replies (see
        # _check_workers), so a driver-side stall can't bury idle workers.
        self._last_pump = time.monotonic()
        self._silence: dict[int, float] = {}
        #: event logs the chaos harness cross-checks report counters
        #: against — one entry per billed steal / retry / scale event.
        self.steal_log: list[dict] = []
        self.retry_log: list[dict] = []
        self.scale_log: list[dict] = []
        if log_dir:
            os.makedirs(log_dir, exist_ok=True)
        _LIVE_POOLS.add(self)

    # -- capabilities ---------------------------------------------------------

    @property
    def capabilities(self) -> Capabilities:
        # remote: lowering attaches fn_refs + raw-operand builders.
        # out_of_core: lowering attaches chunk_refs, so the parent pins a
        # unit's chunks for the whole remote round-trip (and releases them
        # on completion OR requeue — the fault-path contract tests assert).
        return dataclasses.replace(
            super().capabilities,
            name=type(self).__name__,
            remote=True,
            out_of_core=True,
            exporter=self._export_block if self._shm is not None else None,
        )

    # -- pool management ------------------------------------------------------

    def workers_alive(self) -> list[int]:
        """Ids of currently-live workers (diagnostics / tests)."""
        return sorted(w.id for w in self._workers.values() if w.alive())

    def _spawn(self, wid: int, location: int) -> _WorkerHandle:
        handle = _WorkerHandle(
            wid,
            location,
            self._ctx,
            heartbeat_s=self.heartbeat_s,
            fault=self.fault_plan,
            log_dir=self.log_dir,
            # "q" separates wid from the reply sequence number, so sweeping
            # worker 5's prefix can never match worker 55's segments.
            result_prefix=(
                f"{self._shm.prefix}w{wid}q" if self._shm is not None else None
            ),
            result_min_bytes=self.shm_min_bytes,
        )
        self._workers[wid] = handle
        self._by_location[location] = wid
        self._last_hb[wid] = time.monotonic()
        self._silence[wid] = 0.0
        _LIVE_POOLS.add(self)  # re-register after a close()
        return handle

    def _worker_for(self, location: int) -> _WorkerHandle:
        """The live worker owning ``location`` (lazily spawned).

        The initial worker for a location takes the location id as its
        worker id — the addressing contract :class:`FaultPlan` relies on.
        Respawns after a death draw fresh ids, so an injected fault fires
        at most once.
        """
        wid = self._by_location.get(location)
        if wid is not None:
            handle = self._workers.get(wid)
            if handle is not None and handle.alive():
                return handle
            self._on_worker_death(wid)
        if location >= 0 and location not in self._used_wids:
            wid = location
        else:
            wid = next(self._next_wid)
        self._used_wids.add(wid)
        return self._spawn(wid, location)

    def _survivor(self, *, not_worker: int | None = None) -> _WorkerHandle | None:
        """A live worker, preferring one whose command window is empty.

        The preference keeps replays and driver RPCs off a worker that is
        mid-unit (they would otherwise wait out its reply) whenever any
        other survivor's window is free.  Among window-free workers the
        one with the lowest observed service-time EMA wins — then the one
        with the least already staged — so replayed (and stolen) units
        batch onto the fastest free worker instead of spreading back onto
        an idle straggler.
        """
        fallback = None
        free: list[_WorkerHandle] = []
        for wid in sorted(self._workers):
            if wid == not_worker:
                continue
            handle = self._workers[wid]
            if not handle.alive():
                continue
            if self._outstanding.get(wid, 0) == 0:
                free.append(handle)
            fallback = fallback or handle
        if free:
            return min(
                free,
                key=lambda h: (
                    self._task_ema.get(h.id, 0.0),
                    len(self._outbox.get(h.id, ())),
                    h.id,
                ),
            )
        return fallback

    # -- the Executor entry points --------------------------------------------

    def _handoff_stores(self, plan) -> None:
        """Hand off chunk stores before scheduling.

        ``manifest()`` is shm-first and incremental: resident chunks
        export as segment descriptors (no disk write), already-spilled
        chunks reuse their files, and a grown store contributes only the
        chunks this driver has not seen — workers then receive exactly
        the per-worker delta through ``_stage_attaches``, so re-attach
        after growth is O(new chunks).
        """
        for store in chunk_stores(plan.spec.inputs):
            manifest = getattr(store, "manifest", None)
            if manifest is None:
                continue  # in-memory store: payloads ship inline
            full = self._manifests.get(store.uid)
            known = frozenset(full.chunks) if full is not None else frozenset()
            delta = manifest(export=self._manifest_export(store), known=known)
            if full is None:
                self._manifests[delta.uid] = delta
            else:
                full.chunks.update(delta.chunks)

    def execute(self, plan):
        self._handoff_stores(plan)
        return super().execute(plan)

    def execute_async(self, plan):
        self._handoff_stores(plan)
        return super().execute_async(plan)

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        """Register a driver-level task; referencable fns dispatch remotely.

        The remote path is a synchronous RPC with the same replay contract
        as plan units: a worker death mid-call re-issues the call on a
        survivor (counted in ``EngineReport.retries``).  Functions the
        reference encoder rejects run in-process via the engine, exactly
        as on every other backend.
        """
        efn = encode_fn(fn)
        if efn is None:
            return self.engine.task(fn, key=key)
        fn_ref = ("fn", efn)
        key_repr = key_summary(key if key is not None else stable_task_key(fn))

        def dispatch(*args):
            return self._remote_call(fn_ref, args, key_repr)

        return dispatch

    # -- the shared-memory data plane -----------------------------------------

    def _export_block(self, block):
        """``Capabilities.exporter`` hook: one operand block as a descriptor.

        Cached by object identity inside the arena, so an iterative plan
        re-dispatching the same blocks copies each one exactly once;
        ``shm_bytes`` bills only genuine copies.  ``None`` (undersized
        block, budget exhausted) sends the caller down the inline path.
        """
        ref, wrote = self._shm.export(
            block, materialize=lambda: np.asarray(resolve_chunk(block))
        )
        if wrote:
            # current_report: exports fire inside a dispatch sweep, which
            # binds the owning context's per-execute report.
            self.engine.current_report.shm_bytes += wrote
        return ref

    def _manifest_export(self, store):
        """Chunk exporter handed to ``DiskStore.manifest`` (None: shm off).

        Manifest entries outlive any single dispatch, so their segments
        are locked against eviction; no size floor — a chunk must be
        worker-readable either way, and a segment at any size beats a
        spill-file write.
        """
        if self._shm is None:
            return None

        def export(cid, arr):
            ref, wrote = self._shm.export(
                arr, key=("chunk", store.uid, cid), min_bytes=0, lock=True
            )
            if wrote:
                self.engine.current_report.shm_bytes += wrote
            return ref

        return export

    # -- remote dispatch ------------------------------------------------------

    def _remotable(self, unit: _Unit) -> bool:
        return (
            len(unit.tasks) == 1
            and unit.kind in _REMOTE_KINDS
            and unit.tasks[0].fn_ref is not None
            and unit.tasks[0].remote_operands is not None
        )

    # -- peer-to-peer partial exchange (DESIGN.md §16) -------------------------

    @staticmethod
    def _unit_origin(unit: _Unit):
        """The app task a failure attributes to: a fold unit names its
        subtree's ORIGINATING task (first member), never the synthetic
        fold — operators must see which work item's merge keeps dying.
        """
        return unit.tasks[0] if unit.tasks else unit.origin

    def _publish_name(self, epoch: int, index: int, attempt: int) -> str:
        """Deterministic segment name for a published partial.

        Addressed by unit identity (epoch/index/attempt), never worker id:
        a stolen or replayed unit publishes to the same place, so the
        sibling fold's ref tree stays valid however the unit was routed.
        The trailing ``z`` terminates the name — sweeping attempt 1's
        segment can never match attempt 10's.
        """
        return f"{self._shm.prefix}p{epoch}x{index}a{attempt}z"

    def _fold_ref(self, merge) -> tuple | None:
        """Cached reference encoding of a merge combine (None: not refable)."""
        if merge.key not in self._fold_refs:
            self._fold_refs[merge.key] = encode_fn(merge.combine)
        return self._fold_refs[merge.key]

    def _note_partial_bytes(self, key: Hashable, nbytes: int) -> None:
        if nbytes <= 0:
            return
        prev = self._fold_ema.get(key)
        self._fold_ema[key] = (
            float(nbytes) if prev is None else 0.5 * prev + 0.5 * nbytes
        )

    def _remote_fold_plan(self, graph, units, plan):
        """Fold-plan groups whose merge chains run worker-side (the hook
        :meth:`~repro.api.executors._PlanExecutor._build_units` consults).

        A group qualifies when every member can dispatch remotely and the
        data plane is up; the whole mechanism then gates on the cost
        model — ``p2p=True`` forces it, ``"auto"`` requires an observed
        partial-size EMA for this merge key that clears
        :func:`~repro.api.autotune.should_fold_remote`.  Selected groups'
        member units are marked ``publish``: their partials stay in named
        shared-memory segments for the sibling fold to attach.
        """
        if not self.p2p or self._shm is None or graph.merge is None:
            return ()
        if self._fold_ref(graph.merge) is None:
            return ()
        groups = tuple(
            members
            for _loc, members in plan
            if len(members) > 1 and all(self._remotable(units[i]) for i in members)
        )
        if not groups:
            return ()
        if self.p2p is not True:  # "auto": observed-size cost gate
            ema = self._fold_ema.get(graph.merge.key)
            if ema is None or not should_fold_remote(
                self._steal_model(),
                partial_bytes=int(ema),
                fan_in=max(len(m) for m in groups),
                min_bytes=self.p2p_min_bytes,
            ):
                return ()
        for members in groups:
            for i in members:
                units[i].publish = True
        return groups

    def _dispatch_fold(
        self,
        unit: _Unit,
        ctx: _DrainContext,
        *,
        prefer_survivor: bool = False,
        target: _WorkerHandle | None = None,
    ) -> bool:
        """Stage one fold unit for a worker (default: its location owner).

        The message carries the members' *packed ref trees* — ~100-byte
        segment descriptors, not partial bytes — plus the combine's code
        reference; the worker attaches each published segment read-only,
        stacks, and runs the same jitted chain the driver's merge task
        would have.  Member leases stay with the driver until the fold's
        reply confirms consumption (see ``_on_reply``), so a death at any
        point leaves every segment owned and sweepable.  Same window
        discipline and False-means-defer contract as ``_dispatch_remote``.
        """
        worker = (
            target
            or (self._survivor() if prefer_survivor else None)
            or self._worker_for(unit.location)
        )
        if ctx.state.errors:
            return True
        if self._outstanding.get(worker.id, 0) > 0:
            return False
        combine_ref = self._fold_ref(unit.merge)
        ref_trees = tuple(ctx.state.results[i] for i in unit.fold_group)
        ctx.state.assign(unit, worker.id)
        attempt = ctx.state.attempts[unit.index] - 1
        msg = (
            "fold",
            ctx.epoch,
            unit.index,
            attempt,
            combine_ref,
            key_summary(unit.merge.key),
            ref_trees,
        )
        self._outbox.setdefault(worker.id, []).append(((), msg, unit, ctx))
        return True

    def _localize_fold(self, unit: _Unit, ctx: _DrainContext) -> None:
        """Pull a fold group's published partials back into the driver.

        The fallback when the fold cannot (or should not) run remotely:
        each member's packed ref tree is unpacked in place — consuming and
        unlinking its segments, releasing the lease WITHOUT billing
        ``p2p_bytes`` (the bytes did cross into the driver) — after which
        the unit's in-process ``run`` closure folds the now-local values
        through ``_merge_partials``, billing ``driver_merge_bytes`` as the
        pinned path would.
        """
        state = ctx.state
        for mi in unit.fold_group:
            if ctx.leases.pop(mi, None) is not None:
                tree, _segs = shm.unpack_tree(state.results[mi])
                state.results[mi] = jax.tree.map(np.asarray, tree)

    def _route_fold(
        self, unit: _Unit, ctx: _DrainContext, *, prefer_survivor: bool = False
    ) -> bool:
        """Dispatch a ready fold unit remotely, or localize and run it here.

        Remote is the point of the mechanism, so it is preferred whenever
        the data plane is up and the combine is referencable; both were
        preconditions for materializing the unit, so localization is the
        defensive path (e.g. every publish declined on a full
        ``/dev/shm``) — correctness never depends on the exchange.
        """
        if self._shm is not None and self._fold_ref(unit.merge) is not None:
            return self._dispatch_fold(unit, ctx, prefer_survivor=prefer_survivor)
        self._localize_fold(unit, ctx)
        ctx.ready.extend(self._run_unit(unit, ctx.state))
        return True

    def _stage_attaches(self, worker: _WorkerHandle, spec) -> list:
        """Attach messages ``worker`` needs before running ``spec``.

        Incremental: ``self._attached`` records which chunk ids each
        worker has already been sent per store, so a store that grew
        mid-session ships only its new entries (the worker's
        ``AttachedStore.merge`` folds them in).  Returned messages are
        staged ahead of the unit in the same batch, preserving order.
        """
        uids = {
            b.store_uid
            for blocks in spec.data
            for b in blocks
            if isinstance(b, ChunkHandle)
        }
        msgs = []
        for uid in sorted(uids):
            manifest = self._manifests.get(uid)
            if manifest is None:
                raise ClusterFailedError(
                    f"no manifest for chunk store {uid}; inputs changed mid-run?"
                )
            seen = self._attached.get((worker.id, uid), frozenset())
            delta = {c: e for c, e in manifest.chunks.items() if c not in seen}
            if not delta:
                continue
            msgs.append(
                ("attach", StoreManifest(uid, manifest.spill_dir, delta))
            )
            self._attached[(worker.id, uid)] = set(manifest.chunks)
        return msgs

    def _await_window(self, worker: _WorkerHandle) -> bool:
        """Pump replies until ``worker`` has no un-replied command in flight.

        The one-command-per-worker window is the deadlock guard for the
        ~64KB OS pipes: a send only ever targets a worker that is parked
        in ``recv`` (nothing outstanding), so the parent cannot block in
        ``send_bytes`` against a worker that is itself blocked writing a
        large reply — the parent always returns here to keep draining
        reply pipes first.  Returns False if the worker died while we
        waited (the caller re-resolves a target).
        """
        while self._outstanding.get(worker.id, 0) > 0:
            if worker.id not in self._workers or not worker.alive():
                self._on_worker_death(worker.id)
                return False
            self._pump()
        return worker.id in self._workers

    def _dispatch_remote(
        self,
        unit: _Unit,
        ctx: _DrainContext,
        *,
        prefer_survivor: bool = False,
        target: _WorkerHandle | None = None,
    ) -> bool:
        """Stage one unit for its location's worker (or any survivor).

        Staging, not sending: the unit's spec is built (operands exported
        to shared memory here), its chunks pinned and ownership assigned,
        and the message queued in ``self._outbox`` — ``_flush_outbox``
        ships each worker's queue as ONE batched send per sweep.

        Returns False — *without* blocking — when the target worker still
        has a command window in flight from an earlier flush: the drain
        sweep defers the unit and retries after the next pump, so a busy
        worker never head-of-line blocks dispatch to idle ones.  Units
        staged to the same idle worker within one sweep become one batch.

        ``prefer_survivor`` is the replay path: a requeued unit goes to a
        worker that is already alive (locality traded for liveness — the
        dead worker's location has no owner anyway); only when the whole
        pool is gone does a fresh worker spawn.  ``target`` pins the
        worker outright — the steal paths use it to hand a unit to a
        specific idle thief.
        """
        task = unit.tasks[0]
        worker = (
            target
            or (self._survivor() if prefer_survivor else None)
            or self._worker_for(unit.location)
        )
        if ctx.state.errors:  # a death inside _worker_for poisoned the run
            return True
        if self._outstanding.get(worker.id, 0) > 0:
            return False  # window full: defer rather than queue behind it
        # Payload errors (unpicklable operand, missing manifest) propagate
        # from these two with nothing pinned or assigned yet.
        spec = task.spec()
        attaches = self._stage_attaches(worker, spec)
        self._acquire_unit(unit)  # pin chunks for the whole round-trip
        # Assign BEFORE the message leaves our hands: a worker death any
        # time after this leaves the unit owned, so the death sweep's
        # requeue returns it for replay instead of losing it.
        ctx.state.assign(unit, worker.id)
        if self._shm is not None:
            refs = tuple(
                b for blocks in spec.data for b in blocks
                if isinstance(b, ShmBlockRef)
            ) + tuple(e for e in spec.extras if isinstance(e, ShmBlockRef))
            if refs:
                self._shm.pin_refs(refs)
                ctx.shm_pins[unit.index] = refs
        attempt = ctx.state.attempts[unit.index] - 1
        msg = ("unit", ctx.epoch, spec, attempt)
        if unit.publish and self._shm is not None:
            # Peer exchange: the worker leaves this unit's partial in a
            # segment at the deterministic name the sibling fold expects.
            msg = msg + (self._publish_name(ctx.epoch, unit.index, attempt),)
        self._outbox.setdefault(worker.id, []).append((attaches, msg, unit, ctx))
        return True

    def _flush_outbox(self) -> None:
        """Ship every staged queue whose target worker's window is empty.

        One ``send_bytes`` per worker carries its attach messages plus all
        units staged this sweep — batching amortizes per-message pipe
        overhead while keeping the flow-control invariant: the single send
        targets a worker with nothing outstanding (parked in ``recv``), so
        the parent can never block in ``send_bytes`` against a worker that
        is itself blocked writing a reply.  ``_outstanding`` then counts
        one window slot per unit in the batch; the window reopens when the
        last reply lands.

        A batch may mix units from several live contexts (pipelined
        iterations sharing a worker); its serialized bytes bill to the
        first staged entry's report — deterministic, and report sums stay
        exact across the pipeline.
        """
        for wid in list(self._outbox):
            if self._outstanding.get(wid, 0) > 0:
                continue  # window busy: flush after its replies land
            worker = self._workers.get(wid)
            if worker is None or not worker.alive():
                self._on_worker_death(wid)  # staged units are assigned: replayed
                continue
            entries = self._outbox.pop(wid)
            msgs = [
                m for attaches, msg, _unit, _ctx in entries for m in (*attaches, msg)
            ]
            payload = pickle.dumps(msgs[0] if len(msgs) == 1 else ("batch", msgs))
            t0 = time.perf_counter()
            try:
                sent = worker.send_raw(payload)
            except OSError:
                # Worker died between the liveness check and the send; the
                # batch's units are assigned, so the death sweep replays
                # them (and releases this dispatch's pins).
                self._on_worker_death(wid)
                continue
            send_s = time.perf_counter() - t0
            self._outstanding[wid] = self._outstanding.get(wid, 0) + len(entries)
            self._reply_mark[wid] = t0  # the batch's first service starts now
            entries[0][3].report.ipc_bytes += sent
            order = self._dispatch_order.setdefault(wid, [])
            for _attaches, _msg, unit, ectx in entries:
                ectx.meta[unit.index] = (t0, send_s)
                ectx.inflight[unit.index] = unit
                order.append((ectx, unit))  # send order = steal candidacy order

    def _open_context(self, state: _SchedulerState, report) -> _DrainContext:
        self._epoch += 1
        ctx = _DrainContext(state, self._epoch, report)
        self._contexts[ctx.epoch] = ctx
        return ctx

    def _close_context(self, ctx: _DrainContext) -> None:
        """Deregister a context; drop every pin its dispatches still hold.

        Error path included: staged-but-unflushed units (an aborted sweep
        can skip a flush) and in-flight units both hold chunk pins and shm
        reference pins — release exactly this context's, leaving sibling
        contexts' staged work untouched.
        """
        for wid, entries in list(self._outbox.items()):
            keep = [e for e in entries if e[3] is not ctx]
            for _attaches, _msg, unit, ectx in entries:
                if ectx is ctx:
                    ctx.inflight.pop(unit.index, None)
                    self._release_unit(unit)
            if keep:
                self._outbox[wid] = keep
            else:
                del self._outbox[wid]
        for unit in ctx.inflight.values():
            self._release_unit(unit)
        ctx.inflight.clear()
        if self._shm is not None:
            for refs in ctx.shm_pins.values():
                self._shm.unpin_refs(refs)
        ctx.shm_pins.clear()
        for wid, order in list(self._dispatch_order.items()):
            kept = [e for e in order if e[0] is not ctx]
            if kept:
                self._dispatch_order[wid] = kept
            else:
                del self._dispatch_order[wid]
        # Unconsumed publish leases (error/abort paths): the driver owns
        # every published segment, so the context takes them down with it.
        for lease in ctx.leases.values():
            shm.unlink_segments(lease.segments)
        ctx.leases.clear()
        self._contexts.pop(ctx.epoch, None)

    def _sweep_context(self, ctx: _DrainContext) -> None:
        """One dispatch sweep: replays first (retry urgency), then fresh
        ready units.  A unit whose target worker still has a command in
        flight is deferred to the next sweep — the pump in between is what
        closes the window again.  Runs under the context's report binding
        so operand exports and in-process dispatches bill per execute.
        """
        state = ctx.state
        with self.engine.bind_report(ctx.report):
            deferred: list[_Unit] = []
            while ctx.replays and not state.errors:
                unit = ctx.replays.popleft()
                if state.is_done(unit.index):
                    continue  # a salvaged duplicate reply beat the replay
                if unit.kind == "fold":
                    replayed = self._route_fold(unit, ctx, prefer_survivor=True)
                else:
                    replayed = self._dispatch_remote(
                        unit, ctx, prefer_survivor=True
                    )
                if not replayed:
                    deferred.append(unit)
            ctx.replays.extend(deferred)
            deferred = []
            while ctx.ready and not state.errors:
                unit = ctx.ready.popleft()
                if unit.kind == "fold":
                    # Peer-exchange merge chain: remote when the data
                    # plane allows, localized otherwise — never through
                    # the generic in-process branch, whose run closure
                    # would fold packed descriptors instead of values.
                    if not self._route_fold(unit, ctx):
                        deferred.append(unit)
                elif self._remotable(unit):
                    if not self._dispatch_remote(unit, ctx):
                        # Owner busy: an idle sibling may take it now
                        # (driver-side steal) instead of waiting the
                        # owner's window out.
                        if not self._steal_reroute(unit, ctx):
                            deferred.append(unit)
                else:
                    # In-process unit (merge fold, driver view).  Runs
                    # on the calling thread; its task() dispatches may
                    # themselves be remote RPCs, which pump this same
                    # context reentrantly.
                    ctx.ready.extend(self._run_unit(unit, state))
            ctx.ready.extend(deferred)

    def _sweep_all(self) -> None:
        """Sweep every live context, then flush the staged batches."""
        for ctx in list(self._contexts.values()):
            if ctx.ready or ctx.replays:
                self._sweep_context(ctx)
        self._flush_outbox()
        if self.steal_enabled:
            self._maybe_steal()
        if self.autoscale:
            self._autoscale()

    def _any_work(self) -> bool:
        """Anything in flight, staged, or dispatchable across all contexts."""
        if self._outbox:
            return True
        return any(
            c.inflight or c.ready or c.replays for c in self._contexts.values()
        )

    def _drain(self, state: _SchedulerState) -> None:
        ctx = self._open_context(state, state.report or self.engine.current_report)
        ctx.ready.extend(state.initial_ready())
        try:
            while not state.errors:
                self._sweep_all()
                if state.done.is_set() or state.errors:
                    break
                if not self._any_work():
                    break  # nothing left to wait for (defensive)
                self._pump()
        finally:
            self._close_context(ctx)

    # -- pipelined execution (DESIGN.md §14) -----------------------------------

    def _start_entry(self, entry, prev) -> None:
        """Open a context for a pipelined submission and push what's ready.

        Gated units land in the context's ready queue when their
        cross-iteration predecessors complete (the gate callbacks fire
        inside the reply pump's ``state.complete``); ungated units land
        immediately.  A drain-replies + sweep here gives freshly admitted
        work its first chance to dispatch without waiting for the next
        ``result()`` drive.
        """
        ctx = self._open_context(entry.state, entry.report)
        entry.ctx = ctx

        def launch(unit, ctx=ctx):
            if not ctx.state.errors:
                ctx.ready.append(unit)

        self._gate_units(entry, prev, launch)
        self._drain_replies()  # landed replies close windows + fire gates
        self._sweep_all()

    def _drive_raw(self, entry) -> None:
        """Pump the event loop until ``entry`` reaches raw completion.

        Sweeps EVERY live context each round: this entry's units may be
        gated on a previous iteration's, so progress anywhere is progress
        here.  The entry's context closes once its state settles — pins
        drop, and later replies for it become stale by epoch.
        """
        state = entry.state
        while not state.done.is_set():
            self._sweep_all()
            if state.done.is_set():
                break
            if not self._any_work():
                if not state.done.is_set():
                    state.fail(
                        ClusterFailedError(
                            "pipelined drain stalled: nothing in flight can "
                            f"complete execute #{entry.iteration}"
                        )
                    )
                break
            self._pump()
        ctx = entry.ctx
        if ctx is not None:
            entry.ctx = None
            self._close_context(ctx)

    # -- the reply pump / supervisor ------------------------------------------

    def _pump(self) -> None:
        """Process one reply quantum, then sweep worker liveness.

        Waits on every live worker's reply connection at once; a readable
        connection yields either a message or EOF (the worker died with
        the pipe torn) — EOF folds straight into the death path.  Replies
        route to their context by epoch, so one pump serves every live
        context (pipelined iterations included).
        """
        by_conn = {w.reply: w for w in self._workers.values()}
        try:
            ready = connection.wait(list(by_conn), timeout=self.poll_s)
        except OSError:  # a conn closed under us (stop() raced): resweep
            ready = []
        for r in ready:
            worker = by_conn.get(r)
            if worker is None or worker.id not in self._workers:
                continue  # buried while we iterated
            try:
                payload = r.recv_bytes()
            except (EOFError, OSError):
                self._on_worker_death(worker.id)
                continue
            self._on_reply(payload)
        self._check_workers()

    def _drain_replies(self) -> None:
        """Non-blocking sweep of every reply already in flight."""
        progressed = True
        while progressed:
            progressed = False
            for worker in list(self._workers.values()):
                try:
                    while worker.reply.poll(0):
                        self._on_reply(worker.reply.recv_bytes())
                        progressed = True
                except (EOFError, OSError):
                    self._on_worker_death(worker.id)

    def _on_reply(self, payload: bytes) -> None:
        msg = pickle.loads(payload)
        kind, wid = msg[0], msg[1]
        if wid in self._workers:  # never resurrect a buried worker's heartbeat
            self._last_hb[wid] = time.monotonic()
            self._silence[wid] = 0.0
        if kind in ("hb", "ready"):
            return
        if kind == "steal_ok":
            self._on_steal_grant(wid, msg[2], msg[3])
            return
        # any unit/call reply closes that worker's one-command window
        if wid in self._workers and self._outstanding.get(wid, 0) > 0:
            self._outstanding[wid] -= 1
        if kind in ("unit_done", "unit_error"):
            # Per-worker service-time EMA: replies from one batch arrive
            # back-to-back, so the gap since the previous reply (or the
            # batch send) is this unit's observed service time.  This is
            # what the steal gate feeds on — a straggler's EMA dwarfs its
            # siblings', so steals flow off it and never back onto it.
            mark = self._reply_mark.get(wid)
            now_pc = time.perf_counter()
            if mark is not None:
                service = max(now_pc - mark, 1e-6)
                prev = self._task_ema.get(wid)
                self._task_ema[wid] = (
                    service if prev is None else 0.5 * prev + 0.5 * service
                )
            self._reply_mark[wid] = now_pc
        if kind in ("call_done", "call_error"):
            if msg[3] not in self._pending_calls:
                if kind == "call_done":
                    shm.discard_tree(msg[4])  # its segments, or they leak
                return  # superseded call (replayed after a death): drop it
            self.engine.current_report.ipc_bytes += len(payload)
            self._call_results[msg[3]] = msg
            return
        # unit replies route to their context by epoch; no live context of
        # that epoch (an earlier run, or one already closed) means stale
        epoch, index = msg[2], msg[3]
        order = self._dispatch_order.get(wid)
        if order:  # the replied unit is no longer stealable from this worker
            self._dispatch_order[wid] = [
                e for e in order if not (e[0].epoch == epoch and e[1].index == index)
            ]
        ctx = self._contexts.get(epoch)
        stale = ctx is None or ctx.state.errors or ctx.state.is_done(index)
        unit = None if stale else ctx.inflight.pop(index, None)
        if unit is None:
            # Stale: an earlier run, or a duplicate after replay.  A
            # dropped unit_done still owns reply segments — unlink them.
            if kind == "unit_done":
                shm.discard_tree(msg[4])
            return
        ctx.report.ipc_bytes += len(payload)
        self._release_unit(unit)
        if self._shm is not None:
            refs = ctx.shm_pins.pop(index, None)
            if refs:
                self._shm.unpin_refs(refs)
        if kind == "unit_error":
            # Fold units attribute to their subtree's ORIGINATING task —
            # the app-level key an operator can act on, never the
            # synthetic fold (the regression test in tests/test_p2p.py).
            task = self._unit_origin(unit)
            label = (
                f"task {key_summary(task.key)} (blocks={task.block_ids})"
                if task is not None
                else f"unit {index}"
            )
            if unit.kind == "fold":
                label = f"merge fold of {label}"
            handle = self._workers.get(wid)
            ctx.record_failure(
                index,
                wid,
                str(msg[4]).strip().splitlines()[-1] if msg[4] else "unit_error",
                handle.log_path if handle is not None else None,
            )
            ctx.state.fail(
                ClusterFailedError(
                    f"{label} failed on worker {wid}:\n{msg[4]}",
                    task_key=key_summary(task.key) if task is not None else None,
                    **ctx.error_kwargs(index),
                )
            )
            return
        _, _, _, _, result, loaded, shm_wrote = msg
        report = ctx.report
        lease = shm.tree_lease(result) if unit.publish else None
        if lease is not None:
            # Published partial: the packed ref tree IS the unit's result —
            # the sibling fold forwards the descriptors and attaches the
            # segments in place.  The driver records the lease; nothing is
            # copied here.
            ctx.leases[index] = lease
            value = result
            merge_key = getattr(ctx.state, "merge_key", None)
            if merge_key is not None:
                self._note_partial_bytes(merge_key, lease.nbytes)
        else:
            result, _segs = shm.unpack_tree(result)  # consume-and-unlink
            value = jax.tree.map(np.asarray, result)
            merge_key = getattr(ctx.state, "merge_key", None)
            if merge_key is not None and unit.kind != "fold":
                self._note_partial_bytes(merge_key, _tree_nbytes(value))
        if unit.kind == "fold":
            # The worker-side chain replaces a driver merge dispatch: bill
            # the merge, credit the member bytes that never crossed the
            # driver, and release their segments — consumption is the
            # ownership-transfer point of the zero-leak contract.
            report.merges += 1
            for mi in unit.fold_group:
                mlease = ctx.leases.pop(mi, None)
                if mlease is not None:
                    report.p2p_bytes += mlease.nbytes
                    shm.unlink_segments(mlease.segments)
        report.dispatches += 1
        report.remote_dispatches += 1
        report.bytes_loaded += loaded
        report.shm_bytes += shm_wrote
        t0, send_s = ctx.meta.get(index, (None, 0.0))
        wall = (time.perf_counter() - t0) if t0 is not None else 0.0
        self.profile.record_tasks(
            unit.tasks,
            kind=unit.kind,
            location=unit.location,
            dispatch_s=send_s,
            wall_s=wall,
        )
        ctx.ready.extend(sorted(ctx.state.complete(unit, value), key=lambda u: u.index))

    def _check_workers(self) -> None:
        """Liveness sweep: bury dead processes and heartbeat-stale hangs.

        Staleness is debounced against the *driver-side* pump cadence: a
        worker's silence clock only advances by the time since the last
        check, capped at a few poll quanta.  While the driver pumps
        normally that accrues at real-time rate, so a genuinely mute
        worker still times out in ``heartbeat_timeout_s`` — but a driver
        stall (a long in-process merge, a blocked send, load on the CI
        host) contributes one capped tick instead of the whole gap, and
        the stalled-out heartbeats waiting in the pipe zero the clock at
        the very next pump.  Before this debounce an idle worker parked
        in ``recv`` could be declared hung purely because the *driver*
        was busy — the false-staleness window the regression test in
        ``tests/test_elastic.py`` pins.
        """
        now = time.monotonic()
        tick = min(
            now - self._last_pump,
            max(self.poll_s, self.heartbeat_s) * 4,
        )
        self._last_pump = now
        for wid, handle in list(self._workers.items()):
            if not handle.alive():
                self._on_worker_death(wid)
                continue
            silence = self._silence.get(wid, 0.0) + tick
            self._silence[wid] = silence
            if silence > self.heartbeat_timeout_s:
                self._on_worker_death(wid)

    # -- work stealing (DESIGN.md §15) ----------------------------------------

    def _steal_model(self):
        """The fitted :class:`~repro.api.autotune.CostModel`, if any tuner
        has one — the locality-aware steal gate's first choice of evidence.
        """
        for entry in getattr(self, "_tuners", {}).values():
            for item in entry if isinstance(entry, tuple) else (entry,):
                model = getattr(item, "model", None)
                if model is not None:
                    return model
        return None

    def _steal_task_s(self) -> float:
        """Fallback per-task seconds when no model is fitted: the profiled
        mean unit wall (send → reply), floored so a cold profile store
        still lets the gate reason instead of dividing by zero.
        """
        walls = [
            p.mean_wall_s for p in self.profile.profiles.values()
            if p.mean_wall_s > 0.0
        ]
        return max(sum(walls) / len(walls), 1e-4) if walls else 1e-3

    def _steal_gate(
        self,
        victim_wid: int,
        thief_wid: int,
        queued_tasks: int,
        operand_bytes: int = 0,
    ) -> bool:
        """Cost-model steal decision for ``queued_tasks`` waiting units.

        The wait side uses the victim's observed service-time EMA when one
        exists (a straggler's inflated EMA is exactly what makes its queue
        worth raiding); the fetch side charges the thief's EMA for
        actually executing the stolen units — so a slow worker can never
        profitably steal work back from a fast one (no ping-pong).  With
        the shm data plane a steal moves descriptors, not bytes, so
        ``operand_bytes`` only bites when shm is off and the operands
        would re-cross the pipe.
        """
        return should_steal(
            self._steal_model(),
            queued_tasks=queued_tasks,
            operand_bytes=0 if self._shm is not None else operand_bytes,
            fallback_task_s=self._steal_task_s(),
            victim_task_s=self._task_ema.get(victim_wid),
            thief_task_s=self._task_ema.get(thief_wid, 0.0),
        )

    def _idle_workers(self) -> list[_WorkerHandle]:
        """Live workers with nothing outstanding and nothing staged."""
        return [
            self._workers[wid]
            for wid in sorted(self._workers)
            if self._workers[wid].alive()
            and self._outstanding.get(wid, 0) == 0
            and wid not in self._outbox
            and wid not in self._preempting
        ]

    def _maybe_steal(self) -> None:
        """Probe the most-loaded worker on behalf of an idle sibling.

        Victim selection: the live worker with the deepest un-replied
        queue (at least one unit *behind* the one presumed running).  The
        probe asks for every un-replied unit; the victim grants whatever
        it has not started — the head it already popped keeps running, so
        exactly-once needs no further coordination.  At most one probe per
        victim is in flight, and the probe itself is exempt from the
        one-command window: it is a fixed few hundred bytes against a
        64KB pipe the victim drains between units, so it can never block
        the parent the way a unit batch could.
        """
        if not self.steal_enabled or not self._contexts:
            return
        idle = self._idle_workers()
        if not idle:
            return
        thief = min(idle, key=lambda w: (self._task_ema.get(w.id, 0.0), w.id))
        for vid in sorted(
            self._workers, key=lambda w: -self._outstanding.get(w, 0)
        ):
            if vid in self._steal_probes or vid in self._preempting:
                continue
            queue = self._dispatch_order.get(vid, ())
            backlog = self._outstanding.get(vid, 0) - 1
            if backlog < 1 or not queue:
                continue
            cand = [
                (c, u) for c, u in queue if not c.state.is_done(u.index)
            ]
            if not cand or not self._steal_gate(vid, thief.id, backlog):
                continue
            victim = self._workers.get(vid)
            if victim is None or not victim.alive():
                continue
            token = next(self._steal_seq)
            wants = tuple((c.epoch, u.index) for c, u in cand)
            try:
                sent = victim.send(("steal", token, wants))
            except OSError:
                self._on_worker_death(vid)
                continue
            cand[0][0].report.ipc_bytes += sent
            self._steal_probes[vid] = (token, wants)
            return  # one probe per pump round bounds control traffic

    def _on_steal_grant(self, wid: int, token: int, granted: tuple) -> None:
        """Fold a ``steal_ok`` reply in: void the victim's claim on every
        granted unit and requeue it for an idle survivor.

        Each granted unit was removed from the victim's local queue
        *before* execution, so its reply will never come: its window slot
        is released here, its dispatch pins dropped (the thief's dispatch
        re-pins — the pin accounting the property tests audit), its
        attempt refunded via :meth:`_SchedulerState.release` (a steal is
        not a failure), and the unit lands in its context's replay queue,
        which dispatches survivor-first.  A grant that raced a completed
        unit (stale by epoch or by ``is_done``) is dropped harmlessly.
        """
        probe = self._steal_probes.pop(wid, None)
        if probe is not None and probe[0] != token:  # superseded probe
            self._steal_probes[wid] = probe
        if wid in self._workers and granted:
            self._outstanding[wid] = max(
                0, self._outstanding.get(wid, 0) - len(granted)
            )
        order = self._dispatch_order.get(wid)
        if order and granted:
            taken = set(granted)
            self._dispatch_order[wid] = [
                e for e in order if (e[0].epoch, e[1].index) not in taken
            ]
        for epoch, index in granted:
            ctx = self._contexts.get(epoch)
            if ctx is None or ctx.state.errors:
                continue
            unit = ctx.inflight.pop(index, None)
            if unit is None or ctx.state.is_done(index):
                continue
            self._release_unit(unit)
            if self._shm is not None:
                refs = ctx.shm_pins.pop(index, None)
                if refs:
                    self._shm.unpin_refs(refs)
                if unit.publish:
                    # The victim never started the granted unit, but sweep
                    # its voided attempt's publish name anyway — a racing
                    # half-written segment must not survive the re-route.
                    shm.sweep_segments(
                        self._publish_name(
                            epoch, index, ctx.state.attempts[index] - 1
                        )
                    )
            if not ctx.state.release(unit):
                continue  # completed under the victim after all: stale grant
            ctx.report.steals += 1
            self.steal_log.append(
                {"unit": index, "epoch": epoch, "victim": wid, "kind": "probe"}
            )
            ctx.replays.append(unit)

    def _steal_reroute(self, unit: _Unit, ctx: _DrainContext) -> bool:
        """Driver-side steal: a ready unit whose location owner is busy
        goes straight to an idle sibling when the cost gate approves —
        the unit never waits out the owner's window at all.
        """
        if not self.steal_enabled:
            return False
        owner_wid = self._by_location.get(unit.location)
        if owner_wid is None:
            return False
        idle = [
            w for w in self._idle_workers()
            if w.id != owner_wid and w.location != unit.location
        ]
        if not idle:
            return False
        thief = min(idle, key=lambda w: (self._task_ema.get(w.id, 0.0), w.id))
        backlog = self._outstanding.get(owner_wid, 0)
        if not self._steal_gate(owner_wid, thief.id, backlog):
            return False
        if not self._dispatch_remote(unit, ctx, target=thief):
            return False
        ctx.report.steals += 1
        self.steal_log.append(
            {"unit": unit.index, "epoch": ctx.epoch, "victim": owner_wid,
             "kind": "reroute"}
        )
        return True

    # -- elasticity: grow / shrink (DESIGN.md §15) -----------------------------

    def _scale_report(self):
        """Where a scale event bills: the oldest live context's report when
        a run is in flight (the autoscaler path — its sums then reconcile
        against ``scale_log`` exactly), else the engine's current report
        (manual grow/shrink between runs).
        """
        for ctx in self._contexts.values():
            return ctx.report
        return self.engine.current_report

    def grow(self) -> int | None:
        """Add one roamer worker (autoscaler hook; also a manual knob).

        Roamers own no partition — they are fed exclusively by the steal
        paths, so growing the pool never perturbs locality routing for
        owned locations.  Respects ``max_workers``; bills one
        ``scale_events``.
        """
        if len([w for w in self._workers.values() if w.alive()]) >= self.max_workers:
            return None
        wid = next(self._next_wid)
        self._used_wids.add(wid)
        self._roamers.add(wid)
        # Synthetic negative location: unique, never routed to by
        # _worker_for (real locations are >= 0, and -wid < -1 for all
        # roamer wids), so the only way work reaches a roamer is a steal.
        self._spawn(wid, -wid)
        self._scale_report().scale_events += 1
        self.scale_log.append({"event": "grow", "worker": wid})
        return wid

    def shrink(self, wid: int | None = None) -> int | None:
        """Preempt one worker — planned scale-down as deliberate death.

        The drain IS the fault path: the preempted worker's queued and
        in-flight units go through exactly the requeue/replay machinery a
        kill exercises (same code, bit-identical results), except the
        voided attempts are refunded and nothing bills ``retries`` — a
        planned shrink must never push a unit toward retry exhaustion
        (spot-instance semantics).  Default victim: the idlest roamer,
        else the highest-wid live worker (location owners respawn on
        demand).  Bills one ``scale_events``.
        """
        if wid is None:
            candidates = sorted(
                (w for w in self._roamers if w in self._workers),
                key=lambda w: -self._idle_ticks.get(w, 0),
            ) or sorted(self._workers, reverse=True)
            wid = candidates[0] if candidates else None
        if wid is None or wid not in self._workers:
            return None
        self._preempting.add(wid)
        self._scale_report().scale_events += 1
        self.scale_log.append({"event": "shrink", "worker": wid})
        self._on_worker_death(wid)
        return wid

    def _autoscale(self) -> None:
        """One autoscaler tick (runs inside every pump).

        Grow on queue depth: queued-behind-running units across the pool,
        plus everything parked in the driver-side ready/replay queues,
        normalized per live worker.  Shrink on utilization: a roamer idle
        for ``scale_idle_ticks`` consecutive ticks retires through
        :meth:`shrink` — the preemption path, so even a race that slipped
        it new work is safe.
        """
        if not self.autoscale:
            return
        live = [wid for wid, w in self._workers.items() if w.alive()]
        if not live:
            return
        backlog = sum(
            max(0, self._outstanding.get(wid, 0) - 1) for wid in live
        ) + sum(
            len(c.ready) + len(c.replays) for c in self._contexts.values()
        )
        if (
            backlog >= self.scale_up_backlog * len(live)
            and len(live) < self.max_workers
        ):
            self.grow()
            return
        for wid in sorted(self._roamers & set(self._workers)):
            if (
                self._outstanding.get(wid, 0) == 0
                and wid not in self._outbox
                and wid not in self._preempting
            ):
                streak = self._idle_ticks.get(wid, 0) + 1
                self._idle_ticks[wid] = streak
                if (
                    streak >= self.scale_idle_ticks
                    and len([w for w in self._workers.values() if w.alive()])
                    > self.min_workers
                ):
                    self.shrink(wid)
            else:
                self._idle_ticks[wid] = 0

    def _on_worker_death(self, wid: int) -> None:
        """Supervisor: bury a dead/hung worker and replay its units."""
        handle = self._workers.pop(wid, None)
        if handle is None:
            return
        if self._by_location.get(handle.location) == wid:
            del self._by_location[handle.location]
        self._attached = {k: v for k, v in self._attached.items() if k[0] != wid}
        self._last_hb.pop(wid, None)
        self._silence.pop(wid, None)
        self._outstanding.pop(wid, None)
        self._outbox.pop(wid, None)  # staged units are assigned: requeued below
        self._steal_probes.pop(wid, None)
        self._dispatch_order.pop(wid, None)
        self._idle_ticks.pop(wid, None)
        self._task_ema.pop(wid, None)
        self._reply_mark.pop(wid, None)
        self._roamers.discard(wid)
        # Planned preemption (scale-down) drains through this very path —
        # the elasticity contract: what survives a kill survives a shrink,
        # bit-identically — but bills scale_events (already done by
        # shrink()), not retries, and refunds the voided attempts.
        preempted = wid in self._preempting
        self._preempting.discard(wid)
        cause = (
            "preempted (scale-down)"
            if preempted
            else "hung (heartbeat stale)" if handle.alive() else "process died"
        )
        if handle.alive():  # hung (heartbeat-stale), not dead: put it down
            handle.process.terminate()
        handle.process.join(1.0)
        # Salvage completed work: replies that landed before the death are
        # still intact on the worker's own pipe — consuming them here
        # keeps "died after finishing" from being replayed needlessly.
        try:
            while handle.reply.poll(0):
                self._on_reply(handle.reply.recv_bytes())
        except (EOFError, OSError):
            pass  # torn end of the pipe: nothing more to salvage
        finally:
            for conn in (handle._conn, handle.reply):
                try:
                    conn.close()
                except OSError:
                    pass
        # Undelivered replies died with the worker; their segments did not.
        # Salvage above consumed (and unlinked) what reached the pipe — the
        # prefix sweep reaps anything the worker packed but never sent.
        if handle.result_prefix:
            shm.sweep_segments(handle.result_prefix)
        # Requeue the dead worker's units across EVERY live context: with
        # pipelined iterations in flight the worker may have owned units
        # from several graphs at once.
        for ctx in list(self._contexts.values()):
            lost = ctx.state.requeue(wid)
            for unit in lost:
                if ctx.state.errors:
                    break  # poisoned: _close_context releases the rest
                ctx.inflight.pop(unit.index, None)
                # Release-on-requeue: the dead dispatch's pins must not
                # outlive it, or the store could never evict the chunks (or
                # segments) it holds.  The replay's own dispatch re-pins.
                self._release_unit(unit)
                if self._shm is not None:
                    refs = ctx.shm_pins.pop(unit.index, None)
                    if refs:
                        self._shm.unpin_refs(refs)
                    if unit.publish:
                        # The dead worker may have published this attempt's
                        # partial without delivering the reply; the replay
                        # publishes under a fresh attempt name, so the
                        # voided segment would otherwise leak.
                        shm.sweep_segments(
                            self._publish_name(
                                ctx.epoch,
                                unit.index,
                                ctx.state.attempts[unit.index] - 1,
                            )
                        )
                if preempted:
                    # Spot-instance semantics: the voided attempt is
                    # refunded and nothing bills retries — a planned
                    # shrink must not be able to poison a unit.
                    ctx.state.refund_attempt(unit.index)
                    ctx.replays.append(unit)
                    continue
                task = self._unit_origin(unit)
                label = (
                    f"task {key_summary(task.key)} (blocks={task.block_ids})"
                    if task is not None
                    else f"unit {unit.index}"
                )
                if unit.kind == "fold":
                    label = f"merge fold of {label}"
                ctx.record_failure(unit.index, wid, cause, handle.log_path)
                if ctx.state.attempts[unit.index] > self.max_retries:
                    ctx.state.fail(
                        ClusterFailedError(
                            f"{label} poisoned: "
                            f"{ctx.state.attempts[unit.index]} attempts "
                            f"died with their workers (max_retries="
                            f"{self.max_retries})",
                            task_key=key_summary(task.key)
                            if task is not None
                            else None,
                            **ctx.error_kwargs(unit.index),
                        )
                    )
                    break
                ctx.report.retries += 1
                self.retry_log.append(
                    {"unit": unit.index, "epoch": ctx.epoch, "worker": wid,
                     "cause": cause}
                )
                # Enqueue, don't dispatch: this may run deep inside a _pump
                # — the drain sweep replays the unit once control unwinds,
                # so death handling never nests a send inside a send.
                ctx.replays.append(unit)

    # -- driver-level remote calls --------------------------------------------

    def _remote_call(self, fn_ref: tuple, args: tuple, key_repr: str):
        """One driver-level RPC: export big args, pin them, run the loop.

        Args export through the arena's identity cache, so an iterative
        driver loop passing the same arrays every call (k-NN's lookup
        over a fixed train set) copies them into shared memory once and
        ships ~100-byte descriptors thereafter — the bulk of the cluster
        ``ipc_bytes`` win for RPC-shaped apps.  The pins span the whole
        call including replays: a retried call reuses the same refs.
        """
        report = self.engine.current_report
        arg_refs: list[ShmBlockRef] = []
        if self._shm is not None:
            exported = []
            for a in args:
                ref, wrote = self._shm.export(a, materialize=lambda a=a: np.asarray(a))
                if ref is not None:
                    report.shm_bytes += wrote
                    arg_refs.append(ref)
                    exported.append(ref)
                else:
                    exported.append(np.asarray(a))
            payload_args = tuple(exported)
            self._shm.pin_refs(arg_refs)
        else:
            payload_args = tuple(np.asarray(a) for a in args)
        try:
            return self._remote_call_loop(fn_ref, payload_args, key_repr)
        finally:
            if self._shm is not None and arg_refs:
                self._shm.unpin_refs(arg_refs)

    def _remote_call_loop(self, fn_ref: tuple, payload_args: tuple, key_repr: str):
        report = self.engine.current_report
        failures = 0
        history: list[dict] = []

        def err_kwargs():
            return {
                "attempts": tuple(history),
                "log_paths": tuple(
                    dict.fromkeys(a["log"] for a in history if a["log"])
                ),
            }

        while True:
            # Pending batches first: the window invariant (send only to
            # a worker parked in recv) must hold for THIS send too.
            self._flush_outbox()
            worker = self._survivor() or self._worker_for(0)
            if not self._await_window(worker):
                continue  # died while we waited for its window: re-resolve
            call_id = next(self._call_seq)
            payload = pickle.dumps(
                ("call", self._epoch, call_id, fn_ref, payload_args, key_repr)
            )
            try:
                report.ipc_bytes += worker.send_raw(payload)
            except OSError:
                self._on_worker_death(worker.id)
                history.append(
                    {"worker": worker.id, "error": "process died",
                     "log": worker.log_path}
                )
                failures += 1
                if failures > self.max_retries:
                    raise ClusterFailedError(
                        f"call {key_repr} poisoned: {failures} workers died "
                        f"under it (max_retries={self.max_retries})",
                        task_key=key_repr,
                        **err_kwargs(),
                    ) from None
                report.retries += 1
                continue
            self._pending_calls.add(call_id)
            self._outstanding[worker.id] = self._outstanding.get(worker.id, 0) + 1
            while call_id not in self._call_results:
                if worker.id not in self._workers or not worker.alive():
                    # The pump's sweep may already have buried it; make
                    # sure, then collect any reply that landed before the
                    # death so a completed call is not replayed needlessly.
                    self._on_worker_death(worker.id)
                    self._drain_replies()
                    break
                self._pump()
            msg = self._call_results.pop(call_id, None)
            self._pending_calls.discard(call_id)  # resolved or abandoned: done
            if msg is None:  # worker died mid-call: replay on a survivor
                history.append(
                    {"worker": worker.id, "error": "process died mid-call",
                     "log": worker.log_path}
                )
                failures += 1
                if failures > self.max_retries:
                    raise ClusterFailedError(
                        f"call {key_repr} poisoned: {failures} workers died "
                        f"under it (max_retries={self.max_retries})",
                        task_key=key_repr,
                        **err_kwargs(),
                    )
                report.retries += 1
                continue
            if msg[0] == "call_error":
                handle = self._workers.get(msg[1])
                history.append(
                    {"worker": msg[1],
                     "error": str(msg[4]).strip().splitlines()[-1]
                     if msg[4] else "call_error",
                     "log": handle.log_path if handle is not None else None}
                )
                raise ClusterFailedError(
                    f"call {key_repr} failed on worker {msg[1]}:\n{msg[4]}",
                    task_key=key_repr,
                    **err_kwargs(),
                )
            report.dispatches += 1
            report.remote_dispatches += 1
            result, _segs = shm.unpack_tree(msg[4])  # consume-and-unlink
            report.shm_bytes += msg[5]
            import jax.numpy as jnp

            return jax.tree.map(jnp.asarray, result)

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        """Stop the worker pool (idempotent; workers respawn on next use).

        Shared-memory teardown happens AFTER the workers are down: unlink
        the arena, then sweep the whole name prefix — which also reaps any
        reply segment a worker packed but whose message was never consumed
        — so no ``/dev/shm`` entry outlives the executor.
        """
        # In-flight pipelined submissions drain first (while the pool is
        # still up); their outcomes stay on their futures.
        self._drain_pipeline()
        self._contexts.clear()
        workers = list(self._workers.values())
        self._workers.clear()
        self._by_location.clear()
        self._attached.clear()
        self._last_hb.clear()
        self._manifests.clear()
        self._call_results.clear()
        self._pending_calls.clear()
        self._outstanding.clear()
        self._outbox.clear()
        self._dispatch_order.clear()
        self._steal_probes.clear()
        self._roamers.clear()
        self._idle_ticks.clear()
        self._preempting.clear()
        self._task_ema.clear()
        self._reply_mark.clear()
        self._silence.clear()
        for w in workers:
            w.stop()
        if self._shm is not None:
            self._shm.close()
            shm.sweep_segments(self._shm.prefix)
        super().close()
