"""repro.api — the lazy Collection/Executor execution layer (DESIGN.md §3–§5).

Public surface:

* :class:`Collection` — fluent, lazy plan builder over blocked arrays:
  ``Collection.from_array(...).split(policy).map_blocks(fn).reduce(c)``.
* :class:`ExecutionPolicy` and its concrete policies :class:`Baseline`,
  :class:`SplIter`, :class:`Rechunk` — the typed replacement for the
  seed's stringly ``mode`` flag.
* :class:`Executor` protocol with :class:`LocalExecutor` (sequential,
  seed-equivalent) and :class:`ThreadedExecutor` (one worker thread per
  location) backends; both report costs via
  :class:`~repro.core.engine.EngineReport`.
* :class:`ExecutionPlan` — the small IR a Collection chain builds;
  :class:`PartitionView` — what ``map_partitions`` callbacks receive;
  :class:`ComputeResult` — ``(value, report)``.
"""

from repro.api.collection import Collection
from repro.api.executors import (
    ComputeResult,
    Executor,
    LocalExecutor,
    PartitionView,
    ThreadedExecutor,
)
from repro.api.plan import ExecutionPlan, PlanError
from repro.api.policy import Baseline, ExecutionPolicy, Rechunk, SplIter, as_policy

__all__ = [
    "Collection",
    "ComputeResult",
    "Executor",
    "LocalExecutor",
    "PartitionView",
    "ThreadedExecutor",
    "ExecutionPlan",
    "PlanError",
    "Baseline",
    "ExecutionPolicy",
    "Rechunk",
    "SplIter",
    "as_policy",
]
