"""repro.api — the lazy Collection/Executor execution layer (DESIGN.md §3–§5).

Public surface (the curated ``__all__`` below is the contract:
``tests/test_api_surface.py`` fails the build when docs or examples lean
on anything outside it):

* :func:`engine` / :class:`EngineConfig` — THE construction path for
  every backend: ``with engine("cluster", config=EngineConfig(...)) as
  ex:`` (DESIGN.md §16).  The per-backend constructors below keep
  working behind ``DeprecationWarning`` shims.
* :class:`Collection` — fluent, lazy plan builder over blocked arrays:
  ``Collection.from_array(...).split(policy).map_blocks(fn).reduce(c)``.
* :class:`ExecutionPolicy` and its concrete policies :class:`Baseline`,
  :class:`SplIter` (with its ``fusion="auto"|"scan"|"pallas"`` knob),
  :class:`Rechunk` — the typed replacement for the seed's stringly ``mode``
  flag.
* The two-stage execution split: a **lowering pass**
  (:func:`~repro.api.lowering.lower`) turns ``(plan, policy, backend
  Capabilities)`` into a frozen :class:`TaskGraph` of placed, keyed
  :class:`~repro.api.lowering.Task` descriptors; **scheduling** backends
  consume it — :class:`LocalExecutor` (sequential, seed-equivalent),
  :class:`ThreadedExecutor` (persistent worker thread per location),
  :class:`MeshExecutor` (sharded dispatch over a JAX device mesh),
  :class:`StreamExecutor` (out-of-core streaming with double-buffered
  prefetch) and :class:`ClusterExecutor` (multi-process, fault-tolerant
  scheduling over spawn-based workers — picklable
  :class:`~repro.api.lowering.TaskSpec` descriptors over IPC,
  locality-aware routing, deterministic replay of a dead worker's units,
  :class:`FaultPlan` injection for tests).  All report costs via
  :class:`~repro.core.engine.EngineReport` (the cluster adds
  ``ipc_bytes`` / ``remote_dispatches`` / ``retries``).
* The chunk tier (:mod:`repro.api.chunkstore`, DESIGN.md §10): blocks as
  :class:`ChunkRef` handles resolved at dispatch time, behind a
  :class:`ChunkStore` — :class:`InMemoryStore` (today's semantics) or
  :class:`DiskStore` (LRU residency budget, spill-on-eviction,
  pin/unpin) — so datasets larger than memory stream with bounded
  residency and bit-identical results.
* :class:`~repro.api.kernels.PartitionKernel` /
  :func:`~repro.api.kernels.register_partition_kernel` — the registry
  through which a ``map_blocks`` fn declares a fused Pallas partition
  implementation (one ``pallas_call`` per partition).
* :class:`ExecutionPlan` — the small IR a Collection chain builds;
  :class:`PartitionView` — what ``map_partitions`` callbacks receive;
  :class:`ComputeResult` — ``(value, report)``.
* The adaptive-granularity loop (DESIGN.md §9): every backend schedules
  through one instrumented dependency-driven core that populates a
  :class:`~repro.api.profile.ProfileStore` (per-task wall / dispatch
  overhead / bytes); ``SplIter(partitions_per_location="auto")`` hands the
  granularity knob to a per-workload :class:`~repro.api.autotune.Autotuner`
  (measure → cost model → retune, ≤3 retunes, logical regroup only — zero
  re-splits between retunes).
"""

from repro.api.autotune import Autotuner, CostModel, fit_cost_model
from repro.api.chunkstore import (
    AttachedStore,
    ChunkHandle,
    ChunkPinnedError,
    ChunkRef,
    ChunkStore,
    ChunkStoreError,
    DiskStore,
    InMemoryStore,
    StoreManifest,
    StoreStats,
    resolve_chunk,
)
from repro.api.cluster_executor import (
    ChaosSchedule,
    ClusterExecutor,
    ClusterFailedError,
    FaultPlan,
)
from repro.api.collection import Collection
from repro.api.executors import (
    ComputeResult,
    Executor,
    LocalExecutor,
    PartitionView,
    PrepareStats,
    SharedAssets,
    ThreadedExecutor,
)
from repro.api.factory import BACKENDS, EngineConfig, engine
from repro.api.futures import ComputeFuture, Deferred, PipelineBrokenError
from repro.api.jobclient import JobClient
from repro.api.jobserver import Job, JobEvent, JobFailedError, JobRejected, JobServer
from repro.api.journal import JobJournal
from repro.api.kernels import (
    PartitionKernel,
    pallas_interpret,
    partition_kernel_for,
    register_partition_kernel,
)
from repro.api.fnref import decode_fn, encode_fn
from repro.api.lowering import (
    Capabilities,
    Task,
    TaskGraph,
    TaskSpec,
    inputs_signature,
    lower,
    plan_fingerprint,
    stable_task_key,
    stacked_fold,
)
from repro.api.mesh_executor import MeshExecutor
from repro.api.plan import ExecutionPlan, PlanError
from repro.api.shm import ShmAttachments, ShmBlockRef, ShmStore, shm_available
from repro.api.policy import Baseline, ExecutionPolicy, Rechunk, SplIter, as_policy
from repro.api.profile import ProfileEvent, ProfileStore, TaskProfile
from repro.api.stream_executor import StreamExecutor

__all__ = [
    # the blessed construction path (DESIGN.md §16)
    "engine",
    "EngineConfig",
    "BACKENDS",
    "Collection",
    "ComputeResult",
    "ComputeFuture",
    "Deferred",
    "PipelineBrokenError",
    "Executor",
    "LocalExecutor",
    "ThreadedExecutor",
    "MeshExecutor",
    "StreamExecutor",
    "ClusterExecutor",
    "ClusterFailedError",
    "FaultPlan",
    "ChaosSchedule",
    "JobServer",
    "JobClient",
    "Job",
    "JobEvent",
    "JobRejected",
    "JobFailedError",
    "JobJournal",
    "SharedAssets",
    "inputs_signature",
    "plan_fingerprint",
    "ChunkRef",
    "ChunkHandle",
    "StoreManifest",
    "AttachedStore",
    "ChunkStore",
    "ChunkStoreError",
    "ChunkPinnedError",
    "InMemoryStore",
    "DiskStore",
    "StoreStats",
    "resolve_chunk",
    "ShmStore",
    "ShmBlockRef",
    "ShmAttachments",
    "shm_available",
    "PartitionView",
    "PrepareStats",
    "Autotuner",
    "CostModel",
    "fit_cost_model",
    "ProfileEvent",
    "ProfileStore",
    "TaskProfile",
    "stacked_fold",
    "Capabilities",
    "Task",
    "TaskGraph",
    "TaskSpec",
    "lower",
    "stable_task_key",
    "encode_fn",
    "decode_fn",
    "PartitionKernel",
    "register_partition_kernel",
    "partition_kernel_for",
    "pallas_interpret",
    "ExecutionPlan",
    "PlanError",
    "Baseline",
    "ExecutionPolicy",
    "Rechunk",
    "SplIter",
    "as_policy",
]
