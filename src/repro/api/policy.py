"""Typed execution policies — the replacement for the stringly ``mode`` flag.

The seed API threaded ``mode: str`` through every app, benchmark, and
example; each app re-implemented the mode plumbing by hand (and e.g.
k-means duplicated the rechunk-once special case).  A policy is now a small
frozen dataclass that says *how task granularity is derived from the
blocked collection*:

:class:`Baseline`
    One task per block (paper Listing 4).  The granularity coupling the
    paper attacks: dispatch count scales with the blocking.
:class:`SplIter`
    The paper's contribution (Listing 5): one task per locality
    *partition*, iterating the partition's local blocks inside the task.
    ``partitions_per_location`` adapts granularity to the computing
    capability; ``materialize=True`` is the paper-§7 variant that locally
    concatenates each partition into one contiguous buffer.
    ``partitions_per_location="auto"`` hands the choice to the executor's
    cost-model autotuner (:mod:`repro.api.autotune`): the granularity is
    measured, modelled and retuned across iterations instead of hand-picked
    — the knob the paper set out to remove ("finding the optimal block size
    ... requires inner knowledge of the computing environment").
:class:`Rechunk`
    The materializing competitor (paper §3.2.1): re-block the dataset —
    by default at one block per location — paying inter-location traffic,
    then run per-(big-)block tasks.

Policies are frozen and hashable, so executors can cache the prepared
form of ``(inputs, policy)`` — this is what makes the "split/rechunk cost
is paid once and diluted across iterations" behaviour (paper §6.3.1) a
property of the execution layer instead of app-level special casing.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ExecutionPolicy", "Baseline", "SplIter", "Rechunk", "as_policy"]


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """Base class for execution policies.  See module docstring."""

    # Subclasses provide ``mode_name`` (class attr or property) — the
    # report label, kept identical to the seed's mode strings so saved
    # benchmark tables stay comparable across the API transition.
    mode_name = "?"


@dataclasses.dataclass(frozen=True)
class Baseline(ExecutionPolicy):
    """One task per block + one merge task (paper Listing 4)."""

    mode_name = "baseline"


@dataclasses.dataclass(frozen=True)
class SplIter(ExecutionPolicy):
    """One task per locality partition (paper Listing 5, §4).

    Attributes:
      partitions_per_location: number of partitions each location is split
        into — the paper's adaptation to computing capability (nodes ×
        cores) — or the string ``"auto"``, which defers the choice to the
        executor's autotuner (measure → model → retune, with logical
        regrouping only between retunes: zero data movement).
      materialize: locally concatenate each partition's blocks into one
        contiguous buffer before the task consumes it (paper §7; recovers
        the rechunk advantage for compute-bound apps with zero
        inter-location traffic).
      fusion: how the per-partition iteration is fused by the lowering pass
        (DESIGN.md §5.2): ``"scan"`` forces the generic ``lax.scan`` body;
        ``"pallas"`` requests the registered fused Pallas partition kernel
        (one ``pallas_call`` per same-shape run, accumulator in VMEM),
        falling back to the scan when no kernel is registered or the
        shapes are rejected; ``"auto"`` lets the backend capabilities
        decide (compiled Pallas on TPU, scan elsewhere).
      autotune_seed: seed of the autotuner's deterministic probe schedule
        (only meaningful with ``partitions_per_location="auto"``); two runs
        with the same seed probe the same granularity ladder in the same
        order.

    Policies are frozen values — construct, compare, hash, done:

    >>> SplIter(partitions_per_location=2).mode_name
    'spliter'
    >>> SplIter(materialize=True).mode_name
    'spliter_mat'
    >>> SplIter(partitions_per_location="auto").autotuned
    True
    >>> SplIter() == SplIter(partitions_per_location=1)
    True
    """

    partitions_per_location: int | str = 1
    materialize: bool = False
    fusion: str = "auto"
    autotune_seed: int = 0

    def __post_init__(self):
        ppl = self.partitions_per_location
        assert ppl == "auto" or (isinstance(ppl, int) and ppl >= 1), ppl
        assert self.fusion in ("auto", "scan", "pallas"), self.fusion

    @property
    def autotuned(self) -> bool:
        return self.partitions_per_location == "auto"

    @property
    def mode_name(self) -> str:
        name = "spliter_mat" if self.materialize else "spliter"
        return name + "_auto" if self.autotuned else name


@dataclasses.dataclass(frozen=True)
class Rechunk(ExecutionPolicy):
    """Materialize at a new block size, then per-block tasks (paper §3.2.1).

    ``target_rows=None`` re-blocks at one block per location — the
    competitor configuration benchmarked by the paper.
    """

    target_rows: int | None = None

    mode_name = "rechunk"

    def __post_init__(self):
        assert self.target_rows is None or self.target_rows >= 1, self.target_rows


_BY_NAME = {
    "baseline": lambda ppl: Baseline(),
    "spliter": lambda ppl: SplIter(partitions_per_location=ppl),
    "spliter_mat": lambda ppl: SplIter(partitions_per_location=ppl, materialize=True),
    "spliter_auto": lambda ppl: SplIter(partitions_per_location="auto"),
    "rechunk": lambda ppl: Rechunk(),
}


def as_policy(
    policy: ExecutionPolicy | str,
    *,
    partitions_per_location: int = 1,
) -> ExecutionPolicy:
    """Coerce a policy object or legacy mode string into a policy.

    The string form exists for the deprecated ``run_map_reduce`` shim and
    for transitional callers; new code should construct policy objects.

    >>> as_policy("spliter", partitions_per_location=4)
    SplIter(partitions_per_location=4, materialize=False, fusion='auto', autotune_seed=0)
    >>> as_policy(Baseline()) == Baseline()
    True
    """
    if isinstance(policy, ExecutionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return _BY_NAME[policy](partitions_per_location)
        except KeyError:
            raise ValueError(
                f"unknown execution mode {policy!r}; expected one of {sorted(_BY_NAME)}"
            ) from None
    raise TypeError(f"expected ExecutionPolicy or str, got {type(policy).__name__}")
