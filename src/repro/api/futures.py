"""Futures for pipelined (asynchronous) plan execution — DESIGN.md §14.

``Executor.execute_async(plan)`` returns a :class:`ComputeFuture` instead of
draining the plan on the calling thread.  On a pipelined backend
(``Capabilities.pipelined``) consecutive ``execute_async`` submissions
*overlap*: iteration *k+1*'s units launch the moment their same-partition
iteration-*k* predecessors (and, when a :class:`Deferred` operand ties them,
the *k* merge fold) complete — no global per-execute barrier.

Completion is two-phase, and the split is what makes overlap deterministic:

* **raw completion** — every unit of the plan's TaskGraph (merge included)
  has run; the merged value is available to *dependent* iterations through
  :meth:`ComputeFuture.raw_value` / :class:`Deferred` operands.  Cross-
  iteration launches key off this phase.
* **finalization** — :meth:`ComputeFuture.result` performs, exactly once,
  the per-execute bookkeeping the synchronous path does behind its barrier
  (device sync, chunk-store window deltas, tuner feedback, ``wall_s``), and
  returns the sealed :class:`~repro.api.executors.ComputeResult`.  Reports
  stay *exact* per execute: every dispatch/trace/merge is billed to the
  submission that caused it, never to whichever report happened to be
  current.

:class:`Deferred` is the loop-carried-value half of the contract: the next
iteration's operand *is* the previous iteration's merged value, lazily.
``fut.map(fn)`` defers ``fn`` over the raw merged value; the result is
usable anywhere a plan operand (``extra_args``) is.  Resolution is
single-flight and cached, so every task of the next iteration shares ONE
computed array — bit-identical to the synchronous loop, which also computes
the carried value once per iteration.
"""

from __future__ import annotations

import threading
from typing import Any, Callable

__all__ = [
    "ComputeFuture",
    "Deferred",
    "PipelineBrokenError",
    "resolve_deferred",
]


class PipelineBrokenError(RuntimeError):
    """A pipelined execute was aborted by an earlier iteration's failure.

    Raised from the *dependent* iteration's future (and from any
    :class:`Deferred` resolved against the failed one), so overlap never
    blurs attribution: ``iteration`` is the executor-lifetime submit index
    of the execute that originally failed, and ``__cause__`` carries its
    exception.  The originating iteration's own future raises the original
    error untouched.
    """

    def __init__(self, message: str, *, iteration: int | None = None):
        super().__init__(message)
        self.iteration = iteration


class ComputeFuture:
    """Handle on an asynchronously executing plan (one pipelined iteration).

    Backends fill in the private hooks; applications use :meth:`result`,
    :meth:`done` and :meth:`map`:

    * ``result()`` blocks until the execute completes, finalizes it
      (exactly once), and returns its ``ComputeResult`` — or raises the
      failure (:class:`PipelineBrokenError` when the failure originated in
      an earlier overlapped iteration).
    * ``map(fn)`` returns a :class:`Deferred` of ``fn(raw merged value)``,
      usable as the next iteration's operand without waiting.
    """

    def __init__(self, *, iteration: int = 0):
        self.iteration = iteration
        self._raw = threading.Event()
        self._raw_value: Any = None
        self._error: BaseException | None = None
        self._result: Any = None
        # Set by the owning executor: finalization thunk (runs the deferred
        # half of execute()), and — on cooperative backends whose caller
        # pumps the event loop (ClusterExecutor, StreamExecutor) — a drive
        # thunk that makes progress until raw completion.
        self._finalize: Callable[[], Any] | None = None
        self._drive: Callable[[], None] | None = None
        self._lock = threading.Lock()

    @classmethod
    def completed(cls, result, *, iteration: int = 0) -> "ComputeFuture":
        """An already-finished future (the non-pipelined fallback path)."""
        fut = cls(iteration=iteration)
        fut._result = result
        fut._set_raw(result.value)
        return fut

    @classmethod
    def failed(cls, error: BaseException, *, iteration: int = 0) -> "ComputeFuture":
        """An already-failed future (the non-pipelined fallback path)."""
        fut = cls(iteration=iteration)
        fut._set_error(error)
        return fut

    # -- completion signalling (executor-side) --------------------------------

    def _set_raw(self, value: Any) -> None:
        self._raw_value = value
        self._raw.set()

    def _set_error(self, error: BaseException) -> None:
        self._error = error
        self._raw.set()

    # -- the application surface ----------------------------------------------

    def done(self) -> bool:
        """True once the plan's units all completed (or failed) — raw phase."""
        return self._raw.is_set()

    def raw_value(self) -> Any:
        """The merged value, pre-finalization (what :class:`Deferred` reads).

        Blocks until raw completion — on cooperative backends by driving
        the executor's pump.  Raises the execute's failure, if any.
        """
        if not self._raw.is_set():
            drive = self._drive
            if drive is not None:
                drive()
            self._raw.wait()
        if self._error is not None:
            raise self._error
        return self._raw_value

    def result(self):
        """Block until complete, finalize once, return the ComputeResult."""
        with self._lock:
            if self._result is not None:
                return self._result
            fin, self._finalize = self._finalize, None
            if fin is not None:
                self._result = fin()  # raises on failure, after teardown
                return self._result
        # No finalizer: a sync-completed/failed future, or a repeat call
        # after a finalization that raised — surface the stored outcome.
        self.raw_value()
        return self._result

    def map(self, fn: Callable[[Any], Any]) -> "Deferred":
        """Defer ``fn`` over the raw merged value (single-flight, cached)."""
        return Deferred(self, fn)


class Deferred:
    """A lazily-computed view of a future's value, usable as a plan operand.

    The pipelined-iteration carrier: ``centers = fut.map(recompute)`` makes
    the *next* plan's ``extra_args`` entry without waiting for ``fut``.
    The lowering layer resolves deferred operands at dispatch time (see
    :func:`resolve_deferred`) — by which point cross-iteration dependency
    edges guarantee the source execute's raw value exists, so resolution
    never blocks on the scheduler's own pipeline.

    ``resolve()`` is single-flight: the mapped function runs once and every
    consumer shares the cached value, exactly as the synchronous loop
    computes its carried value once per iteration — the bit-identity
    contract.  Deferreds chain: ``d.map(g)`` defers ``g`` over ``d``.
    """

    def __init__(self, source: "ComputeFuture | Deferred", fn: Callable[[Any], Any]):
        self._source = source
        self._fn = fn
        self._lock = threading.Lock()
        self._has_value = False
        self._value: Any = None

    @property
    def future(self) -> ComputeFuture:
        """The root :class:`ComputeFuture` this deferred chain hangs off."""
        src = self._source
        return src.future if isinstance(src, Deferred) else src

    def resolve(self) -> Any:
        if self._has_value:
            return self._value
        with self._lock:
            if not self._has_value:
                src = self._source
                try:
                    raw = src.resolve() if isinstance(src, Deferred) else src.raw_value()
                except PipelineBrokenError:
                    raise
                except BaseException as e:
                    fut = self.future
                    raise PipelineBrokenError(
                        f"deferred operand's source execute (iteration "
                        f"#{fut.iteration}) failed: {e}",
                        iteration=fut.iteration,
                    ) from e
                self._value = self._fn(raw)
                self._has_value = True
        return self._value

    def map(self, fn: Callable[[Any], Any]) -> "Deferred":
        return Deferred(self, fn)


def resolve_deferred(x: Any) -> Any:
    """Resolve ``x`` when it is a deferred/future operand; identity otherwise.

    The hook operand builders call on every ``extra_args`` entry — plain
    arrays pass through untouched, so non-pipelined plans pay one
    ``isinstance`` check and nothing else.
    """
    if isinstance(x, Deferred):
        return x.resolve()
    if isinstance(x, ComputeFuture):
        return x.raw_value()
    return x
