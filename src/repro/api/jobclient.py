"""JobClient — the Executor-shaped front door to a JobServer.

A :class:`JobClient` satisfies the :class:`~repro.api.executors.Executor`
protocol (``execute`` / ``task`` / ``report`` / ``scope``), so application
code is tenant-agnostic: ``kmeans(x, executor=client)`` runs unchanged,
each ``compute`` becoming one server submission multiplexed against every
other tenant's work.  The report crosses the client channel by value —
serialized with :meth:`~repro.core.engine.EngineReport.to_json` and
rebuilt client-side — so client-held reports never alias server state
(the contract a future socket transport inherits unchanged).

Out-of-plan stages (``client.task`` — k-NN's lookup/merge loops) register
against a client-LOCAL :class:`~repro.core.engine.TaskEngine`: they run in
the client's process by definition (the server only schedules plans), and
``scope`` accumulates both local dispatches and returned job reports into
one window, mirroring executor semantics.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Hashable

from repro.api.executors import ComputeResult
from repro.api.futures import ComputeFuture
from repro.api.jobserver import Job, JobFailedError, JobServer
from repro.api.plan import ExecutionPlan
from repro.core.engine import EngineReport, TaskEngine

__all__ = ["JobClient", "JobFailedError"]


class JobClient:
    """One tenant's handle on a :class:`~repro.api.jobserver.JobServer`.

    Args:
      server: the (in-process) server to submit against.
      tenant: fair-share identity — all of a tenant's jobs draw from one
        stride pass, weighted by ``weight``.
      weight: relative unit-slot share (2 ⇒ twice the units per round).
    """

    def __init__(self, server: JobServer, *, tenant: str = "default", weight: int = 1):
        self.server = server
        self.tenant = tenant
        self.weight = weight
        self._engine = TaskEngine()
        self._scope_depth = 0

    # -- async surface ------------------------------------------------------

    def submit(self, plan: ExecutionPlan) -> Job:
        """Fire-and-return: admit the plan, keep the :class:`Job` handle."""
        return self.server.submit(plan, tenant=self.tenant, weight=self.weight)

    def wait(self, job: Job, timeout: float | None = None) -> ComputeResult:
        """Join a submitted job; the report arrives as a channel copy."""
        res = self.server.wait(job, timeout)
        report = EngineReport.from_json(res.report.to_json())
        if self._scope_depth:
            self._engine.report += report
        return ComputeResult(value=res.value, report=report)

    def events(self, job: Job) -> list:
        """Snapshot of the job's lifecycle events so far."""
        return list(job.events)

    # -- the Executor protocol ----------------------------------------------

    def execute(self, plan: ExecutionPlan) -> ComputeResult:
        """Synchronous submit+wait — what ``Collection.compute`` calls."""
        return self.wait(self.submit(plan))

    def execute_async(self, plan: ExecutionPlan) -> ComputeFuture:
        """Executor-protocol parity: submit+wait wrapped in a done future.

        Tenant-side pipelining is the server's scheduler's business (jobs
        from many tenants already interleave at unit granularity), so the
        client keeps ``execute_async`` synchronous — application code
        written against the future surface runs unchanged through a
        JobServer.
        """
        try:
            result = self.execute(plan)
        except BaseException as e:  # noqa: BLE001 — surfaced via the future
            return ComputeFuture.failed(e)
        return ComputeFuture.completed(result)

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        return self._engine.task(fn, key=key)

    @property
    def report(self) -> EngineReport:
        return self._engine.report

    @contextlib.contextmanager
    def scope(self, mode: str):
        """Accumulate job reports + local dispatches into one window."""
        report = self._engine.new_report(mode)
        self._scope_depth += 1
        t0 = time.perf_counter()
        try:
            yield report
        finally:
            self._scope_depth -= 1
            report.wall_s = time.perf_counter() - t0
