"""Chunk storage — block buffers behind references, resolved at dispatch time.

Every layer built before this module assumed all blocks of a
:class:`~repro.core.blocked.BlockedArray` are resident jax arrays, which
caps dataset size at host memory.  Following the chunks-and-tasks model
(Rubensson & Rudberg, 2012 — tasks name *chunk identifiers*, the runtime
manages where chunk data lives), a block may instead be a :class:`ChunkRef`:
a tiny metadata handle (shape/dtype + a store id) whose buffer a
:class:`ChunkStore` materializes only when a task's operands are built.
Everything metadata-only — placement scans, splits, regroups, lowering —
keeps working on refs without touching bytes (asserted via ``StoreStats``).

Two stores:

:class:`InMemoryStore`
    Chunks are plain resident arrays; semantics identical to pre-chunk
    behaviour (no budget, no spill, zero accounting).  The degenerate store
    that keeps the abstraction free for in-memory workloads.
:class:`DiskStore`
    Out-of-core store with an LRU *residency budget*: resident chunks live
    in host memory up to ``residency_bytes``; eviction spills a
    never-written chunk to a ``.npy`` file (spill-on-eviction — a chunk
    that is never evicted never touches disk) and later accesses reload it
    via a memory-mapped read.  ``pin``/``unpin`` (refcounted) protect the
    chunks a running task resolves from eviction; evicting a pinned chunk
    is refused with :class:`ChunkPinnedError`.

Example — a 64 KiB dataset streamed through a 16 KiB budget::

    >>> import numpy as np
    >>> from repro.api.chunkstore import DiskStore
    >>> store = DiskStore(residency_bytes=16 * 1024)
    >>> blocks = [np.full((1024,), i, np.float32) for i in range(16)]  # 4 KiB each
    >>> refs = [store.put(b) for b in blocks]
    >>> store.stats.resident_bytes <= 16 * 1024
    True
    >>> float(refs[0].resolve()[0])        # reloads the spilled chunk
    0.0
    >>> store.stats.bytes_spilled > 0 and store.stats.bytes_loaded > 0
    True
    >>> store.close()                      # removes every spill file

Accounting flows upward: executors snapshot each store's
:class:`StoreStats` around an execution and report the deltas as
``EngineReport.bytes_loaded`` / ``bytes_spilled`` / ``prefetch_hits``.
"""

from __future__ import annotations

import dataclasses
import collections
import os
import shutil
import tempfile
import threading
import weakref
from typing import Iterable, Protocol, runtime_checkable

import jax
import numpy as np

from repro.api.shm import ShmAttachments, ShmBlockRef

__all__ = [
    "ChunkRef",
    "ChunkHandle",
    "ChunkStore",
    "ChunkStoreError",
    "ChunkPinnedError",
    "InMemoryStore",
    "DiskStore",
    "AttachedStore",
    "StoreManifest",
    "StoreStats",
    "resolve_chunk",
    "chunk_stores",
]

# Store uids are process-scoped: a manifest shipped to a worker names its
# origin store, and the worker maps uid -> AttachedStore.  The pid prefix
# keeps uids unambiguous across a parent and its spawned workers.
_store_uid_lock = threading.Lock()
_store_uid_seq = 0


def _new_store_uid() -> str:
    global _store_uid_seq
    with _store_uid_lock:
        _store_uid_seq += 1
        return f"store-{os.getpid()}-{_store_uid_seq}"


class ChunkStoreError(RuntimeError):
    """A chunk operation failed (unknown ref, closed store, ...)."""


class ChunkPinnedError(ChunkStoreError):
    """Refused to evict a chunk that is pinned by a running task."""


class ChunkRef:
    """A reference to one block held by a :class:`ChunkStore`.

    Mirrors the metadata surface of a jax array (``shape``, ``dtype``,
    ``nbytes``) so geometry code — block_rows, row shapes, lowering's
    ``data_shapes`` — works on refs without resolving them.  The buffer
    itself materializes only through :meth:`resolve` (equivalently
    ``store.get(ref)``), which is what "resolved at dispatch time" means:
    task ``operands()`` closures call it when the task actually runs.
    """

    __slots__ = ("store", "chunk_id", "shape", "dtype", "__weakref__")

    def __init__(self, store: "ChunkStore", chunk_id: int, shape: tuple, dtype):
        self.store = store
        self.chunk_id = chunk_id
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype.itemsize if self.shape else self.dtype.itemsize

    def resolve(self) -> jax.Array:
        """Materialize the chunk's buffer (loading from spill if needed)."""
        return self.store.get(self)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ChunkRef(id={self.chunk_id}, shape={self.shape}, "
            f"dtype={self.dtype.name}, store={type(self.store).__name__})"
        )


def resolve_chunk(block):
    """``block`` if it is already an array, else the resolved chunk buffer.

    The single dispatch-time hook: every place that turns block metadata
    into operand bytes (lowering's ``operands()`` closures, partition
    views, ``collect()``/``materialize()``) goes through it, so a
    :class:`BlockedArray` of refs and one of arrays are interchangeable.
    """
    if isinstance(block, ChunkRef):
        return block.resolve()
    return block


@dataclasses.dataclass(frozen=True)
class ChunkHandle:
    """A picklable, store-independent pointer to one chunk.

    What crosses a process boundary instead of a :class:`ChunkRef` (whose
    ``store`` attribute holds locks and finalizers): the origin store's
    uid plus the chunk id and geometry.  A worker resolves a handle
    against the :class:`AttachedStore` it built from that store's
    :class:`StoreManifest` — bytes never transit the control channel.
    """

    store_uid: str
    chunk_id: int
    shape: tuple
    dtype_str: str

    @property
    def nbytes(self) -> int:
        dt = np.dtype(self.dtype_str)
        return int(np.prod(self.shape)) * dt.itemsize if self.shape else dt.itemsize


@dataclasses.dataclass(frozen=True)
class StoreManifest:
    """The handoff half of store attach: where every chunk's bytes live.

    Produced by :meth:`DiskStore.manifest` and consumed worker-side by
    :class:`AttachedStore`.  Picklable by construction; each entry is a
    tagged tuple naming its transport:

    ``("shm", ShmBlockRef)``
        The chunk was resident driver-side and exported into a shared
        memory segment — workers resolve it zero-copy.  The preferred
        path: no disk write, no pipe bytes.
    ``("file", path, shape, dtype_str)``
        The chunk has a spill file (it was evicted, or the shm budget was
        exhausted) — workers memory-map the ``.npy``.

    Manifests of a grown store are **incremental**: ``manifest(known=...)``
    returns only the chunks the caller has not seen, and
    :meth:`AttachedStore.merge` folds the delta into an existing attach.
    """

    uid: str
    spill_dir: str
    chunks: dict  # chunk_id -> ("shm", ShmBlockRef) | ("file", path, shape, dtype_str)


class AttachedStore:
    """A worker-side, read-only view of another process's DiskStore.

    Resolves :class:`ChunkHandle`\\ s against the manifest's tagged
    entries: ``shm`` chunks as zero-copy views of the origin's shared
    memory segments (attached once per segment, cached), ``file`` chunks
    by memory-mapped reads of the spill files.  There is no residency
    budget: a worker holds at most its in-flight task's operands, and the
    buffers are released when the task replies.  ``stats.bytes_loaded``
    bills only the *disk* reads — shm resolution moves no bytes the
    parent has not already paid for (billed once as ``shm_bytes``).
    """

    def __init__(self, manifest: StoreManifest):
        self.manifest = manifest
        self.stats = StoreStats()
        self._shm = ShmAttachments()

    @property
    def uid(self) -> str:
        return self.manifest.uid

    def merge(self, delta: StoreManifest) -> None:
        """Fold a grown store's incremental manifest into this attach."""
        if delta.uid != self.uid:
            raise ChunkStoreError(
                f"manifest for store {delta.uid} merged into {self.uid}"
            )
        self.manifest.chunks.update(delta.chunks)

    def get(self, chunk_id: int):
        import jax.numpy as jnp

        entry = self.manifest.chunks.get(chunk_id)
        if entry is None:
            raise ChunkStoreError(
                f"chunk {chunk_id} not in manifest of store {self.uid}"
            )
        if entry[0] == "shm":
            return jnp.asarray(np.asarray(self._shm.view(entry[1])))
        _tag, path, _shape, _dtype = entry
        mm = np.load(path, mmap_mode="r")
        arr = jnp.asarray(np.asarray(mm))  # copy out of the mmap, then free it
        self.stats.loads += 1
        self.stats.bytes_loaded += arr.nbytes
        return arr

    def resolve(self, handle: ChunkHandle):
        if handle.store_uid != self.uid:
            raise ChunkStoreError(
                f"handle for store {handle.store_uid} resolved against {self.uid}"
            )
        return self.get(handle.chunk_id)

    def close(self) -> None:
        self._shm.close()


def chunk_stores(arrays: Iterable) -> list["ChunkStore"]:
    """Distinct stores backing any chunk-ref blocks of ``arrays``."""
    out: list[ChunkStore] = []
    for a in arrays:
        for b in getattr(a, "blocks", ()):
            if isinstance(b, ChunkRef) and b.store not in out:
                out.append(b.store)
    return out


@dataclasses.dataclass
class StoreStats:
    """Counters over one store's lifetime (executors report window deltas)."""

    loads: int = 0               # spill-file reads (disk -> resident)
    bytes_loaded: int = 0
    spills: int = 0              # spill-file writes (first eviction only)
    bytes_spilled: int = 0
    evictions: int = 0           # residency-cache drops (incl. free re-drops)
    prefetch_hits: int = 0       # get() served by an earlier prefetch()
    resident_bytes: int = 0
    peak_resident_bytes: int = 0

    def snapshot(self) -> "StoreStats":
        return dataclasses.replace(self)


@runtime_checkable
class ChunkStore(Protocol):
    """The storage contract blocks-as-references rely on.

    ``put`` registers a buffer and returns its :class:`ChunkRef`; ``get``
    materializes a ref (the dispatch-time resolve); ``pin``/``unpin`` are
    refcounted eviction guards around a task's lifetime; ``prefetch``
    loads ahead of use (a later ``get`` of a still-resident prefetched
    chunk counts as a ``prefetch_hit``); ``trim`` sheds all unpinned
    residency (executors call it when a prepared dataset falls out of the
    cache); ``close`` releases every resource, including spill files.

    >>> from repro.api.chunkstore import ChunkStore, InMemoryStore, DiskStore
    >>> isinstance(InMemoryStore(), ChunkStore)
    True
    >>> isinstance(DiskStore(residency_bytes=1 << 20), ChunkStore)
    True
    """

    stats: StoreStats

    def put(self, array) -> ChunkRef: ...

    def get(self, ref: ChunkRef) -> jax.Array: ...

    def pin(self, ref: ChunkRef) -> None: ...

    def unpin(self, ref: ChunkRef) -> None: ...

    def prefetch(self, refs: Iterable[ChunkRef]) -> None: ...

    def trim(self) -> None: ...

    def close(self) -> None: ...


class InMemoryStore:
    """Chunks as permanently-resident arrays — today's semantics, kept.

    No budget, no spill, no accounting beyond ``resident_bytes``: a
    plan over an ``InMemoryStore``-backed collection behaves (and reports)
    exactly like one over raw block arrays, which is what keeps the chunk
    abstraction semantics-free until a budgeted store opts in.
    """

    def __init__(self):
        self.uid = _new_store_uid()
        self.stats = StoreStats()
        self._chunks: dict[int, jax.Array] = {}
        self._next_id = 0
        self._lock = threading.Lock()

    def put(self, array) -> ChunkRef:
        import jax.numpy as jnp

        arr = jnp.asarray(array)
        with self._lock:
            cid = self._next_id
            self._next_id += 1
            self._chunks[cid] = arr
            self.stats.resident_bytes += arr.nbytes
            self.stats.peak_resident_bytes = max(
                self.stats.peak_resident_bytes, self.stats.resident_bytes
            )
        return ChunkRef(self, cid, arr.shape, arr.dtype)

    def get(self, ref: ChunkRef) -> jax.Array:
        try:
            return self._chunks[ref.chunk_id]
        except KeyError:
            raise ChunkStoreError(f"unknown or released chunk {ref.chunk_id}") from None

    def pin(self, ref: ChunkRef) -> None:  # resident forever: nothing to guard
        pass

    def unpin(self, ref: ChunkRef) -> None:
        pass

    def prefetch(self, refs: Iterable[ChunkRef]) -> None:  # already resident
        pass

    def trim(self) -> None:  # in-memory chunks cannot be dropped
        pass

    def close(self) -> None:
        with self._lock:
            self._chunks.clear()
            self.stats.resident_bytes = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class DiskStore:
    """LRU-budgeted residency over memory-mapped ``.npy`` spill blocks.

    Args:
      residency_bytes: target bound on resident chunk bytes.  Eviction
        keeps unpinned residency under the budget; pinned chunks are never
        evicted, so the *peak* can transiently exceed the budget by the
        pinned working set (a streaming executor pins at most the current
        and the prefetched partition — the double buffer).
      spill_dir: directory for spill files.  Default: a fresh temp dir,
        removed on :meth:`close` (and by a GC/atexit finalizer if the
        store is never closed — no temp-file leaks).

    Lifecycle of a chunk: ``put`` → resident (dirty, no file) → eviction
    spills it to ``chunk<id>.npy`` once (two-phase: the buffer moves to a
    pending queue under the lock, the ``np.save`` runs outside it, so
    spill I/O never blocks concurrent gets or prefetch inserts) → later
    ``get``/``prefetch`` reload it (memory-mapped read, copied out so the
    file handle is not held) → further evictions are free drops.  Reloads
    are bit-identical: ``.npy`` round-trips preserve every bit of the
    block, which is what makes re-iteration after spill produce
    bit-identical results.
    """

    def __init__(self, residency_bytes: int, *, spill_dir: str | None = None):
        assert residency_bytes >= 1, residency_bytes
        self.uid = _new_store_uid()
        self.residency_bytes = int(residency_bytes)
        self._own_dir = spill_dir is None
        self._dir = (
            tempfile.mkdtemp(prefix="repro-chunks-") if spill_dir is None else spill_dir
        )
        os.makedirs(self._dir, exist_ok=True)
        self.stats = StoreStats()
        # resident: chunk_id -> array, LRU order (oldest first)
        self._resident: collections.OrderedDict[int, object] = collections.OrderedDict()
        self._meta: dict[int, tuple[tuple, np.dtype, str | None]] = {}  # shape, dtype, spill path
        self._pins: collections.Counter = collections.Counter()
        self._prefetched: set[int] = set()
        # Two-phase eviction: _shrink only MOVES a dirty victim here (under
        # the lock); the np.save happens in _flush_spills OUTSIDE the lock,
        # so spill I/O never blocks concurrent gets/prefetch inserts.
        self._pending_spills: dict[int, object] = {}
        self._pending_bytes = 0
        self._spilling: set[int] = set()  # cids with a write in flight
        self._manifested: set[int] = set()  # cids covered by some manifest()
        self._next_id = 0
        self._lock = threading.RLock()
        self._closed = False
        # GC/interpreter-exit safety net: a store that is never close()d
        # must still not leak its spill directory.
        self._finalizer = (
            weakref.finalize(self, shutil.rmtree, self._dir, True)
            if self._own_dir
            else None
        )

    # -- introspection (tests / diagnostics) --------------------------------

    @property
    def spill_dir(self) -> str:
        return self._dir

    @property
    def closed(self) -> bool:
        return self._closed

    def resident_ids(self) -> list[int]:
        with self._lock:
            return list(self._resident)

    def spill_files(self) -> list[str]:
        if not os.path.isdir(self._dir):
            return []
        return sorted(f for f in os.listdir(self._dir) if f.endswith(".npy"))

    def is_pinned(self, ref: ChunkRef) -> bool:
        with self._lock:
            return self._pins[ref.chunk_id] > 0

    # -- the store contract --------------------------------------------------

    def put(self, array) -> ChunkRef:
        import jax.numpy as jnp

        if self._closed:
            raise ChunkStoreError("put() on a closed DiskStore")
        arr = jnp.asarray(array)
        with self._lock:
            cid = self._next_id
            self._next_id += 1
            self._meta[cid] = (tuple(arr.shape), np.dtype(arr.dtype), None)
            self._insert_resident(cid, arr)
        self._flush_spills()
        return ChunkRef(self, cid, arr.shape, arr.dtype)

    def get(self, ref: ChunkRef) -> jax.Array:
        cid = ref.chunk_id
        with self._lock:
            if self._closed:
                raise ChunkStoreError("get() on a closed DiskStore")
            if cid not in self._meta:
                raise ChunkStoreError(f"unknown chunk {cid}")
            arr = self._resident.get(cid)
            if arr is not None:
                self._resident.move_to_end(cid)
                if cid in self._prefetched:
                    self._prefetched.discard(cid)
                    self.stats.prefetch_hits += 1
                return arr
            pending = self._pending_spills.get(cid)
            if pending is not None:
                # Evicted but its spill write hasn't landed yet: the buffer
                # is still in memory — serve it (no disk read, no reinsert).
                return pending
        # Not resident: load outside the lock so a concurrent prefetch
        # thread never serializes behind this read (and vice versa).
        arr = self._load(cid)
        with self._lock:
            raced = self._resident.get(cid)
            if raced is not None:  # a concurrent load won; keep one copy
                self._resident.move_to_end(cid)
                return raced
            self._insert_resident(cid, arr)
        # Only the miss path flushes: a cold load's insert may have
        # deferred a dirty victim, and without a flush here a gets-only
        # workload would grow the pending queue without bound.  The hit
        # path (prefetched chunks) returns above and never pays a write.
        self._flush_spills()
        return arr

    def pin(self, ref: ChunkRef) -> None:
        with self._lock:
            self._pins[ref.chunk_id] += 1

    def unpin(self, ref: ChunkRef) -> None:
        with self._lock:
            cid = ref.chunk_id
            if self._pins[cid] > 0:
                self._pins[cid] -= 1
            # Spill-on-release: dropping the last pin is the moment a
            # streamed partition stops being needed — shed any overshoot.
            if self._pins[cid] == 0:
                self._shrink()
        self._flush_spills()

    def prefetch(self, refs: Iterable[ChunkRef]) -> None:
        """Load ``refs`` ahead of use; their next ``get`` is a prefetch hit."""
        for ref in refs:
            cid = ref.chunk_id
            with self._lock:
                if self._closed or cid not in self._meta:
                    continue
                if cid in self._resident:
                    self._resident.move_to_end(cid)
                    self._prefetched.add(cid)
                    continue
                if cid in self._pending_spills:
                    # Evicted with its spill write still in flight: the
                    # buffer is in memory and gets are served from pending —
                    # loading now would race the writer (_load would see
                    # path=None).  Honor the flusher's invariant like get().
                    continue
            arr = self._load(cid)
            with self._lock:
                if cid not in self._resident:
                    self._insert_resident(cid, arr)
                # The insert's own _shrink may have evicted the chunk again
                # (budget saturated by pins): only a chunk that is STILL
                # resident may carry the marker, or a later unrelated get
                # would count a phantom prefetch hit.
                if cid in self._resident:
                    self._prefetched.add(cid)
        self._flush_spills()

    def evict(self, ref: ChunkRef) -> None:
        """Explicitly evict one chunk; refused while it is pinned."""
        with self._lock:
            cid = ref.chunk_id
            if self._pins[cid] > 0:
                raise ChunkPinnedError(
                    f"chunk {cid} is pinned ({self._pins[cid]} pins); "
                    "eviction refused"
                )
            if cid in self._resident:
                self._evict_one(cid)
        self._flush_spills()

    def trim(self) -> None:
        """Drop every unpinned resident chunk (spilling unwritten ones).

        The release hook the prepare cache and executor ``close()`` use:
        chunk data becomes reloadable-from-disk instead of resident.
        """
        with self._lock:
            for cid in [c for c in self._resident if self._pins[c] == 0]:
                self._evict_one(cid)
        self._flush_spills()

    def handle(self, ref: ChunkRef) -> ChunkHandle | None:
        """Picklable :class:`ChunkHandle` for ``ref``, if workers can read it.

        A chunk is handle-able once it has a spill file OR has appeared in
        a :meth:`manifest` (whose shm entries workers resolve without any
        file).  Returns None otherwise — callers (the cluster payload
        builder) then ship the bytes inline/exported instead.
        """
        with self._lock:
            meta = self._meta.get(ref.chunk_id)
            if meta is None or (meta[2] is None and ref.chunk_id not in self._manifested):
                return None
        return ChunkHandle(
            store_uid=self.uid,
            chunk_id=ref.chunk_id,
            shape=ref.shape,
            dtype_str=ref.dtype.str,
        )

    def manifest(self, *, export=None, known: Iterable[int] = ()) -> StoreManifest:
        """Handoff projection for worker attach — shm-first, incremental.

        Args:
          export: ``callable(chunk_id, array) -> ShmBlockRef | None`` — the
            executor's shared-memory exporter.  Chunks that are resident
            (or eviction-pending) hand off as ``("shm", ref)`` entries
            with **no disk write**; only when the exporter declines (shm
            budget exhausted, or ``export is None``) does the chunk
            force-spill to a ``("file", ...)`` entry.  Chunks that already
            have a spill file always reuse it.
          known: chunk ids the caller has already received — the returned
            manifest contains only the REST, so a store that grew
            mid-session yields a cheap delta instead of re-shipping (and
            re-exporting) the world.

        Billing: only a genuinely new spill *write* counts as
        ``stats.spills``/``bytes_spilled``.  Shm handoffs and chunks whose
        file already exists bill nothing — a second manifest of an
        unchanged store is free.
        """
        self._flush_spills()  # settle any deferred eviction writes first
        known = set(known)
        chunks: dict = {}
        while True:
            with self._lock:
                if self._closed:
                    raise ChunkStoreError("manifest() on a closed DiskStore")
                cid = next(
                    (
                        c
                        for c, (_s, _d, p) in self._meta.items()
                        if p is None
                        and c not in known
                        and c not in chunks
                        and c not in self._spilling
                    ),
                    None,
                )
                if cid is None:
                    for c, (s, d, p) in self._meta.items():
                        if p is not None and c not in known and c not in chunks:
                            chunks[c] = ("file", p, s, np.dtype(d).str)
                    self._manifested.update(chunks)
                    return StoreManifest(uid=self.uid, spill_dir=self._dir, chunks=chunks)
                arr = self._resident.get(cid)
                if arr is None:
                    arr = self._pending_spills.get(cid)
                shape, dtype, _ = self._meta[cid]
            # Shm-first: the resident buffer hands off as a segment
            # descriptor — the residency cache stays warm, nothing is
            # written, nothing is billed.
            if export is not None:
                ref = export(cid, np.asarray(arr))
                if ref is not None:
                    chunks[cid] = ("shm", ref)
                    continue
            # Fallback: force-spill (exporter declined / shm disabled).
            # The write happens outside the lock; _spilling claims the
            # chunk against a concurrent flusher.
            with self._lock:
                if cid in self._spilling:
                    continue  # a flusher claimed it meanwhile: re-scan
                self._spilling.add(cid)
            path = self._path(cid)
            np.save(path, np.asarray(arr))
            with self._lock:
                self._spilling.discard(cid)
                if self._closed or cid not in self._meta:
                    raise ChunkStoreError("DiskStore closed during manifest()")
                _s, _d, existing = self._meta[cid]
                self._meta[cid] = (shape, dtype, path)
                if existing is None:  # bill only a genuinely NEW spill write
                    self.stats.spills += 1
                    self.stats.bytes_spilled += self._nbytes(cid)
                if cid in self._pending_spills:
                    del self._pending_spills[cid]
                    self._pending_bytes -= self._nbytes(cid)

    def close(self) -> None:
        """Release resident chunks and delete the spill directory."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._resident.clear()
            self._meta.clear()
            self._prefetched.clear()
            self._pins.clear()
            self._pending_spills.clear()
            self._pending_bytes = 0
            self._manifested.clear()
            self.stats.resident_bytes = 0
        if self._finalizer is not None:
            self._finalizer()  # rmtree now, exactly once
        elif self._own_dir:  # pragma: no cover — finalizer covers own dirs
            shutil.rmtree(self._dir, ignore_errors=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- internals (call with lock held unless noted) ------------------------

    def _path(self, cid: int) -> str:
        return os.path.join(self._dir, f"chunk{cid}.npy")

    def _nbytes(self, cid: int) -> int:
        shape, dtype, _ = self._meta[cid]
        return int(np.prod(shape)) * dtype.itemsize if shape else dtype.itemsize

    def _insert_resident(self, cid: int, arr) -> None:
        self._resident[cid] = arr
        self.stats.resident_bytes += self._nbytes(cid)
        # Peak tracks the resident CACHE; a deferred spill buffer is a
        # transient I/O buffer (bounded: every mutating store call flushes
        # before returning), not cached residency — including it would make
        # the peak depend on flush-thread timing.
        self.stats.peak_resident_bytes = max(
            self.stats.peak_resident_bytes, self.stats.resident_bytes
        )
        self._shrink()

    def _shrink(self) -> None:
        """Evict LRU unpinned chunks until residency fits the budget."""
        while self.stats.resident_bytes > self.residency_bytes:
            victim = next(
                (c for c in self._resident if self._pins[c] == 0), None
            )
            if victim is None:
                return  # everything resident is pinned: overshoot, recorded in peak
            self._evict_one(victim)

    def _evict_one(self, cid: int) -> None:
        """Drop ``cid`` from residency; a dirty chunk's write is DEFERRED.

        Phase one of two-phase eviction (lock held): the buffer moves to
        ``_pending_spills`` and stays servable from memory; phase two
        (:meth:`_flush_spills`, lock released) performs the ``np.save``.
        """
        arr = self._resident.pop(cid)
        _shape, _dtype, path = self._meta[cid]
        if path is None:  # spill-on-eviction: first eviction writes the file
            self._pending_spills[cid] = arr
            self._pending_bytes += self._nbytes(cid)
        self.stats.evictions += 1
        self.stats.resident_bytes -= self._nbytes(cid)
        self._prefetched.discard(cid)

    def _flush_spills(self) -> None:
        """Write deferred spills to disk.  Call with the lock RELEASED.

        The whole point of the two-phase split: the (slow) ``np.save`` runs
        here, outside the lock, so concurrent gets and prefetch inserts
        never serialize behind spill I/O.  Entries stay servable from
        ``_pending_spills`` until their file path is recorded, so a reader
        can never observe "not resident, not pending, no file".  Multiple
        threads may flush concurrently; ``_spilling`` claims a chunk per
        writer.
        """
        while True:
            with self._lock:
                cid = next(
                    (c for c in self._pending_spills if c not in self._spilling),
                    None,
                )
                if cid is None or self._closed:
                    return
                arr = self._pending_spills[cid]
                self._spilling.add(cid)
                shape, dtype, _ = self._meta[cid]
            path = self._path(cid)
            try:
                np.save(path, np.asarray(arr))
            except OSError:
                # close() raced us and removed the spill dir; the store is
                # (or is about to be) closed — nothing left to persist.
                with self._lock:
                    self._spilling.discard(cid)
                return
            with self._lock:
                self._spilling.discard(cid)
                if self._closed or cid not in self._meta:
                    return
                self._meta[cid] = (shape, dtype, path)
                self.stats.spills += 1
                self.stats.bytes_spilled += self._nbytes(cid)
                if cid in self._pending_spills:
                    del self._pending_spills[cid]
                    self._pending_bytes -= self._nbytes(cid)

    def _load(self, cid: int):
        """Read one spilled chunk back (no lock: pure file I/O)."""
        import jax.numpy as jnp

        with self._lock:
            meta = self._meta.get(cid)
        if meta is None:
            raise ChunkStoreError(f"unknown chunk {cid}")
        shape, dtype, path = meta
        if path is None:
            # Unreachable in practice: a dirty chunk is resident or pending
            # (both checked by get() before calling _load), and the flusher
            # records the file path BEFORE removing the pending entry.
            raise ChunkStoreError(f"chunk {cid} has no resident copy and no spill file")
        mm = np.load(path, mmap_mode="r")
        arr = jnp.asarray(np.asarray(mm))  # copy out of the mmap, then free it
        with self._lock:
            self.stats.loads += 1
            self.stats.bytes_loaded += self._nbytes(cid)
        return arr
