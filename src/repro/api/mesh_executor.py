"""MeshExecutor — sharded scheduling of a TaskGraph over a JAX device mesh.

The third backend of the execution layer (DESIGN.md §5.4): logical
*locations* are mapped onto a 1-D device mesh and all partition tasks of a
location group execute as ONE sharded dispatch.  Where LocalExecutor emits
one host dispatch per task and ThreadedExecutor overlaps them with
threads, MeshExecutor stacks the same-signature tasks of a lowered
:class:`~repro.api.lowering.TaskGraph` along a leading axis, shards that
axis over the mesh with :func:`repro.distributed.compat.shard_map`, folds
each rank's local tasks with the plan's combine, and merges across ranks
with a psum-style collective (all-gather + fold, the all-reduce of an
arbitrary associative monoid — plain ``psum`` when the combine is a sum).

Accounting maps onto the existing :class:`~repro.core.engine.EngineReport`:

* ``dispatches`` — sharded calls (one per same-signature task run), not
  per-task invocations; still bounded by C1.
* ``bytes_moved`` — the collective traffic estimate: each of the M mesh
  ranks receives the other M-1 partial pytrees, so one cross-rank merge
  bills ``(M - 1) × partial_nbytes`` (the per-rank ring volume).
* ``merges`` — cross-rank collective merges (plus the plan-order fold over
  distinct task runs, e.g. ragged tails, exactly as on the other backends).

Tasks that cannot be stacked — ``map_partitions`` views, un-reduced maps,
singleton runs — fall back to per-task dispatch, so every plan the other
backends accept runs here too, and results agree up to float reassociation
(C4).

Ordering: buckets preserve graph task order, so the fold visits partials
in plan order whenever task signatures don't interleave (uniform blocks —
the common case).  With interleaved signatures (a ragged run between
uniform ones) partials are folded bucket-by-bucket, which REASSOCIATES AND
REORDERS the combine relative to LocalExecutor: combines must be
commutative up to float reassociation (true of every reduction in the
paper's apps) for this backend.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.api.executors import _PlanExecutor, _Unit
from repro.api.lowering import (
    Capabilities,
    Task,
    TaskGraph,
    _partition_body,
    stacked_fold,
)
from repro.core.engine import TaskEngine
from repro.distributed.compat import shard_map

__all__ = ["MeshExecutor"]


def _tree_nbytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape")
    )


class MeshExecutor(_PlanExecutor):
    """Execute location groups as sharded dispatches over a device mesh.

    Args:
      engine: shared :class:`TaskEngine` (accounting + jit cache).
      devices: devices backing the mesh; defaults to ``jax.devices()``.
        The mesh axis size for a run of G stacked tasks is the largest
        divisor of G not exceeding the device count (1 on a single-device
        host — still one sharded dispatch, with zero collective traffic).
      axis_name: mesh axis name the location dimension shards over.
    """

    def __init__(
        self,
        engine: TaskEngine | None = None,
        *,
        devices=None,
        axis_name: str = "loc",
    ):
        super().__init__(engine)
        self._devices = tuple(devices) if devices is not None else None
        self.axis_name = axis_name
        self._meshes: dict[int, Mesh] = {}

    @property
    def capabilities(self) -> Capabilities:
        return Capabilities(
            name=type(self).__name__,
            prefer_pallas=jax.default_backend() == "tpu",
            grouped_dispatch=True,
        )

    # -- mesh plumbing ---------------------------------------------------------

    def _device_count(self) -> int:
        return len(self._devices) if self._devices is not None else len(jax.devices())

    def _mesh(self, size: int) -> Mesh:
        m = self._meshes.get(size)
        if m is None:
            devs = self._devices if self._devices is not None else tuple(jax.devices())
            m = self._meshes[size] = Mesh(np.array(devs[:size]), (self.axis_name,))
        return m

    @staticmethod
    def _axis_size(n_tasks: int, n_devices: int) -> int:
        """Largest mesh size that evenly tiles the stacked task dimension."""
        for m in range(min(n_tasks, max(n_devices, 1)), 0, -1):
            if n_tasks % m == 0:
                return m
        return 1

    # -- scheduling ------------------------------------------------------------

    def _plan_dispatches(self, graph: TaskGraph) -> list[_Unit]:
        """Bucketed dispatch units for the shared scheduler core.

        Tasks with the same dispatch signature — same jit key + same
        per-task data shapes — stack into ONE sharded unit, PRESERVING
        graph task order, so within a bucket the fold visits partials in
        plan order (lowering emits partition tasks location-major, which is
        what maps contiguous location groups onto contiguous mesh ranks).
        Operands stay lazy here: buckets form from Task.data_shapes
        metadata and each bucket materializes its stacks only at its own
        dispatch.  Views, un-reduced maps and singleton buckets fall back
        to per-task units (the LocalExecutor path).
        """
        if graph.merge is None or not graph.tasks or any(
            not t.counted for t in graph.tasks
        ):
            return super()._plan_dispatches(graph)

        buckets: dict[tuple, list[Task]] = {}
        for t in graph.tasks:
            buckets.setdefault((t.key, t.data_shapes), []).append(t)

        units: list[_Unit] = []
        for tasks in buckets.values():
            if len(tasks) == 1:
                t = tasks[0]
                units.append(
                    _Unit(index=len(units), location=t.location, tasks=(t,),
                          run=self._bind(t), kind=t.kind)
                )
            else:
                units.append(
                    _Unit(
                        index=len(units),
                        location=-1,
                        tasks=tuple(tasks),
                        run=functools.partial(self._sharded_dispatch, graph, tasks),
                        kind="sharded",
                    )
                )
        return units

    def _sharded_dispatch(self, graph: TaskGraph, tasks: list[Task]) -> Any:
        t0 = tasks[0]
        n_data = t0.n_data
        combine = graph.merge.combine
        g = len(tasks)
        m = self._axis_size(g, self._device_count())
        mesh = self._mesh(m)
        axis = self.axis_name

        # stack each per-task data operand along a new leading (group) axis;
        # extras are plan-wide and shared by every task of the signature
        per_task = [t.operands() for t in tasks]
        stacked = tuple(
            jnp.stack([ops[j] for ops in per_task], axis=0) for j in range(n_data)
        )
        extras = per_task[0][n_data:]
        del per_task

        # local fold over the rank's tasks = the generic partition body over
        # the group axis (one source of truth for the first/scan fold)
        local_fold = _partition_body(t0.fn, combine, n_data)

        def fused(*ops):
            acc = local_fold(*ops)
            # psum-style cross-rank merge: all-gather the rank partials and
            # fold in rank order (all-reduce for an arbitrary monoid) — the
            # same stacked_fold the host-side merge task runs, so the two
            # merge paths cannot drift apart.
            gathered = jax.tree.map(
                lambda x: jax.lax.all_gather(x, axis, axis=0, tiled=False), acc
            )
            return stacked_fold(combine)(gathered)

        sharded = shard_map(
            fused,
            mesh=mesh,
            in_specs=(P(axis),) * n_data + (P(),) * len(extras),
            out_specs=P(),
            check_vma=False,
        )
        # the key carries the merge identity too: the same map fn reduced by
        # a different combine must not reuse this compiled fold
        key = ("mesh", t0.key, graph.merge.key, m, t0.data_shapes, g)
        value = self.engine.task(sharded, key=key)(*stacked, *extras)
        if m > 1:
            self.engine.report.merges += 1
            self.engine.report.bytes_moved += (m - 1) * _tree_nbytes(value)
        return value
