"""Partition-kernel registry — fused Pallas implementations of block fns.

The generic SplIter lowering fuses a partition's per-block work into one
``lax.scan`` (paper Listing 5).  For block functions with a hand-written
Pallas partition kernel (``repro.kernels.partition_reduce``) the lowering
can do strictly better: ONE ``pallas_call`` whose *grid* iterates the
partition's HBM blocks while the reduction accumulator stays in VMEM —
the worksharing-task idea of Maroñas et al. (arXiv:2004.03258) expressed
at the kernel level.

The registry maps a *base* block function to a factory.  App modules
register their kernels at import time (``repro/core/apps/histogram.py``,
``.../kmeans.py``); the lowering pass resolves ``spec.fn`` — unwrapping
``functools.partial`` layers so e.g. ``partial(histogramdd_block, bins=8)``
finds the histogram kernel with the right static parameters — and emits a
``partition_pallas`` task when the policy's ``fusion`` knob and the backend
capabilities allow it.  Contract: for a stacked run ``(nblocks, rows, *row)``
the kernel's result equals folding ``block_fn`` over the blocks with the
plan's ``combine`` (up to float reassociation), so fused and generic
lowerings are interchangeable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Hashable

import jax

__all__ = [
    "PartitionKernel",
    "register_partition_kernel",
    "partition_kernel_for",
    "kernel_ref",
    "kernel_from_ref",
    "pallas_interpret",
]


def pallas_interpret() -> bool:
    """Whether registered kernels should run the Pallas interpreter.

    Compiled Mosaic on TPU, interpreter elsewhere (CPU tests).  Resolved at
    call time, not import time, so jax backend state is never touched by a
    bare import.  Kernel factories thread this into their ``pallas_call``s —
    a kernel that always interprets would be slower than the scan it
    replaces on exactly the backend that prefers it.
    """
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class PartitionKernel:
    """A fused per-partition implementation of one block fn + combine.

    Attributes:
      name: human-readable kernel name (shows up in ``TaskGraph`` dumps).
      key: stable jit-cache key — must encode every static parameter baked
        into ``fn`` (e.g. ``("hist_dd", bins, lo, hi)``) so two plans with
        different statics never share a compiled program.
      fn: ``fn(stacked, *extra_args) -> partial`` where ``stacked`` is the
        partition's same-shape blocks ``(nblocks, rows, *row_shape)`` and
        the result matches the block-fn/combine fold over those blocks.
      supports: optional shape guard ``(stacked_shape, extra_args) -> bool``;
        returning False falls back to the generic scan lowering.
    """

    name: str
    key: Hashable
    fn: Callable
    supports: Callable[[tuple, tuple], bool] | None = None

    def supported(self, stacked_shape: tuple, extra_args: tuple) -> bool:
        return self.supports is None or bool(self.supports(stacked_shape, extra_args))


# base block fn -> factory(partial_args, partial_kwargs) -> PartitionKernel | None
_REGISTRY: dict[Callable, Callable[[tuple, dict], PartitionKernel | None]] = {}
# registry NAME -> factory, and base fn -> registry name.  The name is the
# by-name lookup surface remote workers rehydrate kernels through: a
# ClusterExecutor ships ``("kernel", name, statics)`` instead of the
# (unpicklable) factory-built closure, and the worker — having imported the
# registering module — resolves the same factory by name.
_BY_NAME: dict[str, Callable[[tuple, dict], PartitionKernel | None]] = {}
_NAMES: dict[Callable, str] = {}


def register_partition_kernel(
    block_fn: Callable,
    factory: Callable[[tuple, dict], PartitionKernel | None],
    *,
    name: str | None = None,
) -> None:
    """Register a fused-kernel factory for ``block_fn``.

    ``factory(args, kwargs)`` receives the positional/keyword arguments
    accumulated on any ``functools.partial`` wrappers around ``block_fn``
    (empty tuples when the fn is used bare) and returns a
    :class:`PartitionKernel`, or None when those statics have no fused
    implementation.

    ``name`` is the registry name used for by-name lookup from worker
    processes (:func:`kernel_from_ref`); it defaults to
    ``"module:qualname"`` of ``block_fn``, which doubles as the import
    spec that triggers the registration on the worker side.
    """
    if name is None:
        name = f"{block_fn.__module__}:{block_fn.__qualname__}"
    _REGISTRY[block_fn] = factory
    _BY_NAME[name] = factory
    _NAMES[block_fn] = name


def _unwrap(fn: Callable) -> tuple[Callable, tuple, dict]:
    """Peel ``functools.partial`` layers, merging their args/kwargs."""
    args: tuple = ()
    kwargs: dict = {}
    while isinstance(fn, functools.partial):
        args = fn.args + args
        kwargs = {**fn.keywords, **kwargs}
        fn = fn.func
    return fn, args, kwargs


def partition_kernel_for(fn: Callable) -> PartitionKernel | None:
    """Resolve the registered fused kernel for a (possibly partial) block fn."""
    base, args, kwargs = _unwrap(fn)
    factory = _REGISTRY.get(base)
    if factory is None:
        return None
    return factory(args, kwargs)


def kernel_ref(fn: Callable) -> tuple | None:
    """Picklable by-name reference for the kernel a block fn resolves to.

    ``(name, args, sorted_kwargs)`` — everything a worker needs to rebuild
    the same :class:`PartitionKernel` through the named registry, or None
    when ``fn`` has no registered kernel or carries unhashable statics.
    """
    base, args, kwargs = _unwrap(fn)
    name = _NAMES.get(base)
    if name is None:
        return None
    statics = (tuple(args), tuple(sorted(kwargs.items())))
    try:
        hash(statics)
    except TypeError:
        return None
    return (name, *statics)


def kernel_from_ref(ref: tuple) -> PartitionKernel | None:
    """Rebuild a kernel from :func:`kernel_ref` output (worker side).

    Importing the module half of the registry name runs its
    ``register_partition_kernel`` calls, so a fresh worker process finds
    the factory without any extra bootstrapping.
    """
    import importlib

    name, args, kw = ref
    if name not in _BY_NAME:
        importlib.import_module(name.split(":", 1)[0])
    factory = _BY_NAME.get(name)
    if factory is None:
        return None
    return factory(tuple(args), dict(kw))
