"""Collection — the fluent, lazy public surface of the execution layer.

::

    from repro.api import Collection, SplIter, LocalExecutor

    result = (
        Collection.from_array(x, block_rows=128, num_locations=8)
        .split(SplIter(partitions_per_location=2))
        .map_blocks(block_fn, extra_args=(centers,))
        .reduce(combine)
        .compute(executor=LocalExecutor())
    )
    result.value, result.report.dispatches

Every fluent method returns a new Collection wrapping a plan node; nothing
executes until ``.compute()``.  Multi-input workloads (points + aligned
labels) zip sources: ``Collection.zip(cx, cy).split(p).map_partitions(f)``.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

from repro.api.executors import ComputeResult, Executor, LocalExecutor, _default_local
from repro.api.plan import (
    ExecutionPlan,
    MapBlocks,
    MapPartitions,
    PlanError,
    PlanNode,
    Reduce,
    Source,
    Split,
)
from repro.api.policy import ExecutionPolicy, as_policy
from repro.core.blocked import BlockedArray, PlacementPolicy, contiguous_placement

__all__ = ["Collection"]


class Collection:
    """A lazy, executor-backed view over one or more blocked arrays.

    Nothing executes until :meth:`compute`; every fluent method returns a
    new Collection wrapping a plan node:

    >>> import jax.numpy as jnp
    >>> from repro.api import Collection, SplIter, LocalExecutor
    >>> res = (
    ...     Collection.from_array(jnp.arange(8.0), block_rows=2, num_locations=2)
    ...     .split(SplIter())
    ...     .map_blocks(jnp.sum)
    ...     .reduce(lambda a, b: a + b)
    ...     .compute(executor=LocalExecutor())
    ... )
    >>> float(res.value)
    28.0
    >>> res.report.dispatches  # one fused task per location + the merge
    3
    """

    def __init__(self, node: PlanNode):
        self._node = node

    # -- construction ----------------------------------------------------------

    @classmethod
    def from_array(
        cls,
        x: jax.Array,
        block_rows: int,
        *,
        num_locations: int = 1,
        placement: PlacementPolicy = contiguous_placement,
        store=None,
    ) -> "Collection":
        """Block ``x`` along axis 0 (ragged tail allowed) and wrap it.

        With ``store`` (a :class:`~repro.api.chunkstore.ChunkStore`) the
        blocks become chunk references resolved at dispatch time — pair a
        :class:`~repro.api.chunkstore.DiskStore` with
        :class:`~repro.api.StreamExecutor` for out-of-core execution.
        """
        ba = BlockedArray.from_array(
            x, block_rows, num_locations=num_locations, policy=placement,
            store=store,
        )
        return cls(Source((ba,)))

    @classmethod
    def from_blocked(
        cls, arrays: BlockedArray | Sequence[BlockedArray]
    ) -> "Collection":
        """Wrap existing :class:`BlockedArray` input(s) (must be aligned)."""
        if isinstance(arrays, BlockedArray):
            arrays = (arrays,)
        return cls(Source(tuple(arrays)))

    @classmethod
    def zip(cls, *collections: "Collection") -> "Collection":
        """Zip raw (un-split, un-mapped) collections into one aligned source."""
        arrays: list[BlockedArray] = []
        for c in collections:
            if not isinstance(c._node, Source):
                raise PlanError("Collection.zip requires raw source collections")
            arrays.extend(c._node.arrays)
        return cls(Source(tuple(arrays)))

    # -- the fluent plan builders ----------------------------------------------

    def split(self, policy: ExecutionPolicy | str) -> "Collection":
        """Choose the execution granularity (Baseline / SplIter / Rechunk)."""
        return Collection(Split(self._node, as_policy(policy)))

    def map_blocks(self, fn: Callable[..., Any], *, extra_args: tuple = ()) -> "Collection":
        """Apply ``fn(*blocks, *extra_args)`` per aligned block group.

        ``extra_args`` are traced operands shared by every task (e.g. the
        current centroids) — arguments, not baked-in constants, so
        iterative callers re-dispatch without re-tracing.
        """
        return Collection(MapBlocks(self._node, fn, tuple(extra_args)))

    def map_partitions(self, fn: Callable[..., Any]) -> "Collection":
        """Apply ``fn(view: PartitionView)`` per locality partition.

        Under ``Baseline`` every block is its own single-block partition,
        so one code path expresses both per-block and consolidated
        execution (the k-NN / Cascade SVM pattern).
        """
        return Collection(MapPartitions(self._node, fn))

    def reduce(self, combine: Callable[[Any, Any], Any]) -> "Collection":
        """Fold all map partials with associative ``combine``."""
        return Collection(Reduce(self._node, combine))

    # -- materialization -------------------------------------------------------

    def plan(self) -> ExecutionPlan:
        """Validate and return the plan IR without executing it."""
        return ExecutionPlan(self._node)

    def compute(self, executor: Executor | None = None) -> ComputeResult:
        """Execute the plan; a fresh :class:`LocalExecutor` when none given.

        Any backend accepts any plan — the policy/plan pair is
        backend-independent, so the same chain runs sequentially
        (:class:`LocalExecutor`), thread-overlapped
        (:class:`~repro.api.executors.ThreadedExecutor`), sharded over a
        device mesh (:class:`~repro.api.mesh_executor.MeshExecutor`),
        streamed out of core
        (:class:`~repro.api.stream_executor.StreamExecutor`), or over a
        fault-tolerant pool of worker processes
        (:class:`~repro.api.cluster_executor.ClusterExecutor`) by swapping
        this one argument.
        """
        ex = executor if executor is not None else _default_local()
        return ex.execute(self.plan())

    def compute_async(self, executor: Executor | None = None) -> "ComputeFuture":
        """Submit the plan without waiting — pipelined iteration (§14).

        On a pipelined backend (``ThreadedExecutor``, ``ClusterExecutor``,
        ``StreamExecutor``) consecutive ``compute_async`` submissions
        overlap: the next iteration's units launch as their same-partition
        predecessors finish, with no per-execute barrier.  The returned
        :class:`~repro.api.futures.ComputeFuture` yields the usual
        :class:`~repro.api.executors.ComputeResult` from ``result()``, and
        ``fut.map(fn)`` derives a lazy
        :class:`~repro.api.futures.Deferred` usable as the next
        iteration's ``extra_args`` operand (the loop-carried value).
        Non-pipelined backends execute synchronously and return an
        already-completed future — same results, same code.
        """
        ex = executor if executor is not None else _default_local()
        return ex.execute_async(self.plan())

    def __repr__(self) -> str:  # pragma: no cover
        return f"Collection<{type(self._node).__name__}>"
