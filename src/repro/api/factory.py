"""engine() — the one construction path for every execution backend.

The per-backend constructors grew organically across DESIGN.md §11–§16
(``LocalExecutor()``, ``ThreadedExecutor()``, ``MeshExecutor(devices=...)``,
``StreamExecutor(prefetch_depth=...)``, ``ClusterExecutor(shm=..., steal=...,
p2p=...)``, ``JobServer(executor=...)``) and with them six slightly
different keyword surfaces.  :func:`engine` consolidates them behind a
single factory::

    from repro.api import engine, EngineConfig

    with engine("cluster", config=EngineConfig(steal=True, p2p=True)) as ex:
        result = collection.compute(executor=ex)

* ``backend`` picks the strategy by name (the table below); ``config`` is
  a frozen :class:`EngineConfig` carrying every backend's knobs with
  their constructor defaults — each backend reads only the fields it
  understands, so one config object can describe a whole experiment
  matrix and be handed to different backends unchanged.
* keyword ``overrides`` patch individual fields without building a config
  first: ``engine("cluster", steal=True)``.
* every backend supports ``with engine(...) as ex:`` — context-manager
  exit is :meth:`close`, the idiom docs and examples construct with.

The old constructors keep working (the entire pre-§16 API) but emit a
``DeprecationWarning`` pointing here; library-internal defaults construct
through the same suppressed path this factory uses.

============  =========================================================
backend       class
============  =========================================================
``local``     :class:`~repro.api.executors.LocalExecutor`
``threaded``  :class:`~repro.api.executors.ThreadedExecutor`
``mesh``      :class:`~repro.api.mesh_executor.MeshExecutor`
``stream``    :class:`~repro.api.stream_executor.StreamExecutor`
``cluster``   :class:`~repro.api.cluster_executor.ClusterExecutor`
``server``    :class:`~repro.api.jobserver.JobServer` (over an inner
              ``server_backend`` engine it owns)
============  =========================================================
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["EngineConfig", "engine", "BACKENDS"]

#: backend names :func:`engine` accepts, in documentation order.
BACKENDS = ("local", "threaded", "mesh", "stream", "cluster", "server")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen union of every backend's constructor knobs.

    Fields default to the underlying constructors' defaults, so
    ``EngineConfig()`` reproduces ``LocalExecutor()`` /
    ``ClusterExecutor()`` / ... exactly.  A backend consumes only its own
    section; setting a foreign field is harmless (ignored), which is what
    lets one config drive an A/B matrix across backends.

    Use :meth:`dataclasses.replace` (or :func:`engine`'s keyword
    overrides) to derive variants — the object itself never mutates, so a
    config in a bench table or a test fixture stays a value.
    """

    # -- shared ------------------------------------------------------------
    engine: Any = None                  # repro.core.engine.TaskEngine | None

    # -- stream ------------------------------------------------------------
    prefetch_depth: int = 1
    close_stores: bool = True

    # -- mesh --------------------------------------------------------------
    devices: tuple | None = None
    axis_name: str = "loc"

    # -- cluster -----------------------------------------------------------
    max_retries: int = 2
    heartbeat_s: float = 0.2
    heartbeat_timeout_s: float = 30.0
    fault_plan: Any = None              # repro.api.cluster_executor.FaultPlan
    log_dir: str | None = None
    poll_s: float = 0.02
    shm: bool | None = None
    shm_min_bytes: int = 1024
    shm_segment_bytes: int = 4 << 20
    shm_budget_bytes: int | None = None
    p2p: bool | str = "auto"
    p2p_min_bytes: int = 1 << 16
    steal: bool = False
    autoscale: bool = False
    min_workers: int = 1
    max_workers: int | None = None
    scale_up_backlog: int = 2
    scale_idle_ticks: int = 50

    # -- server ------------------------------------------------------------
    root: str | None = None
    server_backend: str = "local"       # inner engine() the server owns
    max_pending: int = 16
    snapshot_every: int = 8
    fsync: bool = True
    autostart: bool = True


def _cluster_kwargs(cfg: EngineConfig) -> dict:
    return dict(
        max_retries=cfg.max_retries,
        heartbeat_s=cfg.heartbeat_s,
        heartbeat_timeout_s=cfg.heartbeat_timeout_s,
        fault_plan=cfg.fault_plan,
        log_dir=cfg.log_dir,
        poll_s=cfg.poll_s,
        shm=cfg.shm,
        shm_min_bytes=cfg.shm_min_bytes,
        shm_segment_bytes=cfg.shm_segment_bytes,
        shm_budget_bytes=cfg.shm_budget_bytes,
        p2p=cfg.p2p,
        p2p_min_bytes=cfg.p2p_min_bytes,
        steal=cfg.steal,
        autoscale=cfg.autoscale,
        min_workers=cfg.min_workers,
        max_workers=cfg.max_workers,
        scale_up_backlog=cfg.scale_up_backlog,
        scale_idle_ticks=cfg.scale_idle_ticks,
    )


def engine(
    backend: str = "local",
    *,
    config: EngineConfig | None = None,
    **overrides,
):
    """Construct an execution backend by name (the blessed entry point).

    Args:
      backend: one of :data:`BACKENDS`.
      config: an :class:`EngineConfig`; ``None`` means all defaults.
      **overrides: individual :class:`EngineConfig` fields to replace —
        ``engine("cluster", steal=True)`` ≡
        ``engine("cluster", config=EngineConfig(steal=True))``.  Unknown
        names raise ``TypeError`` (a misspelled knob must not silently
        no-op).

    Returns an executor (or, for ``"server"``, a
    :class:`~repro.api.jobserver.JobServer`) ready for
    ``with engine(...) as ex:`` — exit closes it.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}"
        )
    cfg = config if config is not None else EngineConfig()
    if overrides:
        names = {f.name for f in dataclasses.fields(EngineConfig)}
        unknown = sorted(set(overrides) - names)
        if unknown:
            raise TypeError(
                f"unknown EngineConfig field(s) {unknown}; "
                f"valid fields: {sorted(names)}"
            )
        cfg = dataclasses.replace(cfg, **overrides)

    # Late imports: the factory sits above every backend module, and the
    # cluster/server stacks are heavy (multiprocessing, journal) — pay
    # only for the backend actually constructed.
    from repro.api.executors import _factory_construction

    with _factory_construction():
        if backend == "local":
            from repro.api.executors import LocalExecutor

            return LocalExecutor(engine=cfg.engine)
        if backend == "threaded":
            from repro.api.executors import ThreadedExecutor

            return ThreadedExecutor(engine=cfg.engine)
        if backend == "mesh":
            from repro.api.mesh_executor import MeshExecutor

            return MeshExecutor(
                engine=cfg.engine,
                devices=cfg.devices,
                axis_name=cfg.axis_name,
            )
        if backend == "stream":
            from repro.api.stream_executor import StreamExecutor

            return StreamExecutor(
                engine=cfg.engine,
                prefetch_depth=cfg.prefetch_depth,
                close_stores=cfg.close_stores,
            )
        if backend == "cluster":
            from repro.api.cluster_executor import ClusterExecutor

            return ClusterExecutor(engine=cfg.engine, **_cluster_kwargs(cfg))
        # "server": a JobServer owning an inner engine() backend.
        from repro.api.jobserver import JobServer

        if cfg.server_backend == "server":
            raise ValueError("server_backend cannot itself be 'server'")
        inner = engine(cfg.server_backend, config=cfg)
        server = JobServer(
            root=cfg.root,
            executor=inner,
            max_pending=cfg.max_pending,
            snapshot_every=cfg.snapshot_every,
            fsync=cfg.fsync,
            autostart=cfg.autostart,
        )
        # The factory built the inner engine FOR this server; the server's
        # close() must take it down (a caller-passed executor stays the
        # caller's to close — the constructor's contract).
        server._owns_executor = True
        return server
