"""Write-ahead job journal — the durable half of the JobServer (DESIGN.md §12).

An append-only record log with the same crash posture as the checkpoint
layout (:mod:`repro.checkpoint.checkpointer`): every record is framed as
``[4-byte big-endian length][4-byte CRC32][pickled payload]`` and the file
is fsynced after each append, so the tail of the file after a crash is
either a complete record or torn garbage that :meth:`JobJournal.replay`
detects (short frame or CRC mismatch) and drops — a torn tail never
poisons the records before it, exactly like a ``.tmp`` step directory
never shadows a COMMITTED checkpoint.

What the :class:`~repro.api.jobserver.JobServer` writes through it:

``("job", ...)``
    One submission record per accepted job: id, tenant, weight, the
    :func:`~repro.api.lowering.plan_fingerprint`, and — when the plan is
    durable (fn/combine referencable via :mod:`repro.api.fnref`, inputs
    resident) — the encoded replay payload.
``("start", ...)``
    The RESOLVED policy a job's first unit ran under (``SplIter("auto")``
    pins its granularity here), so a resume re-lowers to the *same* unit
    decomposition the completion records are keyed against.
``("unit", ...)``
    One record per completed unit: the restart-stable unit key plus the
    pickled (host numpy) partial result — what lets a resumed job skip
    the unit instead of recomputing it.
``("done" | "failed", ...)``
    Terminal records carrying the job's serialized
    :class:`~repro.core.engine.EngineReport` / error summary.

Replay is full-file: the journal is the authoritative event history and
the checkpoint snapshots are an optimization layered on top (scheduler
fairness state, aggregated report segments), never the other way around.
"""

from __future__ import annotations

import os
import pickle
import struct
import zlib
from typing import Any, Iterator

__all__ = ["JobJournal"]

_HEADER = struct.Struct(">II")  # payload length, CRC32(payload)


class JobJournal:
    """Append-only, torn-tail-tolerant record log (one file).

    ``fsync=True`` (the default) makes every append durable before it
    returns — the write-ahead contract: a unit's completion record hits
    disk before the server acts on the completion.  Tests that hammer the
    journal may pass ``fsync=False`` and accept losing the OS-buffered
    tail on a *machine* crash (a killed process still keeps it).
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")

    # ------------------------------------------------------------ write --

    def append(self, record: Any) -> None:
        payload = pickle.dumps(record)
        self._f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JobJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- read --

    @classmethod
    def replay(cls, path: str) -> Iterator[Any]:
        """Yield every intact record in append order; stop at a torn tail.

        A record is *torn* when the file ends mid-frame or the payload
        fails its CRC — both are what a crash mid-append leaves behind.
        Records before the tear are yielded normally; nothing after a
        tear is trusted (frame boundaries are unrecoverable past it).
        Missing file ⇒ empty history.
        """
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    return  # clean EOF or torn header
                length, crc = _HEADER.unpack(header)
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    return  # torn or corrupt tail
                yield pickle.loads(payload)
