"""Lowering pass — (plan spec, prepared placement, capabilities) → TaskGraph.

This is the first half of the execution layer's two-stage split
(DESIGN.md §5): *lowering* turns a validated
:class:`~repro.api.plan.MapReduceSpec` plus the prepared placement (the
policy-derived task groups) into a frozen :class:`TaskGraph` of placed,
keyed :class:`Task` descriptors; *scheduling* (the executor backends) then
decides where and when each descriptor runs.  Everything execution-strategy
dependent — fusion level, task keys, operand construction — is decided
here, once, so a new backend is "implement scheduling over TaskGraph"
rather than another fork of the task-construction logic.

Fusion levels for a reduced ``map_blocks`` under ``SplIter``:

``partition_scan``
    The generic fusion (paper Listing 5): one task per same-shape run of a
    partition's blocks, ``lax.scan`` carrying the partition-local reduction.
``partition_pallas``
    A registered fused kernel (``repro.api.kernels``): one ``pallas_call``
    whose grid iterates the run's blocks while the accumulator stays in
    VMEM.  Chosen by the policy's ``fusion`` knob ("pallas", or "auto" on
    backends that prefer it) with automatic fallback to the scan when no
    kernel is registered, the kernel rejects the shapes, or the plan has
    multiple inputs.

Task *keys* are stable across plan rebuilds: :func:`stable_task_key`
derives a key from code objects, closures and ``functools.partial``
statics, so an app that recreates its lambdas every call (the historical
``("merge", combine)`` bug) still hits the engine's jit cache instead of
re-tracing per call.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import pickle
from typing import Any, Callable, Hashable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.chunkstore import ChunkRef, resolve_chunk
from repro.api.fnref import encode_fn
from repro.api.futures import Deferred, resolve_deferred
from repro.api.kernels import PartitionKernel, kernel_ref, partition_kernel_for
from repro.api.plan import MapReduceSpec
from repro.api.policy import SplIter
from repro.core.blocked import BlockedArray

__all__ = [
    "Capabilities",
    "PartitionView",
    "PlacedGroup",
    "Task",
    "TaskSpec",
    "key_summary",
    "MergeSpec",
    "TaskGraph",
    "cross_iteration_edges",
    "fold_plan",
    "planned_fold",
    "lower",
    "inputs_signature",
    "partition_key",
    "plan_fingerprint",
    "stable_task_key",
    "stacked_fold",
]


# ---------------------------------------------------------------------------
# stable task keys (jit-cache identity that survives plan rebuilds)
# ---------------------------------------------------------------------------


def stable_task_key(fn: Callable) -> Hashable:
    """A hashable identity for ``fn`` stable across re-creations.

    App-level lambdas and ``functools.partial`` wrappers are rebuilt on
    every call (``histogram()`` makes a fresh ``partial`` and a fresh merge
    lambda each time); keying the engine's jit cache on the *object* made
    every call re-trace.  Two callables get the same key iff they share the
    same code object, the same default arguments, the same closure cell
    values, and (for partials) the same statics — i.e. they compute the
    same function.  Anything non-hashable falls back to the object itself
    (identity keying, the previous behaviour).
    """
    if isinstance(fn, functools.partial):
        inner = stable_task_key(fn.func)
        try:
            statics = (tuple(fn.args), tuple(sorted(fn.keywords.items())))
            hash(statics)
        except TypeError:
            return fn
        return ("partial", inner, statics)
    code = getattr(fn, "__code__", None)
    if code is None:
        return fn  # builtins / callables: identity is the best we can do
    # id(__globals__) guards against identical bytecode resolving different
    # global bindings (two modules defining the same-looking fn): the module
    # dict outlives its functions, so the id is stable across re-creations
    # within a module but distinct across modules.
    parts: list[Any] = [code, id(getattr(fn, "__globals__", None))]
    defaults = getattr(fn, "__defaults__", None)
    cells = getattr(fn, "__closure__", None)
    try:
        if defaults:
            hash(defaults)
            parts.append(defaults)
        if cells:
            vals = tuple(c.cell_contents for c in cells)
            hash(vals)
            parts.append(vals)
    except (TypeError, ValueError):  # unhashable default/cell, or empty cell
        return fn
    return ("fn", *parts)


# ---------------------------------------------------------------------------
# plan fingerprints (cross-request identity for shared server assets)
# ---------------------------------------------------------------------------


def inputs_signature(arrays: tuple) -> tuple:
    """The geometry identity of a set of inputs, independent of object ids.

    Two submissions over equal-geometry datasets (same blocking, dtypes and
    placements) share this signature even when the arrays are distinct
    objects — e.g. two tenants loading the same dataset, or a journal-
    rebuilt array after a server restart.  It deliberately excludes buffer
    *contents* (hashing them would force chunk loads), so it is a
    cache/tuner sharing key, not a proof of data equality.
    """
    return tuple(
        (
            tuple(int(r) for r in a.block_rows),
            tuple(a.row_shape),
            str(a.dtype),
            int(a.num_locations),
            tuple(int(p) for p in a.placements),
        )
        for a in arrays
    )


def plan_fingerprint(spec: MapReduceSpec, policy=None) -> str:
    """A stable hex digest identifying a plan across processes and restarts.

    Combines the plan shape (kind, fn/combine references via
    :func:`~repro.api.fnref.encode_fn`, extra-arg bytes), the policy and
    the :func:`inputs_signature`.  The JobServer journals it per
    submission: equal fingerprints mean "the same work", which is what
    lets shared assets (profiles, tuner state) accumulate across tenants
    and a restarted server match journal records to rebuilt plans.
    Unencodable callables degrade to their qualified name, so the
    fingerprint always exists — it is an identity, not a replay payload.
    """

    def fn_part(fn):
        if fn is None:
            return None
        ref = encode_fn(fn)
        if ref is not None:
            return ref
        return getattr(fn, "__qualname__", repr(type(fn)))

    parts = (
        spec.kind,
        repr(policy if policy is not None else spec.policy),
        fn_part(spec.fn),
        fn_part(spec.combine),
        tuple(
            # Deferred operands (pipelined iteration) have no geometry until
            # their source execute resolves; fingerprinting must not force —
            # or worse, block on — that resolution, so they degrade to a
            # marker.  Loop-carried deferreds share geometry across
            # iterations anyway, so the identity stays useful.
            ("deferred",)
            if isinstance(e, Deferred)
            else (tuple(np.asarray(e).shape), str(np.asarray(e).dtype))
            for e in spec.extra_args
        ),
        inputs_signature(spec.inputs),
    )
    return hashlib.sha256(pickle.dumps(parts)).hexdigest()[:32]


# ---------------------------------------------------------------------------
# backend capabilities
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What an executor backend can (and wants to) run.

    Attributes:
      name: backend label (diagnostics only).
      pallas_fusion: backend can execute fused Pallas partition kernels;
        False lowers everything to the generic scan.
      prefer_pallas: under ``fusion="auto"`` pick the Pallas kernel when one
        is registered.  Backends where the kernel runs compiled (TPU) prefer
        it; interpret-mode backends (CPU tests) keep the scan, which is the
        per-backend granularity trade-off of Bora et al. (arXiv:2202.11464).
      grouped_dispatch: backend consumes location groups as single sharded
        dispatches (MeshExecutor) rather than per-task calls.
      out_of_core: backend streams chunk-backed blocks under a residency
        budget (StreamExecutor).  Lowering then attaches each task's
        :class:`~repro.api.chunkstore.ChunkRef` operands to the descriptor
        (``Task.chunk_refs``) so the scheduler can pin/prefetch/release
        them around dispatch without materializing operands; non-streaming
        backends skip the bookkeeping (refs still resolve lazily inside
        ``operands()``).
      remote: backend dispatches tasks to other processes (ClusterExecutor).
        Lowering then attaches a picklable function reference
        (``Task.fn_ref``, built via :mod:`repro.api.fnref` and the named
        kernel registry) plus a raw-operand builder, so :meth:`Task.spec`
        can project the descriptor into a :class:`TaskSpec` that crosses a
        process boundary.  Tasks whose code cannot be referenced (driver
        views, unpicklable closures) keep ``fn_ref=None`` and the backend
        runs them in-process.
      pipelined: backend overlaps consecutive ``execute_async`` submissions
        (DESIGN.md §14): iteration *k+1*'s units are gated on their
        same-partition *k* predecessors via :func:`cross_iteration_edges`
        instead of a global drain.  Non-pipelined backends run
        ``execute_async`` as a synchronous execute returning an
        already-completed future — same results, no overlap.
      exporter: dispatch-time block exporter of the shared-memory data
        plane (``callable(block) -> ShmBlockRef | None``), or None.  When
        set, operand builders hand large blocks off as shm descriptors
        instead of raw ndarray payloads; a ``None`` return falls back to
        inline bytes.  Excluded from equality/hash so caches keyed on
        capabilities don't fragment on executor identity.
    """

    name: str = "local"
    pallas_fusion: bool = True
    prefer_pallas: bool = False
    grouped_dispatch: bool = False
    out_of_core: bool = False
    remote: bool = False
    pipelined: bool = False
    exporter: Any = dataclasses.field(default=None, compare=False, repr=False)


# ---------------------------------------------------------------------------
# prepared placement + partition views
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacedGroup:
    """One policy-derived task group: which blocks one task consumes, where."""

    location: int
    block_ids: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class PartitionView:
    """A single-location group of aligned blocks, as seen by map_partitions.

    Generalizes :class:`~repro.core.spliter.Partition` to multi-input plans
    (e.g. Cascade SVM's aligned points+labels) and to the Baseline policy,
    where every block is its own single-block partition.
    """

    arrays: tuple[BlockedArray, ...]
    location: int
    block_ids: tuple[int, ...]

    @property
    def blocks(self) -> list[jax.Array]:
        """Blocks of the first (or only) input array."""
        return self.blocks_of(0)

    def blocks_of(self, i: int) -> list[jax.Array]:
        return [self.arrays[i].block(b) for b in self.block_ids]

    @property
    def num_rows(self) -> int:
        return int(sum(self.arrays[0].block_rows[b] for b in self.block_ids))

    @property
    def item_indexes(self) -> np.ndarray:
        """Global row ids of every element (paper §4.1 ``get_item_indexes``)."""
        x = self.arrays[0]
        offs = x.row_offsets()
        rows = x.block_rows
        return np.concatenate(
            [np.arange(offs[b], offs[b] + rows[b], dtype=np.int64) for b in self.block_ids]
        )

    @property
    def materialized(self) -> tuple[jax.Array, ...]:
        """Local concat of each input's blocks — intra-location copy only."""
        return tuple(
            jnp.concatenate(self.blocks_of(i), axis=0) for i in range(len(self.arrays))
        )


# ---------------------------------------------------------------------------
# the TaskGraph IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Task:
    """One placed, keyed task descriptor.

    ``operands()`` builds the operand tuple lazily (stacking/concatenating
    block buffers only when the task actually runs); the first ``n_data``
    operands are per-task data, the rest are plan-wide traced extras shared
    by every task of the same ``key`` — the distinction grouped backends
    (MeshExecutor) use to stack data across tasks while replicating extras.

    ``counted=False`` marks tasks that are *driver* work rather than engine
    dispatches (map_partitions views: the view callback itself dispatches
    engine tasks).
    """

    index: int
    location: int
    kind: str                # "block" | "partition_scan" | "partition_pallas"
                             # | "partition_materialized" | "partition_view"
    key: Hashable
    fn: Callable
    operands: Callable[[], tuple]
    block_ids: tuple[int, ...]
    n_data: int = 1
    counted: bool = True
    kernel_name: str | None = None
    #: ((shape, dtype_str), ...) of the per-task data operands — lets grouped
    #: backends bucket same-signature tasks WITHOUT materializing operands.
    data_shapes: tuple = ()
    #: store-held chunk refs this task's operands resolve — populated only
    #: for out-of-core backends (``Capabilities.out_of_core``), which
    #: pin/prefetch/release them around dispatch.
    chunk_refs: tuple = ()
    #: picklable reference to this task's code (``Capabilities.remote``
    #: lowerings only): ``("fn", ref)``, ``("scan", fn_ref, combine_ref,
    #: n_in)`` or ``("kernel", kernel_ref)``.  None ⇒ not remotable.
    fn_ref: tuple | None = None
    #: nullary builder of the raw remote payload ``(data, extras)`` —
    #: per-input block payloads (ndarray or ChunkHandle) still UNstacked,
    #: so the worker performs the stack/concat and the float story matches
    #: the in-process lowering bit for bit.
    remote_operands: Callable[[], tuple] | None = None

    def spec(self) -> "TaskSpec":
        """Project this descriptor into its picklable :class:`TaskSpec`.

        Only valid on tasks lowered under ``Capabilities.remote`` with a
        resolvable ``fn_ref`` — the cluster backend checks ``fn_ref`` and
        schedules every other task in-process.
        """
        if self.fn_ref is None or self.remote_operands is None:
            raise ValueError(
                f"task {self.index} ({self.kind}) has no remote projection"
            )
        data, extras = self.remote_operands()
        return TaskSpec(
            index=self.index,
            location=self.location,
            kind=self.kind,
            key_repr=key_summary(self.key),
            fn_ref=self.fn_ref,
            block_ids=self.block_ids,
            n_data=self.n_data,
            data=data,
            extras=extras,
        )


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """The picklable projection of one :class:`Task` (DuctTeip-style cheap
    task descriptor): everything a worker process needs to replay the task
    — code reference, geometry, and per-block operand payloads that are
    either raw ``ndarray`` bytes or store-attached
    :class:`~repro.api.chunkstore.ChunkHandle`\\ s.

    Deterministic replay contract: running the same TaskSpec twice (on any
    worker) produces bit-identical partials, because the payloads are
    immutable snapshots and the worker rebuilds the exact stack/concat +
    fn the in-process lowering would have dispatched.
    """

    index: int
    location: int
    kind: str
    key_repr: str          # human-readable key digest (errors, worker logs)
    fn_ref: tuple
    block_ids: tuple
    n_data: int
    data: tuple            # per input: tuple of block payloads
    extras: tuple          # plan-wide traced extras, np-converted


def key_summary(key: Hashable) -> str:
    """Short, address-free rendering of a task key (errors / worker logs)."""
    if isinstance(key, tuple):
        return "(" + ", ".join(key_summary(k) for k in key) + ")"
    name = getattr(key, "co_name", None)
    if name is not None:
        return f"<code {name}>"
    r = repr(key)
    return r if len(r) <= 48 else r[:45] + "..."


@dataclasses.dataclass(frozen=True)
class MergeSpec:
    """The final fold over task partials (the paper's @reduction task)."""

    combine: Callable[[Any, Any], Any]
    key: Hashable


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """Frozen result of lowering: placed tasks + the merge contract.

    Executors consume this and nothing else: scheduling a TaskGraph must
    produce the per-task partials in ``tasks`` order (or a single
    already-merged value when the backend fuses the merge into its
    dispatch), then apply ``merge`` in plan order.
    """

    tasks: tuple[Task, ...]
    merge: MergeSpec | None
    spec: MapReduceSpec

    @property
    def locations(self) -> tuple[int, ...]:
        return tuple(sorted({t.location for t in self.tasks}))

    def by_location(self) -> dict[int, list[Task]]:
        out: dict[int, list[Task]] = {}
        for t in self.tasks:
            out.setdefault(t.location, []).append(t)
        return out

    def describe(self) -> str:
        """One line per task: index, placement, kind, key summary.

        Deliberately free of memory addresses and other run-varying detail
        so the output is golden-testable — a lowering regression shows up
        as a readable string diff (tests/test_api.py).
        """
        lines = []
        for t in self.tasks:
            extra = f" kernel={t.kernel_name}" if t.kernel_name else ""
            lines.append(
                f"[{t.index}] loc={t.location} {t.kind} blocks={t.block_ids}{extra}"
            )
        if self.merge is not None:
            c = self.merge.combine
            name = getattr(c, "__name__", type(c).__name__)
            lines.append(f"[merge] combine={name}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# cross-iteration dependency edges (pipelined iteration, DESIGN.md §14)
# ---------------------------------------------------------------------------


def partition_key(task: Task) -> tuple:
    """The stable identity of the data partition one task covers.

    ``(location, block_ids)`` — the versioned-key half of the pipelining
    contract: the same partition of the same dataset lowers to the same key
    every iteration (placement and grouping are policy-derived and the
    prepare cache reuses them), so "iteration *k*'s unit for this
    partition" is addressable without any global coordination.  Pipelined
    schedulers pair it with a per-partition version counter: version *v* of
    a key is that partition's unit in the *v*-th overlapped execute.
    """
    return (task.location, task.block_ids)


def cross_iteration_edges(prev: TaskGraph, nxt: TaskGraph) -> dict[int, tuple[int, ...]]:
    """Same-partition dependency edges from ``nxt``'s tasks to ``prev``'s.

    The inter-iteration half of the TaskGraph: for consecutive pipelined
    executes, each task of ``nxt`` depends on the ``prev`` tasks covering
    the same :func:`partition_key` — a partition's *k+1* unit may launch
    the moment its *k* unit completes, no global drain.  Keys are task
    indices in ``nxt``; values are matching task indices in ``prev``.

    Tasks with no same-partition predecessor (a granularity retune between
    submits re-partitioned the data) are absent from the mapping; the
    scheduler falls back to gating them on ``prev``'s merge, which is
    always correct — just barrier-shaped for that one boundary.
    """
    by_part: dict[tuple, list[int]] = {}
    for t in prev.tasks:
        by_part.setdefault(partition_key(t), []).append(t.index)
    out: dict[int, tuple[int, ...]] = {}
    for t in nxt.tasks:
        deps = by_part.get(partition_key(t))
        if deps:
            out[t.index] = tuple(deps)
    return out


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def stacked_fold(combine: Callable[[Any, Any], Any]) -> Callable[[Any], Any]:
    """Fold a stacked pytree of partials (leading axis) in index order.

    ``stacked_fold(c)(stacked)`` = ``c(c(s[0], s[1]), s[2]) ...`` as one
    ``lax.scan`` — the single source of truth for "reduce N partials with an
    associative combine": the host-side merge task (``_merge_partials`` in
    :mod:`repro.api.executors`) folds stacked task partials with it, and
    :class:`~repro.api.mesh_executor.MeshExecutor` folds the all-gathered
    per-rank partials with it inside the sharded program (the all-reduce of
    an arbitrary associative monoid).
    """

    def fold(stacked):
        first = jax.tree.map(lambda s: s[0], stacked)
        rest = jax.tree.map(lambda s: s[1:], stacked)
        acc, _ = jax.lax.scan(lambda a, p: (combine(a, p), None), first, rest)
        return acc

    return fold


def fold_plan(entries) -> tuple[tuple[int, tuple[int, ...]], ...]:
    """The canonical merge tree over ``(index, location)`` pairs.

    Returns ``((location, member_indices), ...)`` — one fold group per
    location, members in entry order, groups in first-appearance order of
    their location.  This is the merge association contract every backend
    folds by: each group's members reduce left-to-right (one
    :func:`stacked_fold` chain), then the per-group values reduce
    left-to-right in group order.  The shape is a pure function of the
    entry sequence — itself derived from stable task keys and the
    policy's placement — so a replayed, resumed, or peer-exchanged fold
    (DESIGN.md §16) re-derives the exact same tree and stays
    bit-identical.

    >>> fold_plan([(0, 1), (1, 1), (2, 0), (3, 0)])
    ((1, (0, 1)), (0, (2, 3)))
    >>> fold_plan([(0, -1)])
    ((-1, (0,)),)
    """
    groups: dict[int, list[int]] = {}
    order: list[int] = []
    for idx, loc in entries:
        if loc not in groups:
            groups[loc] = []
            order.append(loc)
        groups[loc].append(idx)
    return tuple((loc, tuple(groups[loc])) for loc in order)


def planned_fold(
    combine: Callable[[Any, Any], Any],
    groups: tuple[tuple[int, ...], ...],
) -> Callable[[Any], Any]:
    """Fold a stacked pytree of partials along a :func:`fold_plan` tree.

    ``planned_fold(c, groups)(stacked)`` reduces each group's members with
    the :func:`stacked_fold` chain, then chains the group values in group
    order — the same arithmetic, in the same order, as running each group
    chain worker-side and the root chain driver-side (the peer-exchange
    path), so the two routes produce bit-identical values.  Degenerates to
    ``stacked_fold(c)`` for a single group.  One jitted program, one
    dispatch — the merge keeps costing exactly one task however many
    groups the plan has.
    """
    chain = stacked_fold(combine)

    def fold(stacked):
        accs = []
        for members in groups:
            if len(members) == 1:
                accs.append(jax.tree.map(lambda s, i=members[0]: s[i], stacked))
            else:
                idx = jnp.asarray(members)
                accs.append(chain(jax.tree.map(lambda s, x=idx: s[x], stacked)))
        if len(accs) == 1:
            return accs[0]
        return chain(jax.tree.map(lambda *xs: jnp.stack(xs, 0), *accs))

    return fold


def _partition_body(block_fn: Callable, combine: Callable, n_in: int) -> Callable:
    """The fused per-partition task (paper Listing 5 as a ``lax.scan``)."""

    def partition_task(*operands):
        data, extra = operands[:n_in], operands[n_in:]

        def body(acc, blk):
            p = block_fn(*blk, *extra)
            return combine(acc, p), None

        first = block_fn(*(s[0] for s in data), *extra)
        acc, _ = jax.lax.scan(body, first, jax.tree.map(lambda s: s[1:], data))
        return acc

    return partition_task


def _pick_fusion(
    policy,
    caps: Capabilities,
    kernel: PartitionKernel | None,
    stacked_shape: tuple,
    extra_args: tuple,
) -> str:
    """Resolve the SplIter ``fusion`` knob for one same-shape run."""
    mode = getattr(policy, "fusion", "auto")
    if mode == "scan" or not caps.pallas_fusion:
        return "scan"
    if kernel is None or not kernel.supported(stacked_shape, extra_args):
        return "scan"  # automatic fallback: no kernel, or shapes rejected
    if mode == "pallas":
        return "pallas"
    return "pallas" if caps.prefer_pallas else "scan"


def lower(
    spec: MapReduceSpec,
    arrays: tuple[BlockedArray, ...],
    groups: list[PlacedGroup],
    caps: Capabilities,
) -> TaskGraph:
    """Lower a normalized plan over prepared placement into a TaskGraph.

    ``arrays``/``groups`` are the policy's prepared form (already rechunked
    for ``Rechunk``; the original arrays plus partition groups otherwise) —
    executors compute them once per ``(inputs, policy)`` and cache.
    """
    merge = (
        MergeSpec(spec.combine, key=("merge", stable_task_key(spec.combine)))
        if spec.combine is not None
        else None
    )

    if spec.kind == "map_partitions":
        tasks = _lower_partition_views(spec, arrays, groups, caps)
    else:
        tasks = _lower_map_blocks(spec, arrays, groups, caps)
    return TaskGraph(tasks=tuple(tasks), merge=merge, spec=spec)


def _refs_of(arrays, ids, caps: Capabilities) -> tuple:
    """The chunk refs a task over ``ids`` resolves — out-of-core backends only."""
    if not caps.out_of_core:
        return ()
    return tuple(
        a.blocks[i] for a in arrays for i in ids if isinstance(a.blocks[i], ChunkRef)
    )


def _block_payload(block, exporter=None):
    """One block as it crosses a process boundary.

    Cheapest transport first: store-held chunks covered by a manifest
    travel as tiny :class:`~repro.api.chunkstore.ChunkHandle` descriptors
    (the worker resolves them against its attached store); other blocks go
    through the backend's shared-memory ``exporter`` when one is set
    (:class:`Capabilities.exporter` — descriptors instead of bytes); only
    when both decline do raw ndarray bytes ship over the control channel.
    """
    if isinstance(block, ChunkRef):
        handle = getattr(block.store, "handle", None)
        if handle is not None:
            h = handle(block)
            if h is not None:
                return h
    if exporter is not None:
        ref = exporter(block)
        if ref is not None:
            return ref
    return np.asarray(resolve_chunk(block))


def _remote_operands_builder(arrays, ids, extra, exporter=None) -> Callable[[], tuple]:
    """Builder of a task's raw remote payload — evaluated at dispatch time."""

    def build():
        data = tuple(
            tuple(_block_payload(a.blocks[b], exporter) for b in ids) for a in arrays
        )
        extras = []
        for e in extra:
            e = resolve_deferred(e)  # pipelined loop-carried operand
            ref = exporter(e) if exporter is not None else None
            extras.append(ref if ref is not None else np.asarray(e))
        return data, tuple(extras)

    return build


def _lower_partition_views(spec, arrays, groups, caps: Capabilities) -> list[Task]:
    tasks = []
    for g in groups:
        view = PartitionView(arrays=arrays, location=g.location, block_ids=g.block_ids)
        tasks.append(
            Task(
                index=len(tasks),
                location=g.location,
                kind="partition_view",
                key=None,
                fn=spec.fn,
                operands=(lambda view=view: (view,)),
                block_ids=g.block_ids,
                n_data=1,
                counted=False,
                chunk_refs=_refs_of(arrays, g.block_ids, caps),
            )
        )
    return tasks


def _lower_map_blocks(spec, arrays, groups, caps: Capabilities) -> list[Task]:
    extra = spec.extra_args
    n_in = len(arrays)
    pol = spec.policy
    fn_key = stable_task_key(spec.fn)
    tasks: list[Task] = []

    # Remote code references (Capabilities.remote): computed once per plan,
    # shared by every task.  A None reference — unencodable fn/combine —
    # simply leaves the tasks in-process-only; lowering never fails on it.
    plain_ref = scan_ref = None
    if caps.remote:
        efn = encode_fn(spec.fn)
        plain_ref = ("fn", efn) if efn is not None else None
        if spec.combine is not None:
            ecomb = encode_fn(spec.combine)
            if efn is not None and ecomb is not None:
                scan_ref = ("scan", efn, ecomb, n_in)

    def remote_fields(fn_ref, ids):
        if not caps.remote or fn_ref is None:
            return {}
        return {
            "fn_ref": fn_ref,
            "remote_operands": _remote_operands_builder(
                arrays, ids, extra, caps.exporter
            ),
        }

    fused = isinstance(pol, SplIter) and not pol.materialize and spec.combine is not None
    if fused:
        # Fused iteration: ONE dispatch scanning (or pallas-gridding) the
        # partition's local blocks, carrying the partition-local reduction.
        # Ragged tails lower per same-shape run — at most one extra task per
        # tail, so C1's dispatch bound survives the fusion choice.
        kernel = partition_kernel_for(spec.fn) if n_in == 1 else None
        scan_fn = _partition_body(spec.fn, spec.combine, n_in)
        scan_key = ("part", fn_key, stable_task_key(spec.combine), n_in)
        pallas_ref = None
        if caps.remote and kernel is not None:
            kref = kernel_ref(spec.fn)
            pallas_ref = ("kernel", kref) if kref is not None else None
        for g in groups:
            by_shape: dict[tuple, list[int]] = {}
            for b in g.block_ids:
                by_shape.setdefault(arrays[0].blocks[b].shape, []).append(b)
            for shape, ids in by_shape.items():
                ids = tuple(ids)
                stacked_shape = (len(ids), *shape)
                choice = _pick_fusion(pol, caps, kernel, stacked_shape, extra)

                def operands(ids=ids):
                    return tuple(
                        jnp.stack([a.block(b) for b in ids], axis=0) for a in arrays
                    ) + tuple(resolve_deferred(e) for e in extra)

                if choice == "pallas":
                    task_fn, key, kname = kernel.fn, ("pallas", kernel.key), kernel.name
                else:
                    task_fn, key, kname = scan_fn, scan_key, None
                tasks.append(
                    Task(
                        index=len(tasks),
                        location=g.location,
                        kind=f"partition_{choice}",
                        key=key,
                        fn=task_fn,
                        operands=operands,
                        block_ids=ids,
                        n_data=n_in,
                        kernel_name=kname,
                        chunk_refs=_refs_of(arrays, ids, caps),
                        data_shapes=tuple(
                            (
                                (len(ids), *a.blocks[ids[0]].shape),
                                str(a.blocks[ids[0]].dtype),
                            )
                            for a in arrays
                        ),
                        **remote_fields(
                            pallas_ref if choice == "pallas" else scan_ref, ids
                        ),
                    )
                )
    elif isinstance(pol, SplIter) and pol.materialize:
        # Materialized partition (paper §7): local concat, one call.
        for g in groups:
            def operands(g=g):
                return tuple(
                    jnp.concatenate([a.block(b) for b in g.block_ids], axis=0)
                    for a in arrays
                ) + tuple(resolve_deferred(e) for e in extra)

            tasks.append(
                Task(
                    index=len(tasks),
                    location=g.location,
                    kind="partition_materialized",
                    key=("block", fn_key),
                    fn=spec.fn,
                    operands=operands,
                    block_ids=g.block_ids,
                    n_data=n_in,
                    chunk_refs=_refs_of(arrays, g.block_ids, caps),
                    data_shapes=tuple(
                        (
                            (
                                sum(a.blocks[b].shape[0] for b in g.block_ids),
                                *a.blocks[g.block_ids[0]].shape[1:],
                            ),
                            str(a.blocks[g.block_ids[0]].dtype),
                        )
                        for a in arrays
                    ),
                    **remote_fields(plain_ref, g.block_ids),
                )
            )
    else:
        # Baseline / Rechunk (single-block groups), or an un-reduced SplIter
        # map: one task per block, in GLOBAL block order so an un-reduced
        # compute() returns partials aligned with the blocking regardless of
        # policy/partition layout.
        placed = sorted((b, g.location) for g in groups for b in g.block_ids)
        for b, loc in placed:
            def operands(b=b):
                return tuple(a.block(b) for a in arrays) + tuple(
                    resolve_deferred(e) for e in extra
                )

            tasks.append(
                Task(
                    index=len(tasks),
                    location=loc,
                    kind="block",
                    key=("block", fn_key),
                    fn=spec.fn,
                    operands=operands,
                    block_ids=(b,),
                    n_data=n_in,
                    chunk_refs=_refs_of(arrays, (b,), caps),
                    data_shapes=tuple(
                        (a.blocks[b].shape, str(a.blocks[b].dtype)) for a in arrays
                    ),
                    **remote_fields(plain_ref, (b,)),
                )
            )
    return tasks
