"""JobServer — multi-tenant engine-as-a-service over the execution layer.

Everything below the plan boundary so far serves ONE driver running one
plan at a time; this module (DESIGN.md §12) turns the engine into a
long-lived *service*: many concurrent clients submit
:class:`~repro.api.plan.ExecutionPlan`\\ s, the server multiplexes them
onto a shared executor pool at **unit granularity**, and job state is
durable — a killed server restarts and resumes in-flight jobs from its
write-ahead journal instead of recomputing them.  The exemplar shapes are
Flux's journaled ``ExecutionContext`` (replay-from-journal) and
Chunks-and-Tasks' separation of work *submission* from work *placement*.

Architecture (one sentence per layer):

* **admission** — a bounded count of open jobs; past it, ``submit`` raises
  the typed :class:`JobRejected` instead of queueing unboundedly;
* **scheduling** — one scheduler thread interleaves READY UNITS from every
  open job, picking the next tenant by stride (virtual-time) weighted
  fairness: tenant ``t``'s pass advances by ``1/weight`` per unit, lowest
  pass runs next — a 2× weight tenant gets 2× the unit slots, and no
  tenant starves (its pass eventually undercuts every busier one);
* **execution** — units run through the pooled executor's shared core
  (:meth:`~repro.api.executors._PlanExecutor._run_unit`) with the engine's
  report swapped to the job's own segment around every unit, so per-job
  accounting survives multiplexing on one :class:`~repro.core.engine.TaskEngine`;
* **shared assets** — ONE :class:`~repro.api.executors.SharedAssets`
  (prepare cache, profiles, autotuners) serves every tenant: geometry-based
  keys (:func:`~repro.api.lowering.inputs_signature`) mean tenant B's
  ``SplIter("auto")`` starts from the granularity tenant A's probes found;
* **durability** — every accepted job appends its fingerprint + replay
  payload to a :class:`~repro.api.journal.JobJournal`; every completed
  unit appends its key + host-side partial result; scheduler state
  (tenant passes, per-job cumulative reports) snapshots periodically via
  :class:`~repro.checkpoint.checkpointer.Checkpointer` (atomic
  COMMITTED-marker layout).  Restart = full journal replay + newest
  committed snapshot: unfinished durable jobs re-lower under their
  journaled resolved policy, journaled units restore as completed
  (``Job.restored_units``), and only the remainder recomputes
  (``Job.recomputed_units``) — bit-identically, because unit partials are
  exact host copies and the merge folds them in plan order either way.

Lifecycle events stream per job:
``queued → preparing → running(k/n units) → merged → done | failed``
(plus ``resumed`` after a restart), each a :class:`JobEvent` in
``Job.events`` and the server-wide ``event_log``.
"""

from __future__ import annotations

import collections
import itertools
import os
import pickle
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.api.executors import (
    ComputeResult,
    LocalExecutor,
    SharedAssets,
    _PlanExecutor,
    _default_local,
)
from repro.api.fnref import decode_fn, encode_fn
from repro.api.journal import JobJournal
from repro.api.lowering import key_summary, lower, plan_fingerprint
from repro.api.plan import ExecutionPlan, MapReduceSpec
from repro.api.policy import SplIter
from repro.checkpoint.checkpointer import Checkpointer
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport

__all__ = ["JobServer", "Job", "JobEvent", "JobRejected", "JobFailedError"]


class JobRejected(RuntimeError):
    """Typed admission-control rejection (``reason``: why, machine-readable).

    Raised synchronously by :meth:`JobServer.submit` — a rejected plan was
    never journaled and owns no server state; the client may back off and
    resubmit.  ``reason`` is ``"queue_full"`` or ``"closed"``.
    """

    def __init__(self, message: str, *, reason: str):
        super().__init__(message)
        self.reason = reason


class JobFailedError(RuntimeError):
    """A waited-on job finished ``failed``; carries the job id + summary."""

    def __init__(self, job_id: str, error: str):
        super().__init__(f"{job_id} failed: {error}")
        self.job_id = job_id
        self.error = error


class JobEvent:
    """One lifecycle event: ``(job_id, kind, detail, completed/total)``."""

    __slots__ = ("job_id", "kind", "detail", "completed", "total", "time")

    def __init__(self, job_id, kind, detail="", completed=0, total=0):
        self.job_id = job_id
        self.kind = kind
        self.detail = detail
        self.completed = completed
        self.total = total
        self.time = time.time()

    def __repr__(self):
        frac = f" {self.completed}/{self.total}" if self.total else ""
        detail = f" ({self.detail})" if self.detail else ""
        return f"<JobEvent {self.job_id} {self.kind}{frac}{detail}>"


class Job:
    """Server-side state of one submission (also the client's handle).

    Scheduling internals (unit deques, scheduler state) are owned by the
    server's scheduler thread; clients read the public fields — ``status``,
    ``events``, ``result`` / ``report`` / ``error`` after ``done`` — and
    the resume counters ``restored_units`` (journal-restored completions)
    vs ``recomputed_units`` (units this incarnation actually ran).
    """

    def __init__(self, job_id, tenant, weight, spec, fingerprint, payload):
        self.id = job_id
        self.tenant = tenant
        self.weight = weight
        self.spec: MapReduceSpec | None = spec
        self.fingerprint = fingerprint
        self.payload = payload            # durable replay bytes, or None
        self.status = "queued"
        self.result: Any = None
        self.report: EngineReport | None = None
        self.error: str | None = None
        self.events: list[JobEvent] = []
        self.done = threading.Event()
        self.total_units = 0
        self.restored_units = 0
        self.recomputed_units = 0
        # resume bookkeeping (populated by journal replay)
        self.completed_keys: dict[str, bytes] = {}
        self.resolved_policy = None
        self.prior_report: EngineReport | None = None
        # scheduler-thread internals
        self._segment: EngineReport | None = None
        self._units = None
        self._state = None
        self._merge = None
        self._graph = None
        self._tuner = None
        self._ready: collections.deque = collections.deque()
        self._t0 = 0.0

    @property
    def durable(self) -> bool:
        return self.payload is not None

    @property
    def open(self) -> bool:
        return self.status in ("queued", "preparing", "running")


class JobServer:
    """Long-lived, multi-tenant, durable front-end over one executor pool.

    Args:
      root: durability directory (journal + snapshots).  ``None`` runs the
        server in-memory: full multiplexing/fairness, no resume.
      executor: the pooled backend (any ``_PlanExecutor`` — Local,
        Threaded, Cluster...).  Defaults to a server-owned
        :class:`LocalExecutor`.  The server adopts ONE
        :class:`SharedAssets` into it, making its caches cross-tenant.
      max_pending: admission bound — maximum simultaneously OPEN jobs
        (queued/preparing/running); the next ``submit`` past it raises
        :class:`JobRejected`.
      snapshot_every: scheduler-state snapshot period, in completed units.
      fsync: journal write-ahead durability (tests may relax it).
      autostart: spawn the scheduler thread immediately (tests that drive
        recovery state inspection may delay with ``autostart=False`` and
        call :meth:`start`).
    """

    def __init__(
        self,
        *,
        root: str | None = None,
        executor: _PlanExecutor | None = None,
        max_pending: int = 16,
        snapshot_every: int = 8,
        fsync: bool = True,
        autostart: bool = True,
    ):
        self.root = root
        self._owns_executor = executor is None
        self.executor = executor if executor is not None else _default_local()
        self.assets = SharedAssets()
        self.executor.adopt_shared_assets(self.assets)
        self.max_pending = max_pending
        self.snapshot_every = snapshot_every
        self.journal: JobJournal | None = None
        self.checkpointer: Checkpointer | None = None
        self._jobs: dict[str, Job] = {}
        self._order: list[str] = []
        self._tenant_pass: dict[str, float] = {}
        self._seq = itertools.count()
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self._closed = False
        self.event_log: list[JobEvent] = []
        self._completions_total = 0
        self._units_since_snapshot = 0
        self.resumed_jobs = 0
        if root is not None:
            os.makedirs(root, exist_ok=True)
            self.checkpointer = Checkpointer(os.path.join(root, "snapshots"))
            self._recover(os.path.join(root, "journal.bin"))
            self.journal = JobJournal(os.path.join(root, "journal.bin"), fsync=fsync)
        self._thread = threading.Thread(
            target=self._loop, name="repro-jobserver", daemon=True
        )
        if autostart:
            self._thread.start()

    def start(self) -> None:
        if not self._thread.is_alive():
            self._thread.start()

    # ------------------------------------------------------------ submit --

    def submit(self, plan: ExecutionPlan, *, tenant="default", weight=1) -> Job:
        """Admit one plan; returns its :class:`Job` handle (non-blocking).

        Admission is checked and the submission journaled BEFORE the
        scheduler sees the job — write-ahead: a crash right after
        ``submit`` returns still resumes the job (when its plan is
        durable, i.e. fn/combine referencable and inputs resident).
        """
        spec = plan.spec
        with self._cond:
            if self._closed or self._stop.is_set():
                raise JobRejected("server is closed", reason="closed")
            pending = sum(1 for j in self._jobs.values() if j.open)
            if pending >= self.max_pending:
                raise JobRejected(
                    f"admission queue full ({pending}/{self.max_pending} "
                    f"open jobs)",
                    reason="queue_full",
                )
            job_id = f"job-{next(self._seq):04d}"
            fingerprint = plan_fingerprint(spec)
            payload = self._encode_payload(spec)
            if self.journal is not None:
                self.journal.append(
                    ("job", job_id, tenant, weight, fingerprint, payload)
                )
            job = Job(job_id, tenant, weight, spec, fingerprint, payload)
            self._jobs[job_id] = job
            self._order.append(job_id)
            self._emit(job, "queued", detail=f"tenant={tenant} weight={weight}")
            self._cond.notify_all()
        return job

    def wait(self, job: Job, timeout: float | None = None) -> ComputeResult:
        """Block until ``job`` finishes; raise :class:`JobFailedError` on
        failure.  The report is a fresh copy (channel semantics)."""
        if not job.done.wait(timeout):
            raise TimeoutError(f"{job.id} still {job.status} after {timeout}s")
        if job.status == "failed":
            raise JobFailedError(job.id, job.error or "unknown error")
        return ComputeResult(
            value=job.result, report=EngineReport.from_json(job.report.to_json())
        )

    def jobs(self) -> list[Job]:
        with self._cond:
            return [self._jobs[j] for j in self._order]

    # -------------------------------------------------- durable payloads --

    @staticmethod
    def _encode_payload(spec: MapReduceSpec) -> bytes | None:
        """The replay payload: everything needed to rebuild ``spec`` in a
        fresh process — or None when the plan is not durably encodable
        (unreferencable callables, chunk-backed inputs).  Non-durable jobs
        still RUN normally; they just cannot survive a restart."""
        fn_ref = encode_fn(spec.fn)
        if fn_ref is None:
            return None
        combine_ref = None
        if spec.combine is not None:
            combine_ref = encode_fn(spec.combine)
            if combine_ref is None:
                return None
        inputs = []
        for a in spec.inputs:
            if a.is_chunked:
                return None
            inputs.append(
                (
                    tuple(np.asarray(b) for b in a.blocks),
                    np.asarray(a.placements),
                    int(a.num_locations),
                )
            )
        try:
            return pickle.dumps(
                {
                    "kind": spec.kind,
                    "policy": spec.policy,
                    "fn": fn_ref,
                    "combine": combine_ref,
                    "extra_args": tuple(np.asarray(e) for e in spec.extra_args),
                    "inputs": tuple(inputs),
                }
            )
        except Exception:
            return None

    @staticmethod
    def _decode_payload(payload: bytes) -> MapReduceSpec:
        d = pickle.loads(payload)
        inputs = tuple(
            BlockedArray.from_blocks(
                [jax.numpy.asarray(b) for b in blocks], placements, nloc
            )
            for blocks, placements, nloc in d["inputs"]
        )
        return MapReduceSpec(
            inputs=inputs,
            policy=d["policy"],
            kind=d["kind"],
            fn=decode_fn(d["fn"]),
            extra_args=d["extra_args"],
            combine=decode_fn(d["combine"]) if d["combine"] is not None else None,
        )

    # ----------------------------------------------------------- recover --

    def _recover(self, journal_path: str) -> None:
        """Rebuild job state from the journal + newest committed snapshot."""
        max_seq = -1
        for rec in JobJournal.replay(journal_path):
            kind = rec[0]
            if kind == "job":
                _, job_id, tenant, weight, fingerprint, payload = rec
                job = Job(job_id, tenant, weight, None, fingerprint, payload)
                self._jobs[job_id] = job
                self._order.append(job_id)
                max_seq = max(max_seq, int(job_id.split("-")[1]))
            elif kind == "start":
                _, job_id, pol_bytes = rec
                if job_id in self._jobs:
                    self._jobs[job_id].resolved_policy = pickle.loads(pol_bytes)
            elif kind == "unit":
                _, job_id, ukey, value_bytes = rec
                if job_id in self._jobs:
                    self._jobs[job_id].completed_keys[ukey] = value_bytes
            elif kind in ("done", "failed"):
                _, job_id, detail = rec
                job = self._jobs.get(job_id)
                if job is None:
                    continue
                job.status = kind
                if kind == "done":
                    job.report = EngineReport.from_json(detail)
                    # The value itself is the merge unit's journaled
                    # partial; surface it for post-restart wait() calls.
                    for key, blob in job.completed_keys.items():
                        if key.startswith("merge:"):
                            job.result = pickle.loads(blob)
                else:
                    job.error = detail
                job.done.set()
        self._seq = itertools.count(max_seq + 1)

        extras: dict = {}
        if self.checkpointer is not None:
            try:
                manifest, _step = self.checkpointer.load_manifest()
                extras = manifest.get("extras", {})
            except FileNotFoundError:
                pass
        self._tenant_pass.update(extras.get("tenant_pass", {}))
        reports = extras.get("job_reports", {})

        for job in self._jobs.values():
            if not job.open:
                continue
            if job.payload is None:
                job.status = "failed"
                job.error = "job was not durable (unreferencable plan); lost at restart"
                job.done.set()
                self._emit(job, "failed", detail=job.error)
                continue
            job.spec = self._decode_payload(job.payload)
            if job.id in reports:
                job.prior_report = EngineReport.from_json(reports[job.id])
            job.status = "queued"
            self.resumed_jobs += 1
            self._emit(
                job,
                "resumed",
                detail=f"{len(job.completed_keys)} journaled units",
            )

    # --------------------------------------------------------- scheduler --

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                job = self._next_runnable()
                if job is None:
                    if self._closed and not any(
                        j.open for j in self._jobs.values()
                    ):
                        return
                    self._cond.wait(0.05)
                    continue
                tenant = job.tenant
                base = min(self._tenant_pass.values(), default=0.0)
                self._tenant_pass.setdefault(tenant, base)
                self._tenant_pass[tenant] += 1.0 / max(job.weight, 1)
            try:
                if self._stop.is_set():
                    return
                if job.status == "queued":
                    self._prepare(job)
                else:
                    self._step_unit(job)
            except BaseException as e:  # noqa: BLE001 — job-scoped failure
                self._fail(job, e)

    def _next_runnable(self) -> Job | None:
        """Earliest runnable job of the lowest-pass tenant (stride pick)."""
        candidates: dict[str, Job] = {}
        for jid in self._order:
            job = self._jobs[jid]
            if job.status == "queued" or (job.status == "running" and job._ready):
                candidates.setdefault(job.tenant, job)
        if not candidates:
            return None
        tenant = min(
            candidates, key=lambda t: (self._tenant_pass.get(t, 0.0), t)
        )
        return candidates[tenant]

    def _bind_report(self, job: Job) -> None:
        """Point the shared engine at this job's report segment.

        The multiplexing contract: ONE TaskEngine serves every job, so
        before each unit the engine's current report AND its trace mark
        swap to the job's segment — ``traces_total - segment.traces``
        reproduces exactly the mark a dedicated executor would hold, so
        trace deltas land on the job that paid them.
        """
        engine = self.executor.engine
        engine.report = job._segment
        engine._trace_mark = engine.traces_total - job._segment.traces

    def _prepare(self, job: Job) -> None:
        job.status = "preparing"
        self._emit(job, "preparing")
        ex = self.executor
        spec = job.spec
        job._t0 = time.perf_counter()
        if job.resolved_policy is not None:
            policy, tuner = job.resolved_policy, None
        else:
            policy, tuner = ex._resolve_policy(spec)
            job.resolved_policy = policy
            if self.journal is not None:
                # Journal the RESOLVED policy: a SplIter("auto") resume
                # must re-lower at the granularity the units were keyed
                # under, not whatever a fresh tuner would propose.
                self.journal.append(("start", job.id, pickle.dumps(policy)))
        job._tuner = tuner
        job._segment = EngineReport(mode=policy.mode_name)
        self._bind_report(job)
        prepared = ex._prepare(spec.inputs, policy, job._segment)
        graph = lower(spec, prepared.arrays, prepared.groups, ex.capabilities)
        units, state, merge_unit = ex._build_units(graph)
        job._units, job._state, job._merge, job._graph = (
            units, state, merge_unit, graph,
        )
        job.total_units = len(units)

        # Restore journaled completions BEFORE computing the ready set:
        # restored units never re-run, and a fully-restored dependency set
        # (e.g. every task unit of a killed-at-the-merge job) releases its
        # dependents immediately.
        ukeys = {u.index: self._unit_key(u) for u in units}
        job._ukeys = ukeys
        for u in units:
            blob = job.completed_keys.get(ukeys[u.index])
            if blob is not None:
                state.complete(u, pickle.loads(blob))
                job.restored_units += 1
        job._ready = collections.deque(
            u
            for u in units
            if not state.is_done(u.index)
            and all(state.is_done(d) for d in u.deps)
        )
        job.status = "running"
        self._emit(
            job,
            "running",
            detail=f"policy={policy.mode_name}",
            completed=job.restored_units,
            total=job.total_units,
        )
        if state.done.is_set():  # everything restored: straight to finish
            self._finish(job)

    @staticmethod
    def _unit_key(unit) -> str:
        """Restart-stable identity of one unit within its job.

        Same plan + same resolved policy re-lower to the same unit list in
        the same order, so the index disambiguates units sharing a task
        key (one jit key covers every block group of a map fn) and the
        address-free :func:`key_summary` + block ids pin the content.
        """
        if not unit.tasks:
            return f"merge:{unit.index}"
        blocks = ",".join(
            str(b) for task in unit.tasks for b in task.block_ids
        )
        return f"u{unit.index}:{key_summary(unit.tasks[0].key)}:{blocks}"

    def _step_unit(self, job: Job) -> None:
        unit = job._ready.popleft()
        self._bind_report(job)
        t0 = time.perf_counter()
        newly = self.executor._run_unit(unit, job._state)
        job._segment.wall_s += time.perf_counter() - t0
        if job._state.errors:
            self._fail(job, job._state.errors[0])
            return
        job._ready.extend(newly)
        job.recomputed_units += 1
        if self.journal is not None:
            host = jax.tree.map(np.asarray, job._state.results[unit.index])
            self.journal.append(
                ("unit", job.id, job._ukeys[unit.index], pickle.dumps(host))
            )
        completed = job.restored_units + job.recomputed_units
        if unit.kind == "merge":
            self._emit(job, "merged", completed=completed, total=job.total_units)
        else:
            self._emit(job, "running", completed=completed, total=job.total_units)
        with self._cond:
            self._completions_total += 1
            self._units_since_snapshot += 1
            want_snapshot = (
                self.checkpointer is not None
                and self._units_since_snapshot >= self.snapshot_every
            )
        if want_snapshot:
            self._snapshot()
        if job._state.done.is_set():
            self._finish(job)

    def _finish(self, job: Job) -> None:
        state, merge_unit = job._state, job._merge
        value = (
            state.results[merge_unit.index]
            if merge_unit is not None
            else list(state.results)
        )
        policy = job.resolved_policy
        if isinstance(policy, SplIter):
            job._segment.granularity = policy.partitions_per_location
        dt = time.perf_counter() - job._t0
        if job._tuner is not None:
            self.executor._feed_tuner(
                job._tuner, policy, job._graph, dt,
                traced=job._segment.traces > 0,
            )
        job.report = (
            job.prior_report.merge(job._segment)
            if job.prior_report is not None
            else job._segment
        )
        job.result = value
        job.status = "done"
        if self.journal is not None:
            self.journal.append(("done", job.id, job.report.to_json()))
        self._emit(
            job,
            "done",
            completed=job.restored_units + job.recomputed_units,
            total=job.total_units,
        )
        job.done.set()
        with self._cond:
            self._cond.notify_all()

    def _fail(self, job: Job, exc: BaseException) -> None:
        job.error = f"{type(exc).__name__}: {exc}"
        job.status = "failed"
        if self.journal is not None:
            self.journal.append(("failed", job.id, job.error))
        self._emit(job, "failed", detail=job.error)
        job.done.set()
        with self._cond:
            self._cond.notify_all()

    # ---------------------------------------------------------- snapshot --

    def _snapshot(self) -> None:
        """Periodic scheduler-state snapshot (COMMITTED-marker layout).

        Pure-JSON extras, zero array leaves: the journal owns unit
        results; the snapshot carries what full replay alone cannot
        reconstruct — tenant fairness passes and each open job's
        cumulative report (pre-crash segments merged in), read back
        template-free via :meth:`Checkpointer.load_manifest`.
        """
        with self._cond:
            self._units_since_snapshot = 0
            extras = {
                "tenant_pass": dict(self._tenant_pass),
                "tuners": [
                    tuner.describe()
                    for _inputs, tuner in self.assets.tuners.values()
                ],
                "job_reports": {
                    job.id: (
                        job.prior_report.merge(job._segment)
                        if job.prior_report is not None
                        else job._segment
                    ).to_json()
                    for job in self._jobs.values()
                    if job.open and job._segment is not None
                },
            }
        self.checkpointer.save(self._completions_total, {}, extras=extras)
        self.checkpointer.keep_last(3)

    # ---------------------------------------------------------- lifecycle --

    def _emit(self, job: Job, kind: str, detail="", completed=0, total=0) -> None:
        ev = JobEvent(job.id, kind, detail, completed, total)
        job.events.append(ev)
        self.event_log.append(ev)

    def kill(self) -> None:
        """Crash simulation: stop scheduling NOW, mid-job, no terminal
        records.  Disk state (journal + snapshots) is left exactly as a
        SIGKILL would — the restart/resume tests drive this hook."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)
        if self.journal is not None:
            self.journal.close()

    def close(self, *, drain: bool = True, timeout: float | None = 60.0) -> None:
        """Graceful shutdown: refuse new work, optionally drain open jobs."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if drain and self._thread.is_alive():
            deadline = None if timeout is None else time.monotonic() + timeout
            for job in self.jobs():
                left = None if deadline is None else max(deadline - time.monotonic(), 0)
                job.done.wait(left)
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)
        if self.journal is not None:
            self.journal.close()
        if self._owns_executor:
            self.executor.close()

    def __enter__(self):
        """``with engine("server") as srv:`` — exit is a draining close."""
        return self

    def __exit__(self, *exc):
        self.close()
        return False
