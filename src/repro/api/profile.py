"""Per-task profiling — the *measure* third of the adaptive-granularity loop.

Every executor backend schedules its :class:`~repro.api.lowering.TaskGraph`
through the shared scheduler core in :mod:`repro.api.executors`, and that
core emits one :class:`ProfileEvent` per scheduled unit (a task, a sharded
mesh bucket, or the merge) into the executor's :class:`ProfileStore`.
Events aggregate into :class:`TaskProfile` records keyed by the task's
*signature* — its :func:`~repro.api.lowering.stable_task_key` plus the
per-task data shapes — so an iterative workload accumulates one profile per
distinct compiled program, not one per invocation.

What is measured per unit (DESIGN.md §9):

``dispatch_s``
    Time for the dispatch call to *return*.  Under JAX's async dispatch
    this is the host-side overhead — the quantity the Tiny-Tasks
    granularity model (Bora et al., arXiv:2202.11464) calls the per-task
    overhead ``o``.
``wall_s``
    Time until the unit's outputs are ready (``block_until_ready``), i.e.
    dispatch + compute.  Only measured when the store's ``sync`` flag is
    on; the default is **off**, because blocking per unit would serialize
    the async-dispatch pipeline the executors rely on (the measurement
    must not distort the thing measured).  The autotuner turns ``sync``
    on only for its probe iterations; with it off, ``wall_s ==
    dispatch_s``.
``nbytes`` / ``rows``
    Input footprint, derived from the task descriptors' ``data_shapes`` —
    no operand materialization, so recording is O(1) per unit.

The store is consumed by :mod:`repro.api.autotune` (per-task overhead
estimates seed the cost model) and is inspectable by users via
``executor.profile.snapshot()``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Hashable, Iterable, Sequence

import numpy as np

__all__ = ["ProfileEvent", "TaskProfile", "ProfileStore", "signature_nbytes"]


def signature_nbytes(data_shapes: tuple) -> int:
    """Bytes of the per-task data operands described by ``Task.data_shapes``."""
    total = 0
    for shape, dtype in data_shapes:
        total += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return total


def _signature_rows(kind: str, data_shapes: tuple) -> int:
    """Input rows of the first data operand (cost-model work proxy).

    Stacked partition operands are ``(k, block_rows, *row)`` — rows is the
    product of the two leading dims; everything else is ``(rows, *row)``.
    """
    if not data_shapes:
        return 0
    shape = data_shapes[0][0]
    if kind in ("partition_scan", "partition_pallas") and len(shape) >= 2:
        return int(shape[0]) * int(shape[1])
    return int(shape[0]) if shape else 0


@dataclasses.dataclass(frozen=True)
class ProfileEvent:
    """One scheduled unit, as observed by the scheduler core."""

    key: Hashable                # stable task key (None for driver views)
    kind: str                    # Task.kind | "sharded" | "merge"
    location: int                # placement (-1: any / caller)
    tasks: int                   # graph tasks covered (mesh buckets: >1)
    blocks: int                  # source blocks covered
    rows: int                    # input rows (first data operand)
    nbytes: int                  # input bytes across data operands
    dispatch_s: float            # host-side dispatch overhead
    wall_s: float                # dispatch + compute (== dispatch_s if !sync)


@dataclasses.dataclass
class TaskProfile:
    """Aggregate over all events sharing one (key, data_shapes) signature."""

    key: Hashable
    data_shapes: tuple
    kind: str
    calls: int = 0
    tasks: int = 0
    blocks: int = 0
    rows: int = 0
    nbytes: int = 0
    dispatch_s: float = 0.0
    wall_s: float = 0.0

    def add(self, event: ProfileEvent) -> None:
        self.calls += 1
        self.tasks += event.tasks
        self.blocks += event.blocks
        self.rows += event.rows
        self.nbytes += event.nbytes
        self.dispatch_s += event.dispatch_s
        self.wall_s += event.wall_s

    @property
    def mean_dispatch_s(self) -> float:
        return self.dispatch_s / self.calls if self.calls else 0.0

    @property
    def mean_wall_s(self) -> float:
        return self.wall_s / self.calls if self.calls else 0.0

    @property
    def seconds_per_row(self) -> float:
        return self.wall_s / self.rows if self.rows else 0.0


class ProfileStore:
    """Thread-safe per-executor store of profile events and aggregates.

    ``sync=True`` blocks on each unit's outputs so ``wall_s`` covers
    compute; the default ``sync=False`` only times the dispatch overhead
    and never introduces extra synchronization points into scheduling
    (the executors flip it on transiently while the autotuner probes).
    A bounded deque of recent raw events is kept for inspection; the
    per-signature aggregates are unbounded but small (one per compiled
    program).
    """

    def __init__(self, *, sync: bool = False, keep_events: int = 256):
        self.sync = sync
        self.events: collections.deque[ProfileEvent] = collections.deque(
            maxlen=keep_events
        )
        self.profiles: dict[tuple, TaskProfile] = {}
        self._lock = threading.Lock()

    def record_tasks(
        self,
        tasks: Sequence[Any],
        *,
        kind: str,
        location: int,
        dispatch_s: float,
        wall_s: float,
    ) -> ProfileEvent:
        """Record one scheduled unit covering ``tasks`` graph descriptors.

        ``tasks`` duck-types :class:`~repro.api.lowering.Task` (``key``,
        ``kind``, ``block_ids``, ``data_shapes``); an empty sequence records
        a task-less unit (the merge) under ``key=None``.
        """
        if tasks:
            t0 = tasks[0]
            key, shapes = t0.key, t0.data_shapes
            blocks = sum(len(t.block_ids) for t in tasks)
            rows = sum(_signature_rows(t.kind, t.data_shapes) for t in tasks)
            nbytes = sum(signature_nbytes(t.data_shapes) for t in tasks)
        else:
            key, shapes, blocks, rows, nbytes = None, (), 0, 0, 0
        event = ProfileEvent(
            key=key,
            kind=kind,
            location=location,
            tasks=max(len(tasks), 1),
            blocks=blocks,
            rows=rows,
            nbytes=nbytes,
            dispatch_s=dispatch_s,
            wall_s=wall_s,
        )
        sig = (_hashable(key), shapes, kind)
        with self._lock:
            self.events.append(event)
            prof = self.profiles.get(sig)
            if prof is None:
                prof = self.profiles[sig] = TaskProfile(
                    key=key, data_shapes=shapes, kind=kind
                )
            prof.add(event)
        return event

    # -- read side -----------------------------------------------------------

    def snapshot(self) -> list[TaskProfile]:
        """Aggregates, most expensive first (copy; safe to hold)."""
        with self._lock:
            profs = [dataclasses.replace(p) for p in self.profiles.values()]
        return sorted(profs, key=lambda p: -p.wall_s)

    def mean_task_overhead_s(
        self,
        kinds: Iterable[str] | None = None,
        keys: Iterable[Hashable] | None = None,
    ) -> float:
        """Mean per-task dispatch overhead across (optionally filtered) kinds.

        This is the measured seed for the cost model's per-task overhead
        coefficient when too few granularities have been sampled to fit.
        ``keys`` restricts the mean to specific task identities so one
        workload's hint is not polluted by everything else the executor
        ever ran.
        """
        key_set = None if keys is None else set(keys)
        with self._lock:
            profs = [
                p
                for p in self.profiles.values()
                if (kinds is None or p.kind in kinds)
                and (key_set is None or p.key in key_set)
            ]
            tasks = sum(p.tasks for p in profs)
            overhead = sum(p.dispatch_s for p in profs)
        return overhead / tasks if tasks else 0.0

    def merge(self, other: "ProfileStore") -> None:
        """Fold another store's aggregates into this one (events included).

        The shared-asset adoption path: when an executor joins a
        :class:`~repro.api.executors.SharedAssets` pool, its pre-pool
        private history folds into the shared store so earlier probes
        keep informing the overhead hint.  ``other`` is left untouched.
        """
        with other._lock:
            events = list(other.events)
            profs = [dataclasses.replace(p) for p in other.profiles.values()]
        with self._lock:
            self.events.extend(events)
            for p in profs:
                sig = (_hashable(p.key), p.data_shapes, p.kind)
                mine = self.profiles.get(sig)
                if mine is None:
                    self.profiles[sig] = p
                else:
                    mine.calls += p.calls
                    mine.tasks += p.tasks
                    mine.blocks += p.blocks
                    mine.rows += p.rows
                    mine.nbytes += p.nbytes
                    mine.dispatch_s += p.dispatch_s
                    mine.wall_s += p.wall_s

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.profiles.clear()


def _hashable(key: Hashable) -> Hashable:
    try:
        hash(key)
        return key
    except TypeError:
        return id(key)
