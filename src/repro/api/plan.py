"""Execution plan IR — the lazy middle layer between Collection and Executor.

A :class:`repro.api.Collection` method chain builds a linked list of small
frozen node dataclasses; nothing runs until ``.compute(executor=...)``.
The grammar accepted by executors is

::

    plan    := [Reduce] map [Split] Source
    map     := MapBlocks | MapPartitions

:class:`ExecutionPlan` normalizes a node chain into a flat
:class:`MapReduceSpec` at construction time, so malformed chains fail fast
(with a :class:`PlanError`) instead of failing mid-execution, and every
executor backend consumes the same validated spec.  ``describe()`` renders
the plan for logging / DESIGN.md examples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.api.policy import Baseline, ExecutionPolicy
from repro.core.blocked import BlockedArray

__all__ = [
    "PlanError",
    "PlanNode",
    "Source",
    "Split",
    "MapBlocks",
    "MapPartitions",
    "Reduce",
    "MapReduceSpec",
    "ExecutionPlan",
]


class PlanError(ValueError):
    """A Collection chain does not form a valid execution plan."""


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """Base class of plan IR nodes."""


@dataclasses.dataclass(frozen=True)
class Source(PlanNode):
    """Leaf: one or more blocking-aligned :class:`BlockedArray` inputs."""

    arrays: tuple[BlockedArray, ...]


@dataclasses.dataclass(frozen=True)
class Split(PlanNode):
    """Derive task granularity from the blocking via an ExecutionPolicy."""

    child: PlanNode
    policy: ExecutionPolicy


@dataclasses.dataclass(frozen=True)
class MapBlocks(PlanNode):
    """Apply ``fn(*blocks, *extra_args)`` to every aligned block group."""

    child: PlanNode
    fn: Callable[..., Any]
    extra_args: tuple = ()


@dataclasses.dataclass(frozen=True)
class MapPartitions(PlanNode):
    """Apply ``fn(view)`` to every :class:`~repro.api.executors.PartitionView`.

    Under :class:`~repro.api.policy.Baseline` each block is its own
    single-block partition, so the same app code expresses both the
    per-block and the consolidated (SplIter) execution — this is what
    removes the hand-written mode plumbing from k-NN and Cascade SVM.
    """

    child: PlanNode
    fn: Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class Reduce(PlanNode):
    """Fold all map partials with an associative ``combine`` into one value."""

    child: PlanNode
    combine: Callable[[Any, Any], Any]


@dataclasses.dataclass(frozen=True)
class MapReduceSpec:
    """Normalized, validated view of a plan — what executors consume."""

    inputs: tuple[BlockedArray, ...]
    policy: ExecutionPolicy
    kind: str                       # "map_blocks" | "map_partitions"
    fn: Callable[..., Any]
    extra_args: tuple
    combine: Callable[[Any, Any], Any] | None


def _normalize(root: PlanNode) -> MapReduceSpec:
    node = root
    combine = None
    if isinstance(node, Reduce):
        combine = node.combine
        node = node.child

    if isinstance(node, MapBlocks):
        kind, fn, extra = "map_blocks", node.fn, node.extra_args
        node = node.child
    elif isinstance(node, MapPartitions):
        kind, fn, extra = "map_partitions", node.fn, ()
        node = node.child
    elif isinstance(node, (Source, Split)):
        raise PlanError("plan has no map stage; call .map_blocks() or .map_partitions()")
    else:
        raise PlanError(f"unexpected node under Reduce: {type(node).__name__}")

    policy: ExecutionPolicy = Baseline()
    if isinstance(node, Split):
        policy = node.policy
        node = node.child

    if not isinstance(node, Source):
        raise PlanError(
            f"expected Source at the bottom of the plan, got {type(node).__name__} "
            "(only one map stage and one split are supported per plan)"
        )
    inputs = node.arrays
    if not inputs:
        raise PlanError("empty Source")
    x0 = inputs[0]
    for a in inputs[1:]:
        if a.num_blocks != x0.num_blocks or not np.array_equal(a.placements, x0.placements):
            raise PlanError("Source inputs must be blocking-aligned (same blocks/placements)")
    return MapReduceSpec(
        inputs=inputs, policy=policy, kind=kind, fn=fn, extra_args=tuple(extra),
        combine=combine,
    )


class ExecutionPlan:
    """A validated plan: the node chain plus its normalized spec."""

    def __init__(self, root: PlanNode):
        self.root = root
        self.spec = _normalize(root)

    def describe(self) -> str:
        """Render the plan bottom-up, one node per line."""
        s = self.spec
        x0 = s.inputs[0]
        lines = [
            f"Source({len(s.inputs)} array(s), {x0.num_blocks} blocks, "
            f"{x0.num_locations} locations)",
            f"Split({s.policy!r})",
        ]
        fn_name = getattr(s.fn, "__name__", type(s.fn).__name__)
        if s.kind == "map_blocks":
            lines.append(f"MapBlocks({fn_name}, extra_args={len(s.extra_args)})")
        else:
            lines.append(f"MapPartitions({fn_name})")
        if s.combine is not None:
            cname = getattr(s.combine, "__name__", type(s.combine).__name__)
            lines.append(f"Reduce({cname})")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return f"ExecutionPlan(\n  " + self.describe().replace("\n", "\n  ") + "\n)"
