"""Fault-tolerance machinery: heartbeats, straggler detection, restart policy.

On a real multi-host deployment these hooks wire into the cluster manager
(GKE/Borg preemption signals, jax.distributed heartbeats).  The logic itself
is host-agnostic and is exercised by simulation in tests:

* :class:`HeartbeatMonitor` — per-worker last-seen timestamps; workers that
  miss ``timeout`` are declared dead → the runner triggers
  checkpoint-restore on the survivor set (elastic restore path).
* :class:`StragglerDetector` — per-step wall-time EWMA + k·MAD outlier
  rule.  On sustained straggle it recommends a re-split: the SplIter's
  ``partitions_per_location`` map is rebuilt with the slow worker's
  capacity discounted — the paper's "computing capability" input made
  dynamic (DESIGN.md §5).
* :class:`PreemptionGuard` — converts SIGTERM/SIGINT into a
  checkpoint-then-exit request the training loop polls between steps.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque


class HeartbeatMonitor:
    def __init__(self, workers: list[str], timeout: float = 60.0):
        self.timeout = timeout
        self.last_seen = {w: time.monotonic() for w in workers}

    def beat(self, worker: str, now: float | None = None) -> None:
        self.last_seen[worker] = now if now is not None else time.monotonic()

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = now if now is not None else time.monotonic()
        return [w for w, t in self.last_seen.items() if now - t > self.timeout]

    def remove(self, worker: str) -> None:
        self.last_seen.pop(worker, None)


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    worker: str | None
    ratio: float  # slowest / median


class StragglerDetector:
    """Flags a worker whose step time exceeds median · threshold for
    ``patience`` consecutive steps."""

    def __init__(self, workers: list[str], threshold: float = 1.5, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self.history: dict[str, deque] = {w: deque(maxlen=16) for w in workers}
        self._strikes: dict[str, int] = {w: 0 for w in workers}

    def record_step(self, times: dict[str, float]) -> StragglerVerdict:
        for w, t in times.items():
            self.history[w].append(t)
        med = sorted(times.values())[len(times) // 2]
        worst = max(times, key=times.get)
        ratio = times[worst] / max(med, 1e-9)
        for w in times:
            if w == worst and ratio > self.threshold:
                self._strikes[w] += 1
            else:
                self._strikes[w] = 0
        if self._strikes[worst] >= self.patience:
            return StragglerVerdict(True, worst, ratio)
        return StragglerVerdict(False, None, ratio)

    def capacity_weights(self, workers: list[str]) -> dict[str, float]:
        """Relative capacity per worker (1/EWMA step time, normalized) —
        feeds SplIter's partitions_per_location for the re-split."""
        inv = {}
        for w in workers:
            h = self.history[w]
            inv[w] = 1.0 / (sum(h) / len(h)) if h else 1.0
        s = sum(inv.values())
        return {w: v / s * len(workers) for w, v in inv.items()}


class PreemptionGuard:
    """SIGTERM/SIGINT → graceful checkpoint request (poll ``should_stop``)."""

    def __init__(self, install: bool = True):
        self._stop = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                self._prev[sig] = signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self._stop = True

    def request_stop(self) -> None:  # testable without raising signals
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
