"""Batched serving: prefill/decode loop over blocked request batches.

Requests arrive as a *blocked collection* (the paper's L2 mapping again):
a request block = a group of same-length prompts.  The server prefills each
block, then runs a fused decode loop — ONE dispatch per decode step for the
whole batch (SplIter) vs. one dispatch per request block (baseline), the
serving analogue of the accumulation modes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    dispatches: int
    tokens_out: int


class Server:
    def __init__(self, cfg: ModelConfig, *, max_len: int = 256):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def load(self, params: Any) -> None:
        self.params = params

    def generate(
        self,
        prompts: np.ndarray,  # (B, P) int32
        *,
        steps: int = 32,
        greedy: bool = True,
        extras: dict[str, jax.Array] | None = None,
    ) -> tuple[np.ndarray, ServeStats]:
        b, p = prompts.shape
        # cache in the model's compute dtype (fp32 models get fp32 caches)
        cache = self.model.init_cache(b, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        batch = {"tokens": jnp.asarray(prompts, jnp.int32), **(extras or {})}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        logits = jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        memory = (extras or {}).get("image_embeds")
        out = []
        dispatches = 1
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for i in range(steps):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(
                self.params, cache, tok, jnp.asarray(p + i, jnp.int32), memory
            )
            dispatches += 1
            if greedy:
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            else:
                key = jax.random.key(i)
                tok = jax.random.categorical(key, logits)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        return (
            np.stack(out, 1),
            ServeStats(t_prefill, t_decode, dispatches, b * steps),
        )
