"""Fault-tolerant training loop with SplIter-fused gradient accumulation.

The Trainer owns: the jitted train step (one dispatch per optimizer step in
``spliter`` mode — paper Listing 5 at trainer level), the optimizer, the
resumable data pipeline, preemption-safe checkpointing, and the straggler
hooks.  ``accum_mode`` selects the paper's three execution strategies so
benchmarks can sweep them on identical math:

  spliter       scan over local microbatch blocks (1 dispatch/step)
  per_block     1 dispatch per microbatch + host accumulation (baseline)
  materialized  single fused microbatch (rechunk-equivalent, max memory)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import BlockedBatchPipeline, PipelineState
from repro.models import build_model
from repro.optim import (
    accumulate_gradients,
    adamw_init,
    adamw_update,
    cosine_schedule,
)
from repro.runtime.ft import PreemptionGuard, StragglerDetector


@dataclasses.dataclass
class TrainConfig:
    global_batch: int = 16
    num_blocks: int = 4          # microbatch blocks per step (the blocking)
    seq_len: int = 64
    steps: int = 50
    peak_lr: float = 3e-3
    warmup_steps: int = 10
    weight_decay: float = 0.1
    accum_mode: str = "spliter"  # spliter | per_block | materialized
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 0          # 0 = only on preemption
    keep_ckpts: int = 2


class Trainer:
    def __init__(self, model_cfg: ModelConfig, cfg: TrainConfig):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.model = build_model(model_cfg)
        self.pipeline = BlockedBatchPipeline(
            vocab_size=model_cfg.vocab_size,
            seq_len=cfg.seq_len,
            global_batch=cfg.global_batch,
            num_blocks=cfg.num_blocks,
            seed=cfg.seed,
        )
        self.ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
        self.straggler = StragglerDetector(["self"])
        self._build_steps()

    # ------------------------------------------------------------------
    def _build_steps(self):
        model, cfg = self.model, self.cfg

        def lr(step):
            return cosine_schedule(
                step,
                peak_lr=cfg.peak_lr,
                warmup_steps=cfg.warmup_steps,
                total_steps=cfg.steps,
            )

        def full_step(params, opt, blocks):
            loss, grads = accumulate_gradients(
                model.loss, params, blocks, mode="spliter"
            )
            new_params, new_opt = adamw_update(
                params, grads, opt, lr=lr(opt.step), weight_decay=cfg.weight_decay
            )
            return new_params, new_opt, loss

        def mat_step(params, opt, blocks):
            loss, grads = accumulate_gradients(
                model.loss, params, blocks, mode="materialized"
            )
            new_params, new_opt = adamw_update(
                params, grads, opt, lr=lr(opt.step), weight_decay=cfg.weight_decay
            )
            return new_params, new_opt, loss

        def block_grad(params, microbatch):
            return jax.value_and_grad(model.loss)(params, microbatch)

        def apply_update(params, opt, grads, nb):
            grads = jax.tree.map(lambda g: g / nb, grads)
            new_params, new_opt = adamw_update(
                params, grads, opt, lr=lr(opt.step), weight_decay=cfg.weight_decay
            )
            return new_params, new_opt

        donate = dict(donate_argnums=(0, 1))
        self._full_step = jax.jit(full_step, **donate)
        self._mat_step = jax.jit(mat_step, **donate)
        self._block_grad = jax.jit(block_grad)
        self._apply_update = jax.jit(apply_update, static_argnums=(3,), **donate)

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        params = self.model.init(key if key is not None else jax.random.key(self.cfg.seed))
        return params, adamw_init(params)

    def train_step(self, params, opt, blocks: dict[str, np.ndarray]):
        """One optimizer step in the configured accumulation mode.

        Returns (params, opt, loss, n_dispatches)."""
        mode = self.cfg.accum_mode
        blocks = {k: jnp.asarray(v) for k, v in blocks.items()}
        if mode == "spliter":
            p, o, loss = self._full_step(params, opt, blocks)
            return p, o, loss, 1
        if mode == "materialized":
            p, o, loss = self._mat_step(params, opt, blocks)
            return p, o, loss, 1
        assert mode == "per_block", mode
        nb = jax.tree.leaves(blocks)[0].shape[0]
        loss_sum, grad_acc = 0.0, None
        for i in range(nb):  # paper baseline: one dispatch per block
            mb = jax.tree.map(lambda x: x[i], blocks)
            loss, g = self._block_grad(params, mb)
            loss_sum += loss
            grad_acc = g if grad_acc is None else jax.tree.map(jnp.add, grad_acc, g)
        p, o = self._apply_update(params, opt, grad_acc, nb)
        return p, o, loss_sum / nb, nb + 1

    # ------------------------------------------------------------------
    def run(
        self,
        *,
        steps: int | None = None,
        resume: bool = True,
        guard: PreemptionGuard | None = None,
        on_step: Callable[[int, float], None] | None = None,
    ) -> dict[str, Any]:
        """Train; preemption-safe; resumes from the newest checkpoint."""
        cfg = self.cfg
        steps = steps if steps is not None else cfg.steps
        params, opt = self.init_state()
        start = 0

        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            (params, opt), extras, start = self.ckpt.restore((params, opt))
            self.pipeline.state = PipelineState.from_json(extras["pipeline"])
            start = int(extras["next_step"])

        losses = []
        dispatches = 0
        it = iter(self.pipeline)
        t_total0 = time.perf_counter()
        for step in range(start, steps):
            t0 = time.perf_counter()
            blocks = next(it)
            params, opt, loss, nd = self.train_step(params, opt, blocks)
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.straggler.record_step({"self": dt})
            losses.append(loss)
            dispatches += nd
            if on_step:
                on_step(step, loss)

            want_ckpt = cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0
            preempted = guard is not None and guard.should_stop
            if self.ckpt and (want_ckpt or preempted):
                self.ckpt.save(
                    step + 1,
                    (params, opt),
                    extras={
                        "pipeline": self.pipeline.state.to_json(),
                        "next_step": step + 1,
                        "loss": loss,
                    },
                    blocking=preempted,  # async for periodic, sync on exit
                )
                self.ckpt.keep_last(cfg.keep_ckpts)
            if preempted:
                self.pipeline.close()
                return {
                    "params": params,
                    "opt": opt,
                    "losses": losses,
                    "stopped_at": step + 1,
                    "dispatches": dispatches,
                    "preempted": True,
                }
        self.pipeline.close()
        if self.ckpt:
            self.ckpt.wait()
        return {
            "params": params,
            "opt": opt,
            "losses": losses,
            "stopped_at": steps,
            "dispatches": dispatches,
            "preempted": False,
            "wall_s": time.perf_counter() - t_total0,
        }
