"""Runtime: fault-tolerant training loop, batched serving, FT machinery."""

from repro.runtime.trainer import Trainer, TrainConfig

__all__ = ["Trainer", "TrainConfig"]
