"""Batched serving driver (deliverable b).

Loads (randomly initialized, or checkpointed) weights for a smoke-sized
architecture and serves batched generation requests through the blocked
request queue — prefill once, then a fused decode loop (one dispatch per
step for the whole batch; the serving analogue of the SplIter accumulation).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --batch 8 --prompt-len 16 --steps 32
    PYTHONPATH=src python -m repro.launch.serve --arch whisper-tiny --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.checkpoint import Checkpointer
from repro.models import build_model
from repro.runtime.server import Server


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sample", action="store_true", help="sample instead of greedy")
    ap.add_argument("--ckpt-dir", default=None, help="restore params from here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    if args.ckpt_dir:
        from repro.optim import adamw_init

        ckpt = Checkpointer(args.ckpt_dir)
        opt_tmpl = jax.eval_shape(adamw_init, params)
        (params, _opt), _extras, step = ckpt.restore((params, opt_tmpl))
        print(f"restored step {step} from {args.ckpt_dir}")

    n_params = cfg.param_counts()["total"]
    print(f"serving {cfg.name} ({n_params / 1e6:.1f}M params) "
          f"batch={args.batch} prompt={args.prompt_len} steps={args.steps}",
          flush=True)

    server = Server(cfg, max_len=args.max_len)
    server.load(params)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    extras: dict[str, jax.Array] = {}
    if cfg.family == "audio":
        extras["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16,
        )
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.image_tokens, cfg.image_embed_dim)),
            jnp.bfloat16,
        )

    t0 = time.perf_counter()
    tokens, stats = server.generate(
        prompts, steps=args.steps, greedy=not args.sample, extras=extras
    )
    wall = time.perf_counter() - t0
    print(f"prefill {stats.prefill_s * 1e3:.1f} ms   "
          f"decode {stats.decode_s * 1e3:.1f} ms "
          f"({stats.decode_s / args.steps * 1e3:.2f} ms/tok)   "
          f"dispatches={stats.dispatches}   "
          f"throughput={stats.tokens_out / wall:.1f} tok/s", flush=True)
    print("first request's tokens:", tokens[0].tolist())


if __name__ == "__main__":
    main()
