"""Dry-run library: lower + compile every (arch × shape × mesh) cell.

Pure library — does NOT touch XLA_FLAGS / device state.  The CLI wrapper
``repro.launch.dryrun`` sets the 512-device host platform before importing
anything; tests use a small mesh via ``make_test_mesh``.

Per cell this produces:
  * compiled artifact for the *scanned* full config → ``memory_analysis``
    (the per-device fits proof) + the collective schedule of one layer
    (loop body) — and compile/lower wall times;
  * optional roofline probes (two small *unrolled* depths) → linear-fit
    extrapolation of FLOPs / bytes / collective bytes to the real depth
    (``cost_analysis`` counts a scan body once — DESIGN.md §6).

Step functions lowered per shape kind:
  train   — SplIter-fused accumulation over microbatch blocks + AdamW update
  prefill — prompt forward into the decode cache
  decode  — one token against a seq_len-long cache (serve_step)
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import (
    cache_shardings,
    decode_rules,
    decode_rules_headsharded,
    long_decode_rules,
    params_shardings,
    train_rules,
    train_rules_sp,
    use_rules,
)
from repro.models import build_model
from repro.optim import accumulate_gradients, adamw_init, adamw_update
from repro.analysis.hlo import parse_collectives

# Shape-cell applicability (DESIGN.md §Arch-applicability):
# long_500k only for sub-quadratic archs; reason recorded in the result.
def cell_skip_reason(cfg: ModelConfig, shape: ShapeCell) -> str | None:
    if shape.name == "long_500k" and not cfg.is_seq_subquadratic:
        return (
            "pure full-attention stack: 524k-token decode needs sub-quadratic "
            "attention/state (run for ssm/hybrid/SWA archs only)"
        )
    return None


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _bf16_like(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
        ),
        tree,
    )


def _replicated(mesh, tree):
    return jax.tree.map(lambda l: NamedSharding(mesh, P(*((None,) * l.ndim))), tree)


# ---------------------------------------------------------------------------
# per-kind lowering
# ---------------------------------------------------------------------------


def _lower_train(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeCell,
    num_blocks: int = 4,
    accum_mode: str = "spliter",
    sp: bool = False,
    hoist: bool = False,
):
    model = build_model(cfg)
    dp = _dp_axes(mesh)

    params = jax.eval_shape(model.init, jax.random.key(0))
    opt = jax.eval_shape(adamw_init, params)
    specs = model.input_specs(shape)
    mb = shape.global_batch // num_blocks
    blocks = {
        k: jax.ShapeDtypeStruct((num_blocks, mb) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }

    constraint = (
        (lambda t: jax.lax.with_sharding_constraint(
            t, params_shardings(t, mesh, fsdp_axis=None)))
        if hoist
        else None
    )

    def train_step(params, opt, blocks):
        loss, grads = accumulate_gradients(
            model.loss, params, blocks, mode=accum_mode,
            hoist=hoist, hoist_constraint=constraint,
        )
        new_params, new_opt = adamw_update(params, grads, opt, lr=1e-4)
        return new_params, new_opt, loss

    p_sh = params_shardings(params, mesh, fsdp_axis="data")
    o_sh = dataclasses.replace(
        params_shardings(opt, mesh, fsdp_axis="data"),
        step=NamedSharding(mesh, P()),
    )
    b_sh = {
        k: NamedSharding(mesh, P(None, dp, *(None,) * (v.ndim - 2)))
        for k, v in blocks.items()
    }
    rules = train_rules_sp(mesh) if sp else train_rules(mesh)
    with use_rules(rules):
        lowered = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        ).lower(params, opt, blocks)
    return lowered


def _serving_fsdp(cfg: ModelConfig) -> Any:
    """Serving keeps bf16 weights TP-only when they fit; else ZeRO over data."""
    bf16_bytes = cfg.param_counts()["total"] * 2
    return "data" if bf16_bytes / 16 > 12e9 else None


def _lower_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell):
    model = build_model(cfg)
    dp = _dp_axes(mesh)
    params = _bf16_like(jax.eval_shape(model.init, jax.random.key(0)))
    specs = model.input_specs(shape)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
    )

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    p_sh = params_shardings(params, mesh, fsdp_axis=_serving_fsdp(cfg))
    b_sh = {
        k: NamedSharding(mesh, P(dp, *(None,) * (v.ndim - 1)))
        for k, v in specs.items()
    }
    c_sh = cache_shardings(cache, mesh)
    with use_rules(decode_rules(mesh)):
        lowered = jax.jit(
            prefill_step,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(NamedSharding(mesh, P(dp, "model")), c_sh),
            donate_argnums=(2,),
        ).lower(params, specs, cache)
    return lowered


def _lower_decode(
    cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, cache_impl: str = "masked"
):
    model = build_model(cfg)
    long_ctx = shape.global_batch == 1
    dp = _dp_axes(mesh)
    params = _bf16_like(jax.eval_shape(model.init, jax.random.key(0)))
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
    )
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    p_sh = params_shardings(params, mesh, fsdp_axis=_serving_fsdp(cfg))
    c_sh = cache_shardings(
        cache, mesh, long_context=long_ctx,
        layout="heads" if "heads_dus" in cache_impl else "seq",
    )
    batch_ax = None if long_ctx else dp
    t_sh = NamedSharding(mesh, P(batch_ax, None))
    if long_ctx:
        rules = long_decode_rules(mesh)
    elif "heads_dus" in cache_impl:
        rules = decode_rules_headsharded(mesh)
    else:
        rules = decode_rules(mesh)
    rules = dataclasses.replace(rules, cache_impl=cache_impl)
    with use_rules(rules):
        lowered = jax.jit(
            decode_step,
            in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, P(batch_ax, "model")), c_sh),
            donate_argnums=(1,),
        ).lower(params, cache, token, pos)
    return lowered


def lower_cell(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeCell,
    *,
    num_blocks: int = 4,
    sp: bool = False,
    cache_impl: str = "masked",
    hoist: bool = False,
):
    if shape.kind == "train":
        return _lower_train(
            cfg, mesh, shape, num_blocks=num_blocks, sp=sp, hoist=hoist
        )
    if shape.kind == "prefill":
        return _lower_prefill(cfg, mesh, shape)
    return _lower_decode(cfg, mesh, shape, cache_impl=cache_impl)


# ---------------------------------------------------------------------------
# analysis capture
# ---------------------------------------------------------------------------


def analyze_compiled(lowered, compiled) -> dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_live_bytes": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        },
        "cost": {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        },
        "collectives": coll.as_dict(),
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    mesh_label: str,
    overrides: dict[str, Any] | None = None,
    num_blocks: int = 4,
    sp: bool = False,
    cache_impl: str = "masked",
    hoist: bool = False,
) -> dict[str, Any]:
    """Lower + compile + analyze one cell.  Returns a JSON-able record."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label,
        "devices": int(np.prod([mesh.shape[a] for a in mesh.axis_names])),
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "SKIP"
        rec["reason"] = skip
        return rec
    t0 = time.perf_counter()
    lowered = lower_cell(
        cfg, mesh, shape, num_blocks=num_blocks, sp=sp, cache_impl=cache_impl,
        hoist=hoist,
    )
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    rec.update(
        status="OK",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        **analyze_compiled(lowered, compiled),
    )
    return rec


# ---------------------------------------------------------------------------
# roofline probes: unrolled-depth compiles → linear extrapolation
# ---------------------------------------------------------------------------
#
# ``cost_analysis()`` counts a ``while`` (scan) body once, so the scanned
# full-size artifact under-reports FLOPs/bytes by ~the trip count.  The probe
# compiles the SAME cell at two small depths with ``unroll_layers=True`` (no
# scan anywhere: the grad-accum scan is replaced by one materialized block)
# and fits cost(k) = a + b·k, extrapolating to the real depth R.  Every
# config has exactly one depth-scaled segment (asserted), so the fit is exact
# for homogeneous stacks and period-exact for heterogeneous ones.


def probe_config(cfg: ModelConfig, k: int) -> tuple[ModelConfig, int]:
    """Clamp the repeated-segment depth to ``k`` periods; return (cfg_k, R).

    R is the full-config repeat count of the scaled segment(s) — the
    extrapolation target.  Encoder segments (whisper) scale together with
    the decoder (their full repeats are equal; asserted).
    """
    f = cfg.family
    if f == "hybrid":
        n, R = cfg.attn_period * k, cfg.num_layers // cfg.attn_period
        cfg_k = dataclasses.replace(cfg, num_layers=n)
    elif f == "vlm":
        n, R = cfg.cross_attn_period * k, cfg.num_layers // cfg.cross_attn_period
        cfg_k = dataclasses.replace(cfg, num_layers=n)
    elif f == "audio":
        assert cfg.encoder_layers == cfg.num_layers, (
            "audio probe assumes enc/dec repeats are equal"
        )
        R = cfg.num_layers
        cfg_k = dataclasses.replace(cfg, num_layers=k, encoder_layers=k)
    elif cfg.moe_first_dense:
        R = cfg.num_layers - cfg.moe_first_dense
        cfg_k = dataclasses.replace(cfg, num_layers=cfg.moe_first_dense + k)
    else:
        R = cfg.num_layers
        cfg_k = dataclasses.replace(cfg, num_layers=k)
    cfg_k = dataclasses.replace(cfg_k, unroll_layers=True)
    # exactly one depth-scaled segment family (the fit slope is per-k of it)
    return cfg_k, R


def _probe_metrics(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeCell,
    *,
    sp: bool = False,
    cache_impl: str = "masked",
    hoist: bool = False,
    probe_blocks: int = 1,
) -> dict[str, float]:
    if shape.kind == "train":
        lowered = _lower_train(
            cfg, mesh, shape,
            num_blocks=probe_blocks,
            accum_mode="materialized" if probe_blocks == 1 else "spliter_unrolled",
            sp=sp,
            hoist=hoist,
        )
    elif shape.kind == "prefill":
        lowered = _lower_prefill(cfg, mesh, shape)
    else:
        lowered = _lower_decode(cfg, mesh, shape, cache_impl=cache_impl)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll.total_operand_bytes),
        "collective_by_kind": {k: float(v) for k, v in coll.operand_bytes.items()},
    }


def probe_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    mesh_label: str,
    depths: tuple[int, int] = (1, 2),
    overrides: dict[str, Any] | None = None,
    sp: bool = False,
    cache_impl: str = "masked",
    hoist: bool = False,
    probe_blocks: int = 1,
) -> dict[str, Any]:
    """Two unrolled-depth compiles → per-chip cost extrapolated to full depth."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label,
        "devices": int(np.prod([mesh.shape[a] for a in mesh.axis_names])),
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "SKIP"
        rec["reason"] = skip
        return rec
    k1, k2 = depths
    t0 = time.perf_counter()
    cfg1, R = probe_config(cfg, k1)
    cfg2, _ = probe_config(cfg, k2)
    kw = dict(sp=sp, cache_impl=cache_impl, hoist=hoist, probe_blocks=probe_blocks)
    m1 = _probe_metrics(cfg1, mesh, shape, **kw)
    m2 = _probe_metrics(cfg2, mesh, shape, **kw)

    def fit(v1: float, v2: float) -> float:
        slope = max((v2 - v1) / (k2 - k1), 0.0)
        return v1 + slope * (R - k1)

    kinds = set(m1["collective_by_kind"]) | set(m2["collective_by_kind"])
    rec.update(
        status="OK",
        depths={str(k1): m1, str(k2): m2},
        repeats=R,
        probe_s=round(time.perf_counter() - t0, 2),
        extrapolated={
            "flops": fit(m1["flops"], m2["flops"]),
            "bytes_accessed": fit(m1["bytes_accessed"], m2["bytes_accessed"]),
            "collective_bytes": fit(m1["collective_bytes"], m2["collective_bytes"]),
            "collective_by_kind": {
                k: fit(m1["collective_by_kind"].get(k, 0.0),
                       m2["collective_by_kind"].get(k, 0.0))
                for k in sorted(kinds)
            },
        },
    )
    return rec


def run_probe_matrix(
    arches: list[str],
    shapes: list[str],
    meshes: list[tuple[str, Mesh]],
    out_path: str | None = None,
    *,
    verbose: bool = True,
) -> list[dict[str, Any]]:
    results = []
    for mesh_label, mesh in meshes:
        for arch in arches:
            for shape_name in shapes:
                try:
                    rec = probe_cell(arch, shape_name, mesh, mesh_label=mesh_label)
                except Exception as e:
                    rec = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mesh_label,
                        "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                    }
                results.append(rec)
                if verbose:
                    s = rec["status"]
                    extra = ""
                    if s == "OK":
                        ex = rec["extrapolated"]
                        extra = (f" flops={ex['flops']:.3g}"
                                 f" bytes={ex['bytes_accessed']:.3g}"
                                 f" coll={ex['collective_bytes']:.3g}"
                                 f" ({rec['probe_s']}s)")
                    elif s == "FAIL":
                        extra = " " + rec["error"][:140]
                    print(f"[probe:{mesh_label}] {arch:22s} {shape_name:12s} {s}{extra}",
                          flush=True)
                if out_path:
                    os.makedirs(os.path.dirname(out_path), exist_ok=True)
                    with open(out_path, "w") as f:
                        json.dump(results, f, indent=1)
    return results


def run_matrix(
    arches: list[str],
    shapes: list[str],
    meshes: list[tuple[str, Mesh]],
    out_path: str | None = None,
    *,
    verbose: bool = True,
) -> list[dict[str, Any]]:
    results = []
    for mesh_label, mesh in meshes:
        for arch in arches:
            for shape_name in shapes:
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_label=mesh_label)
                except Exception as e:  # a failed cell is a bug — record it
                    rec = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mesh_label,
                        "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                    }
                results.append(rec)
                if verbose:
                    s = rec["status"]
                    extra = ""
                    if s == "OK":
                        gb = rec["memory"]["peak_live_bytes"] / 1e9
                        extra = f" peak={gb:.2f}GB/dev lower={rec['lower_s']}s compile={rec['compile_s']}s"
                    elif s == "FAIL":
                        extra = " " + rec["error"][:120]
                    print(f"[{mesh_label}] {arch:22s} {shape_name:12s} {s}{extra}", flush=True)
                if out_path:
                    os.makedirs(os.path.dirname(out_path), exist_ok=True)
                    with open(out_path, "w") as f:
                        json.dump(results, f, indent=1)
    return results
