import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run named variants of one (arch × shape) cell and
report the three roofline terms + peak memory, before/after.

Each variant is one hypothesis from EXPERIMENTS.md §Perf.  The scanned
compile gives the peak-bytes/device proof; the probe compiles give the
extrapolated roofline terms.

Usage::

    PYTHONPATH=src python -m repro.launch.perf --arch qwen2-72b --shape train_4k \
        --variant baseline --variant sp --variant nb16 --variant sp+nb16
    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-7b --shape decode_32k \
        --variant baseline --variant dus
"""

import argparse
import json


# variant name -> kwargs for run_cell / probe_cell
def variant_kwargs(name: str) -> tuple[dict, dict]:
    kw: dict = {"overrides": {}}
    probe_only: dict = {}
    for part in name.split("+"):
        if part == "baseline":
            pass
        elif part.startswith("nb"):
            kw["num_blocks"] = int(part[2:])
        elif part == "sp":
            kw["sp"] = True
        elif part in ("dus", "hdus", "dec"):
            tag = {"dus": "sharded_dus", "hdus": "heads_dus",
                   "dec": "decomposed"}[part]
            prev = kw.get("cache_impl", "")
            kw["cache_impl"] = (prev + "+" + tag) if prev else tag
        elif part == "hoist":
            kw["hoist"] = True
        elif part.startswith("pb"):  # probe the block loop unrolled N deep
            probe_only["probe_blocks"] = int(part[2:])
        elif part.startswith("remat_"):
            kw["overrides"]["remat"] = part[len("remat_"):]
        elif part.startswith("moeg"):
            kw["overrides"]["moe_group"] = int(part[4:])
        elif part.startswith("cf"):
            kw["overrides"]["moe_capacity_factor"] = float(part[2:])
        elif part == "flash":
            kw["overrides"]["attn_impl"] = "flash"
        else:
            raise KeyError(f"unknown variant component {part!r}")
    if not kw["overrides"]:
        kw.pop("overrides")
    return kw, probe_only


def main() -> None:
    from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops
    from repro.launch.dryrun_lib import probe_cell, run_cell
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", required=True)
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod"],
                    default="single_pod")
    ap.add_argument("--out", default=None, help="append JSON rows here")
    ap.add_argument("--no-probe", action="store_true",
                    help="scanned compile only (peak memory, fast)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi_pod")
    rows = []
    hdr = (f"{'variant':16s} {'peakGB/dev':>10s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} {'MFU':>6s}")
    print(f"== {args.arch} × {args.shape} on {args.mesh} ==")
    print(hdr, flush=True)
    mf = model_flops(args.arch, args.shape)
    for name in args.variant:
        kw, probe_only = variant_kwargs(name)
        rec = run_cell(args.arch, args.shape, mesh, mesh_label=args.mesh, **kw)
        row = {"variant": name, **rec}
        if rec["status"] == "OK" and not args.no_probe:
            pkw = {k: v for k, v in kw.items() if k != "num_blocks"}
            if "num_blocks" in kw and "probe_blocks" not in probe_only:
                # probe the block loop unrolled at the variant's blocking
                probe_only["probe_blocks"] = min(kw["num_blocks"], 16)
            p = probe_cell(
                args.arch, args.shape, mesh, mesh_label=args.mesh, **pkw, **probe_only
            )
            if p["status"] == "OK":
                ex = p["extrapolated"]
                terms = {
                    "compute": ex["flops"] / PEAK_FLOPS,
                    "memory": ex["bytes_accessed"] / HBM_BW,
                    "collective": ex["collective_bytes"] / ICI_BW,
                }
                dom = max(terms, key=terms.get)
                mfu = (mf / rec["devices"] / PEAK_FLOPS) / terms[dom]
                row.update(probe=p, terms=terms, dominant=dom, mfu=mfu)
        rows.append(row)
        if "terms" in row:
            t = row["terms"]
            print(f"{name:16s} {rec['memory']['peak_live_bytes']/1e9:10.2f} "
                  f"{t['compute']:10.4f} {t['memory']:10.4f} "
                  f"{t['collective']:10.4f} {row['dominant']:>10s} "
                  f"{row['mfu']:6.3f}", flush=True)
        elif rec["status"] == "OK":
            print(f"{name:16s} {rec['memory']['peak_live_bytes']/1e9:10.2f} "
                  f"{'—':>10s} {'—':>10s} {'—':>10s} {'—':>10s} {'—':>6s}",
                  flush=True)
        else:
            print(f"{name:16s} {rec['status']}: {rec.get('error', '')[:90]}",
                  flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1)


if __name__ == "__main__":
    main()
