"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count locks on first jax init, and smoke
tests must keep seeing 1 device).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the leading
"pod" axis is the slow (DCN/inter-pod) dimension; gradient reductions are
hierarchical across it (DESIGN.md §5).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer jax;
    on older releases every axis is implicitly Auto, which is exactly what
    we request, so the kwarg is passed only when the enum exists.
    """
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for subprocess-isolated distribution tests."""
    return compat_make_mesh(shape, axes)
