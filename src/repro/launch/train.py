"""End-to-end training driver (deliverable b).

Runs the fault-tolerant Trainer on any assigned architecture (reduced smoke
config on CPU; the full config under the production mesh on real hardware)
or on the named presets used by the examples.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --smoke \
        --steps 50 --global-batch 16 --num-blocks 4 --seq-len 64
    PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 300
    PYTHONPATH=src python -m repro.launch.train --preset lm20m --steps 300 \
        --ckpt-dir /tmp/ckpt --ckpt-every 50       # preemption-safe + resume

``--accum-mode`` sweeps the paper's three execution strategies on identical
math: ``spliter`` (one dispatch per step, scan over microbatch blocks),
``per_block`` (the baseline: one dispatch per block), ``materialized``
(fused giant microbatch — the on-device rechunk analogue).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ModelConfig
from repro.runtime.trainer import TrainConfig, Trainer

# ----------------------------------------------------------------------------
# presets for the runnable examples (CPU-sized but real transformers)
# ----------------------------------------------------------------------------


def _preset(name: str) -> ModelConfig:
    common = dict(
        family="dense",
        source="[example preset]",
        num_kv_heads=4,
        qk_norm=False,
        rope_theta=1e4,
        vocab_pad_multiple=128,
        remat="none",
    )
    if name == "lm1m":  # integration-test size
        return ModelConfig(
            name="lm1m", num_layers=2, d_model=64, num_heads=4, d_ff=256,
            vocab_size=512, **common,
        )
    if name == "lm20m":  # a few hundred steps in minutes on CPU
        return ModelConfig(
            name="lm20m", num_layers=6, d_model=384, num_heads=6, d_ff=1536,
            vocab_size=8192, **{**common, "num_kv_heads": 6},
        )
    if name == "lm100m":  # the ~100M end-to-end deliverable configuration
        return ModelConfig(
            name="lm100m", num_layers=12, d_model=768, num_heads=12, d_ff=3072,
            vocab_size=32000, **{**common, "num_kv_heads": 12},
        )
    raise KeyError(f"unknown preset {name!r}")


PRESETS = ("lm1m", "lm20m", "lm100m")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--arch", choices=list(ARCH_IDS))
    g.add_argument("--preset", choices=PRESETS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=4,
                    help="microbatch blocks per step (the blocking)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--warmup-steps", type=int, default=20)
    ap.add_argument("--accum-mode", default="spliter",
                    choices=("spliter", "per_block", "materialized"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out-json", default=None, help="write the loss curve here")
    args = ap.parse_args()

    if args.arch:
        if not args.smoke:
            ap.error("--arch on CPU requires --smoke (full configs are "
                     "exercised via the dry-run, not host training)")
        model_cfg = get_smoke_config(args.arch)
    else:
        model_cfg = _preset(args.preset)

    n_params = model_cfg.param_counts()["total"]
    print(f"model={model_cfg.name}  params={n_params/1e6:.1f}M  "
          f"mode={args.accum_mode}", flush=True)

    cfg = TrainConfig(
        global_batch=args.global_batch,
        num_blocks=args.num_blocks,
        seq_len=args.seq_len,
        steps=args.steps,
        peak_lr=args.peak_lr,
        warmup_steps=args.warmup_steps,
        accum_mode=args.accum_mode,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    trainer = Trainer(model_cfg, cfg)

    t_last = [time.perf_counter()]

    def on_step(step: int, loss: float) -> None:
        if (step + 1) % args.log_every == 0 or step == 0:
            now = time.perf_counter()
            dt = (now - t_last[0]) / (1 if step == 0 else args.log_every)
            t_last[0] = now
            tps = cfg.global_batch * cfg.seq_len / dt
            print(f"step {step + 1:5d}  loss {loss:8.4f}  "
                  f"{dt * 1e3:8.1f} ms/step  {tps:9.0f} tok/s", flush=True)

    out = trainer.run(resume=not args.no_resume, on_step=on_step)
    print(f"done: steps={out['stopped_at']}  dispatches={out['dispatches']}  "
          f"final_loss={out['losses'][-1]:.4f}  wall={out.get('wall_s', 0):.1f}s",
          flush=True)
    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(
                {
                    "model": model_cfg.name,
                    "params_m": n_params / 1e6,
                    "config": dataclasses.asdict(cfg),
                    "losses": out["losses"],
                    "dispatches": out["dispatches"],
                    "wall_s": out.get("wall_s"),
                },
                f,
                indent=1,
            )


if __name__ == "__main__":
    main()
