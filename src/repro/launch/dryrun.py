import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI (deliverable e).

Lowers + compiles every (architecture × input-shape) cell on the single-pod
(16×16) and multi-pod (2×16×16) production meshes, printing
``memory_analysis`` / ``cost_analysis`` per cell and writing the full matrix
to results/dryrun/<mesh>.json.

The two lines above run before ANY other import — jax locks the device
count at first init, and the dry-run needs 512 host-platform placeholder
devices to build the production meshes.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                  # full 2×40 matrix
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod
"""

import argparse


def main() -> None:
    # heavy imports AFTER the XLA_FLAGS line
    from repro.configs import ARCH_IDS, SHAPES
    from repro.launch.dryrun_lib import run_matrix, run_probe_matrix
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", action="append", choices=list(SHAPES), default=None)
    ap.add_argument(
        "--mesh",
        choices=["single_pod", "multi_pod", "both"],
        default="both",
    )
    ap.add_argument(
        "--probe",
        action="store_true",
        help="roofline probes: two unrolled-depth compiles per cell, "
        "extrapolated to full depth (writes <out>/probe_<mesh>.json)",
    )
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    arches = args.arch or list(ARCH_IDS)
    shapes = args.shape or list(SHAPES)
    meshes = []
    if args.mesh in ("single_pod", "both"):
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi_pod", "both"):
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    for label, mesh in meshes:
        if args.probe:
            results = run_probe_matrix(
                arches, shapes, [(label, mesh)],
                out_path=f"{args.out}/probe_{label}.json",
            )
        else:
            results = run_matrix(
                arches, shapes, [(label, mesh)], out_path=f"{args.out}/{label}.json"
            )
        ok = sum(r["status"] == "OK" for r in results)
        skip = sum(r["status"] == "SKIP" for r in results)
        fail = sum(r["status"] == "FAIL" for r in results)
        print(f"== {label}: {ok} OK / {skip} SKIP / {fail} FAIL ==", flush=True)


if __name__ == "__main__":
    main()
